//! Calibration harness: prints filtered/unfiltered geomeans for the 12
//! main variants so the SimLLM tier parameters (rust/src/agent/tiers.rs)
//! can be fitted against the paper's Figure 3 (DESIGN.md §5b).
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::experiments::{run_variant, Bench};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::metrics;

fn main() {
    let bench = Bench::new();
    let pipeline = IntegrityPipeline::default();
    for tier in ModelTier::ALL {
        for spec in ucutlass_repro::experiments::runner::main_variants(tier) {
            let log = run_variant(&bench, &spec, 12345, None);
            let sp: Vec<f64> = log.runs.iter().map(|r| pipeline.filtered_speedup(r, 99).unwrap_or(1.0)).collect();
            let unf: Vec<f64> = log.speedups();
            let beat = sp.iter().filter(|&&s| s > 1.0).count();
            let ge2 = sp.iter().filter(|&&s| s >= 2.0).count();
            println!("{:45} geo={:5.2} med={:5.2} unfilt_geo={:5.2} beat={:2}/59 ge2={:2}",
                spec.label(), metrics::geomean_speedup(&sp), metrics::median_speedup(&sp),
                metrics::geomean_speedup(&unf), beat, ge2);
        }
    }
}
