//! Integrity audit (paper §6.3): run a gaming-prone variant, label every
//! attempt with the three-detector pipeline, and show the outcome bands,
//! the LGD category breakdown, and the speedup inflation that skipping
//! the pipeline would cause.
//!
//! ```bash
//! cargo run --release --example integrity_audit [seed]
//! ```

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::{ModelTier, SolutionKind};
use ucutlass_repro::experiments::runner::{run_variant, Bench};
use ucutlass_repro::integrity::{outcome_counts, IntegrityPipeline, ReviewLabel};
use ucutlass_repro::metrics;
use ucutlass_repro::report::table;

fn main() {
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12345);
    let bench = Bench::new();
    let pipeline = IntegrityPipeline::default();

    // µCUTLASS + MI on the strongest tier: the paper's most gaming-prone cell
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max);
    println!("auditing {} (seed {seed})...\n", spec.label());
    let log = run_variant(&bench, &spec, seed, None);

    let counts = outcome_counts(&pipeline, &log.runs, seed);
    let rows: Vec<Vec<String>> = counts.iter().map(|(k, v)| vec![k.to_string(), v.to_string()]).collect();
    println!("{}", table(&["review band", "attempts"], &rows));

    // which problems were gamed, and what the exploit was
    let mut grows = Vec::new();
    for run in &log.runs {
        let labels = pipeline.review_run(run, seed);
        for (a, l) in run.attempts.iter().zip(&labels) {
            if matches!(l, ReviewLabel::OriginalGaming) {
                if let SolutionKind::Gaming(g) = &a.kind {
                    grows.push(vec![
                        bench.problems[run.problem_idx].id.to_string(),
                        g.name().to_string(),
                        format!("{:.3} ms", a.outcome.time_ms().unwrap_or(0.0)),
                        format!("{:.3} ms", run.t_sol_fp16_ms),
                    ]);
                }
            }
        }
    }
    println!("original gaming discoveries:");
    println!("{}", table(&["problem", "exploit", "claimed time", "FP16 SOL"], &grows));

    // inflation
    let geo = |allow: &[ReviewLabel]| {
        let sp: Vec<f64> = log
            .runs
            .iter()
            .map(|r| pipeline.speedup_allowing(r, seed, allow).unwrap_or(1.0))
            .collect();
        metrics::geomean_speedup(&sp)
    };
    let filtered = geo(&[]);
    let unfiltered = geo(&ReviewLabel::ALL);
    println!(
        "filtered geomean {filtered:.2}x | unfiltered {unfiltered:.2}x | inflation {:.2}x",
        unfiltered / filtered
    );
}
