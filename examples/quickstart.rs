//! Quickstart: compile a µCUTLASS program, read its SOL report, run one
//! SOL-guided agent on one problem, run the whole suite through the
//! online SOL-budgeted scheduler (realized attempt/token savings), and
//! (when `make artifacts` has run) numerically validate the selected
//! kernel through the PJRT runtime.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ucutlass_repro::agent::controller::{run_problem, ControllerKind, VariantSpec};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::exec;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::runtime::Runtime;
use ucutlass_repro::scheduler::{self, Policy};
use ucutlass_repro::{dsl, kernelbench, sol};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. compile a µCUTLASS kernel specification ------------------------
    let src = "\
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
.with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)
.with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)
.with_stages(2).with_scheduler(kernel=tma_cooperative, epilogue=auto)
>> bias() >> relu()";
    let compiled = dsl::compile(src)?;
    println!("=== µCUTLASS compile ===");
    println!("header: {} ({} bytes)", compiled.header_name, compiled.header.len());
    let k = compiled.plan.primary();
    println!(
        "plan: {} on {} tile {}x{}x{} {} stages={} smem={}B hash={}\n",
        k.family, k.arch, k.tile.m, k.tile.n, k.tile.k, k.dtype_input, k.stages,
        k.smem_bytes, compiled.plan.config_hash
    );

    // ... and see a static rejection with its explanatory hint:
    let bad = src.replace("sm_90a", "sm_90");
    println!("=== static rejection demo ===\n{}\n", dsl::compile(&bad).unwrap_err());

    // --- 2. SOL analysis for KernelBench problem L1-1 -----------------------
    let problems = kernelbench::suite();
    let idx = kernelbench::find(&problems, "L1-1").unwrap();
    let analysis = sol::analyze(&problems[idx], &sol::H100_SXM);
    println!("=== SOL (L1-1, 4096^3 FP32 GEMM) ===");
    println!(
        "t_SOL = {:.3} ms (TF32), {:.3} ms (FP16 augmented), bottleneck {:?}\n",
        analysis.t_sol_ms, analysis.t_sol_fp16_ms, analysis.bottleneck
    );

    // --- 3. one SOL-guided µCUTLASS agent run --------------------------------
    let bench = Bench::new();
    let env = bench.env();
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini);
    let run = run_problem(&env, &spec, idx, 42);
    let pipeline = IntegrityPipeline::default();
    let best = pipeline.filtered_best_ms(&run, 42);
    println!("=== agent run ({}) on L1-1 ===", spec.label());
    println!(
        "t_ref {:.3} ms -> best {:?} ms  speedup {:.2}x  SOL gap {:.2}",
        run.t_ref_ms,
        best,
        pipeline.filtered_speedup(&run, 42).unwrap_or(1.0),
        analysis.gap(best.unwrap_or(run.t_ref_ms)),
    );

    // --- 4. online SOL-budgeted scheduling over the suite --------------------
    // The paper's ε=100%/w=8 policy applied DURING execution: attempts
    // stop as soon as a problem is within 2x of its FP16 SOL bound (and
    // ahead of PyTorch) or has made no progress for 8 attempts. The
    // savings printed here were genuinely never spent.
    let jobs = exec::effective_jobs(0);
    let env = bench.env();
    let policy = Policy { epsilon: 1.0, window: 8 };
    let online = scheduler::run_online(&env, &spec, 42, &policy, jobs);
    let fixed = scheduler::run_online(&env, &spec, 42, &Policy::fixed(), jobs);
    println!("\n=== online scheduler ({}, {} jobs) ===", policy.label(), jobs);
    // (orchestrated sessions run with per-problem memory here — the online
    // rotation has no defined cross-problem memory order, ADR-002)
    println!(
        "attempts {} of {} ({:.0}% saved, {} problems stopped early)",
        online.attempts_total(),
        fixed.attempts_total(),
        online.attempt_savings() * 100.0,
        online.stopped_early()
    );
    println!(
        "tokens   {:.1}M of {:.1}M ({:.0}% saved)",
        online.tokens_used as f64 / 1e6,
        fixed.tokens_used as f64 / 1e6,
        online.token_savings_vs(&fixed.log) * 100.0
    );

    // --- 5. numeric validation via PJRT (needs `make artifacts`) -------------
    match Runtime::open("artifacts") {
        Ok(mut rt) => {
            let prob = rt.manifest.problems.get("gemm_square").cloned().unwrap();
            let variant = Runtime::select_variant(&prob, &compiled.plan).unwrap();
            let report = rt.validate_variant("gemm_square", &variant, 7)?;
            println!("\n=== PJRT numeric validation ===");
            println!(
                "gemm_square/{}: max|err| {:.2e} over {} elems -> {}",
                report.variant,
                report.max_abs_err,
                report.elems,
                if report.pass { "PASS" } else { "FAIL" }
            );
        }
        Err(_) => println!("\n(artifacts/ not built — run `make artifacts` for the PJRT demo)"),
    }
    Ok(())
}
