//! Scheduler study (paper §6.2): run one variant, sweep all (ε, w)
//! policies, print the Pareto frontier and the best policy under the 95%
//! retention constraint.
//!
//! ```bash
//! cargo run --release --example scheduler_replay [mini|mid|max] [seed]
//! ```

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::experiments::runner::{run_variant, Bench};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::report::table;
use ucutlass_repro::scheduler::{self, Policy};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tier = match args.first().map(String::as_str) {
        Some("mini") => ModelTier::Mini,
        Some("mid") => ModelTier::Mid,
        _ => ModelTier::Max,
    };
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12345);

    let bench = Bench::new();
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, tier);
    println!("running {} over 59 problems...", spec.label());
    let log = run_variant(&bench, &spec, seed, None);
    let pipeline = IntegrityPipeline::default();

    // independent ε sweep
    let mut rows = Vec::new();
    for &e in &scheduler::epsilon_grid() {
        let r = scheduler::replay(&log, &Policy { epsilon: e, window: 0 }, &pipeline, seed);
        rows.push(vec![
            format!("ε={}%", (e * 100.0) as u64),
            format!("{:.0}%", r.token_savings() * 100.0),
            format!("{:.0}%", r.geomean_retention() * 100.0),
            format!("{:.2}x", r.efficiency_gain()),
        ]);
    }
    println!("{}", table(&["policy", "token savings", "geo retention", "gain"], &rows));

    // joint sweep + Pareto frontier
    let sweep = scheduler::sweep(&log, &pipeline, seed);
    let pts: Vec<(f64, f64)> = sweep
        .iter()
        .map(|r| (r.tokens_used as f64 / r.tokens_fixed as f64, r.geomean))
        .collect();
    let front = scheduler::pareto_front(&pts);
    println!("Pareto frontier ({} of {} policies):", front.len(), sweep.len());
    for &i in &front {
        println!(
            "  {:16}  cost {:.2}  geomean {:.2}x",
            sweep[i].policy.label(),
            pts[i].0,
            pts[i].1
        );
    }

    match scheduler::best_policy(&sweep, 0.95) {
        Some(best) => println!(
            "\nbest policy (≥95% retention): {} -> {:.0}% savings, {:.0}% retention, {:.2}x efficiency gain",
            best.policy.label(),
            best.token_savings() * 100.0,
            best.geomean_retention() * 100.0,
            best.efficiency_gain()
        ),
        None => println!("\nno policy met the 95% retention constraint"),
    }
}
