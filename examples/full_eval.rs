//! End-to-end driver (DESIGN.md deliverable (b), EXPERIMENTS.md §E2E):
//! exercises the full three-layer stack on a real workload:
//!
//! 1. runs the four main agent variants for one tier over all 59 problems
//!    (Generate–Compile–Test–Profile loops with real µCUTLASS compilation
//!    on every DSL attempt), fanned across the deterministic parallel
//!    engine (`--jobs`-equivalent third argument),
//! 2. applies the integrity pipeline and reports Fast-p / geomean,
//! 3. replays the best scheduler policy offline, then *executes* the
//!    paper's ε=100%/w=8 policy through the online scheduler so the
//!    attempt/token savings are realized, not simulated,
//! 4. numerically validates the winning kernel of every artifact-backed
//!    problem by executing candidate + reference HLO through PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_eval [tier] [seed] [jobs]
//! ```

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::{ModelTier, SolutionKind};
use ucutlass_repro::exec;
use ucutlass_repro::experiments::runner::{main_variants, Bench};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::metrics;
use ucutlass_repro::perfmodel::CandidateConfig;
use ucutlass_repro::report::table;
use ucutlass_repro::runtime::Runtime;
use ucutlass_repro::scheduler::{self, Policy};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tier = match args.first().map(String::as_str) {
        Some("mid") => ModelTier::Mid,
        Some("max") => ModelTier::Max,
        _ => ModelTier::Mini,
    };
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12345);
    let jobs: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0); // 0 = all cores

    let bench = Bench::new();
    let pipeline = IntegrityPipeline::default();
    println!(
        "=== full evaluation, tier {} (seed {seed}, {} jobs) ===\n",
        tier.name(),
        exec::effective_jobs(jobs)
    );

    let work: Vec<_> = main_variants(tier).into_iter().map(|s| (s, None)).collect();
    let t0 = std::time::Instant::now();
    let logs = exec::eval_variants(&bench, &work, seed, jobs);
    let eval_wall = t0.elapsed();

    let mut rows = Vec::new();
    let mut best_log: Option<(f64, ucutlass_repro::agent::RunLog, VariantSpec)> = None;
    for ((spec, _), log) in work.iter().zip(logs) {
        let speedups: Vec<f64> = log
            .runs
            .iter()
            .map(|r| pipeline.filtered_speedup(r, seed).unwrap_or(1.0))
            .collect();
        let geo = metrics::geomean_speedup(&speedups);
        rows.push(vec![
            spec.label(),
            format!("{geo:.2}x"),
            format!("{:.2}x", metrics::median_speedup(&speedups)),
            format!("{}", speedups.iter().filter(|&&s| s > 1.0).count()),
            format!("{}", speedups.iter().filter(|&&s| s >= 2.0).count()),
            format!("${:.2}", log.dollar_cost()),
        ]);
        if best_log.as_ref().map(|(g, _, _)| geo > *g).unwrap_or(true) {
            best_log = Some((geo, log, *spec));
        }
    }
    println!(
        "{}",
        table(&["variant", "geomean", "median", ">1x", ">=2x", "cost"], &rows)
    );
    println!("(4 variants × 59 problems evaluated in {eval_wall:.2?})\n");

    // offline scheduler replay on the best variant
    let (_, log, spec) = best_log.unwrap();
    let sweep = scheduler::sweep(&log, &pipeline, seed);
    if let Some(best) = scheduler::best_policy(&sweep, 0.95) {
        println!(
            "best offline policy for {}: {} -> {:.0}% token savings, {:.0}% retention, {:.2}x efficiency gain\n",
            spec.label(),
            best.policy.label(),
            best.token_savings() * 100.0,
            best.geomean_retention() * 100.0,
            best.efficiency_gain()
        );
    }

    // ONLINE scheduling: execute the paper's ε=100%/w=8 policy — savings
    // below are attempts/tokens that were genuinely never spent.
    let env = bench.env();
    let policy = Policy { epsilon: 1.0, window: 8 };
    let online = scheduler::run_online(&env, &spec, seed, &policy, jobs);
    // Fixed baseline: for flat controllers the eval log above IS the
    // fixed-budget run (run_online under Policy::fixed() reproduces it
    // bit-for-bit), so don't re-simulate 59×40 attempts. Orchestrated
    // variants differ — the online engine uses per-problem memory, not the
    // eval's cross-problem chain (ADR-002) — so recompute, and say so.
    let fixed_log = if spec.controller == ControllerKind::OrchestratedSol {
        println!("(orchestrated: online engine uses per-problem memory, not the eval's cross-problem chain)");
        scheduler::run_online(&env, &spec, seed, &Policy::fixed(), jobs).log
    } else {
        log.clone()
    };
    let fixed_attempts: usize = fixed_log.runs.iter().map(|r| r.attempts.len()).sum();
    let geo_of = |l: &ucutlass_repro::agent::RunLog| pipeline.filtered_geomean(l, seed);
    println!("=== online SOL-budgeted scheduling ({}, {}) ===", spec.label(), policy.label());
    println!(
        "attempts: {} of {} ({:.0}% saved; {} of {} problems stopped early)",
        online.attempts_total(),
        fixed_attempts,
        online.attempt_savings() * 100.0,
        online.stopped_early(),
        online.attempts_used.len()
    );
    println!(
        "tokens:   {:.1}M of {:.1}M ({:.0}% saved, ${:.2} of ${:.2})",
        online.tokens_used as f64 / 1e6,
        fixed_log.total_tokens() as f64 / 1e6,
        online.token_savings_vs(&fixed_log) * 100.0,
        online.log.dollar_cost(),
        fixed_log.dollar_cost()
    );
    println!(
        "geomean:  {:.2}x vs fixed {:.2}x ({:.0}% retention)\n",
        geo_of(&online.log),
        geo_of(&fixed_log),
        metrics::retention(geo_of(&online.log), geo_of(&fixed_log)) * 100.0
    );

    // PJRT numeric validation of winning kernels on artifact-backed problems
    match Runtime::open("artifacts") {
        Err(e) => println!("(skipping PJRT validation: {e})"),
        Ok(mut rt) => {
            let mut vrows = Vec::new();
            let mut fails = 0;
            for (pidx, run) in log.runs.iter().enumerate() {
                let Some(artifact) = bench.problems[pidx].artifact else { continue };
                // config of the best accepted genuine attempt
                let best_cfg: Option<&CandidateConfig> = run
                    .attempts
                    .iter()
                    .filter(|a| {
                        matches!(a.kind, SolutionKind::DslKernel | SolutionKind::RawCuda)
                            && a.outcome.time_ms().is_some()
                    })
                    .min_by(|a, b| {
                        a.outcome.time_ms().partial_cmp(&b.outcome.time_ms()).unwrap()
                    })
                    .and_then(|a| a.config.as_ref());
                let Some(cfg) = best_cfg else { continue };
                let Some(prob) = rt.manifest.problems.get(artifact).cloned() else { continue };
                // map the winning config onto the nearest AOT variant
                let variant =
                    Runtime::select_variant_for(&prob, cfg.tile, cfg.compute_dtype).unwrap();
                let rep = rt.validate_variant(artifact, &variant, seed)?;
                if !rep.pass {
                    fails += 1;
                }
                vrows.push(vec![
                    bench.problems[pidx].id.to_string(),
                    artifact.to_string(),
                    variant,
                    format!("{:.2e}", rep.max_abs_err),
                    if rep.pass { "PASS".into() } else { "FAIL".into() },
                ]);
            }
            println!("=== PJRT numeric validation of winning kernels ===");
            println!(
                "{}",
                table(&["problem", "artifact", "selected variant", "max |err|", "status"], &vrows)
            );
            println!(
                "{} validations, {} failures, {} executables compiled once and cached",
                vrows.len(),
                fails,
                rt.cached()
            );
            if fails > 0 {
                return Err(format!("{fails} winning kernels failed numeric validation").into());
            }
        }
    }
    Ok(())
}
