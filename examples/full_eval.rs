//! End-to-end driver (DESIGN.md deliverable (b), EXPERIMENTS.md §E2E):
//! exercises the full three-layer stack on a real workload:
//!
//! 1. runs the four main agent variants for one tier over all 59 problems
//!    (Generate–Compile–Test–Profile loops with real µCUTLASS compilation
//!    on every DSL attempt),
//! 2. applies the integrity pipeline and reports Fast-p / geomean,
//! 3. replays the best scheduler policy,
//! 4. numerically validates the winning kernel of every artifact-backed
//!    problem by executing candidate + reference HLO through PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_eval [tier] [seed]
//! ```

use ucutlass_repro::agent::controller::VariantSpec;
use ucutlass_repro::agent::{ModelTier, SolutionKind};
use ucutlass_repro::experiments::runner::{main_variants, run_variant, Bench};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::metrics;
use ucutlass_repro::perfmodel::CandidateConfig;
use ucutlass_repro::report::table;
use ucutlass_repro::runtime::Runtime;
use ucutlass_repro::scheduler;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tier = match args.first().map(String::as_str) {
        Some("mid") => ModelTier::Mid,
        Some("max") => ModelTier::Max,
        _ => ModelTier::Mini,
    };
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12345);

    let bench = Bench::new();
    let pipeline = IntegrityPipeline::default();
    println!("=== full evaluation, tier {} (seed {seed}) ===\n", tier.name());

    let mut rows = Vec::new();
    let mut best_log: Option<(f64, ucutlass_repro::agent::RunLog, VariantSpec)> = None;
    for spec in main_variants(tier) {
        let log = run_variant(&bench, &spec, seed, None);
        let speedups: Vec<f64> = log
            .runs
            .iter()
            .map(|r| pipeline.filtered_speedup(r, seed).unwrap_or(1.0))
            .collect();
        let geo = metrics::geomean_speedup(&speedups);
        rows.push(vec![
            spec.label(),
            format!("{geo:.2}x"),
            format!("{:.2}x", metrics::median_speedup(&speedups)),
            format!("{}", speedups.iter().filter(|&&s| s > 1.0).count()),
            format!("{}", speedups.iter().filter(|&&s| s >= 2.0).count()),
            format!("${:.2}", log.dollar_cost()),
        ]);
        if best_log.as_ref().map(|(g, _, _)| geo > *g).unwrap_or(true) {
            best_log = Some((geo, log, spec));
        }
    }
    println!(
        "{}",
        table(&["variant", "geomean", "median", ">1x", ">=2x", "cost"], &rows)
    );

    // scheduler replay on the best variant
    let (_, log, spec) = best_log.unwrap();
    let sweep = scheduler::sweep(&log, &pipeline, seed);
    if let Some(best) = scheduler::best_policy(&sweep, 0.95) {
        println!(
            "best scheduler policy for {}: {} -> {:.0}% token savings, {:.0}% retention, {:.2}x efficiency gain\n",
            spec.label(),
            best.policy.label(),
            best.token_savings() * 100.0,
            best.geomean_retention() * 100.0,
            best.efficiency_gain()
        );
    }

    // PJRT numeric validation of winning kernels on artifact-backed problems
    match Runtime::open("artifacts") {
        Err(e) => println!("(skipping PJRT validation: {e})"),
        Ok(mut rt) => {
            let mut vrows = Vec::new();
            let mut fails = 0;
            for (pidx, run) in log.runs.iter().enumerate() {
                let Some(artifact) = bench.problems[pidx].artifact else { continue };
                // config of the best accepted genuine attempt
                let best_cfg: Option<&CandidateConfig> = run
                    .attempts
                    .iter()
                    .filter(|a| {
                        matches!(a.kind, SolutionKind::DslKernel | SolutionKind::RawCuda)
                            && a.outcome.time_ms().is_some()
                    })
                    .min_by(|a, b| {
                        a.outcome.time_ms().partial_cmp(&b.outcome.time_ms()).unwrap()
                    })
                    .and_then(|a| a.config.as_ref());
                let Some(cfg) = best_cfg else { continue };
                let Some(prob) = rt.manifest.problems.get(artifact).cloned() else { continue };
                // map the winning config onto the nearest AOT variant
                let variant =
                    Runtime::select_variant_for(&prob, cfg.tile, cfg.compute_dtype).unwrap();
                let rep = rt.validate_variant(artifact, &variant, seed)?;
                if !rep.pass {
                    fails += 1;
                }
                vrows.push(vec![
                    bench.problems[pidx].id.to_string(),
                    artifact.to_string(),
                    variant,
                    format!("{:.2e}", rep.max_abs_err),
                    if rep.pass { "PASS".into() } else { "FAIL".into() },
                ]);
            }
            println!("=== PJRT numeric validation of winning kernels ===");
            println!(
                "{}",
                table(&["problem", "artifact", "selected variant", "max |err|", "status"], &vrows)
            );
            println!(
                "{} validations, {} failures, {} executables compiled once and cached",
                vrows.len(),
                fails,
                rt.cached()
            );
            if fails > 0 {
                return Err(format!("{fails} winning kernels failed numeric validation").into());
            }
        }
    }
    Ok(())
}
