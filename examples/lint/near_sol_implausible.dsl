# Adversarial corpus: constant-output epilogue (ADR-009).
# Expected: A103 (deny) — clip(5, 5) clamps every element to the same
# value regardless of the computed product, so a measurement of this
# kernel can undercut the SOL bound only because the declared computation
# is no longer performed (constant-output gaming).
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=128, n=64, k=64).with_stages(3)
    >> clip(5.0, 5.0)
