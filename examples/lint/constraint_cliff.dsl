# Corpus: constraint-cliff notes (ADR-009). Valid program, exit code 0.
# Expected: C402 (note) — with_stages(12) is the sm_90a maximum; any
#           upward mutation is a hard reject.
#           C403 (note) — alignment 8 × fp16 = 16 bytes, exactly the TMA
#           vector minimum; halving any alignment rejects.
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=64, n=64, k=16)
    .with_stages(12)
    .with_alignment(A=8, B=8, C=8)
