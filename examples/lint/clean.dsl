# Corpus: a clean program (ADR-009). Expected: zero diagnostics, exit 0.
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=128, n=64, k=64).with_stages(3)
    >> bias() >> relu()
