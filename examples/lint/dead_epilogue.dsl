# Adversarial corpus: dead epilogue store (ADR-009).
# Expected: A201 (warn) — aux_store(t0) is never aux_load-ed, so the
# stored tensor is unobservable downstream; the store is dead weight and a
# chain built around it can hide skipped computation.
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=128, n=64, k=64).with_stages(3)
    >> aux_store(t0) >> relu()
