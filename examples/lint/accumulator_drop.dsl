# Adversarial corpus: accumulator-dropping epilogue (ADR-009).
# Expected: A202 (deny) — scale(0) multiplies the accumulator by zero, so
# every FLOP the main loop computes is discarded; any measured speedup is
# benchmark gaming, not optimization.
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=128, n=64, k=64).with_stages(3)
    >> scale(0.0)
