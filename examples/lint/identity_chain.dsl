# Adversarial corpus: identity epilogue chain (ADR-009).
# Expected: A203 (warn) × 2 — scale(1) and leaky_relu(alpha=1) are both
# identities: each consumes an EVT fusion slot and trial variance without
# changing the output.
gemm().with_dtype(input=fp16, acc=fp32, output=fp16)
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)
    .with_arch(sm_90a)
    .with_threadblockshape(m=128, n=64, k=64).with_stages(3)
    >> scale(1.0) >> leaky_relu(alpha=1.0)
