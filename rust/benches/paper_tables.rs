//! End-to-end benchmark: regenerate every paper table/figure and report
//! wall time + the headline number each produces. This is the "one bench
//! per paper table" target — each row is one §6 artifact regenerated from
//! scratch (fresh seeded runs through the full agent/DSL/SOL/scheduler/
//! integrity stack).

use std::time::Instant;

use ucutlass_repro::experiments::figures::{self, ExpCtx};

fn main() {
    println!("== paper-artifact regeneration benchmark ==");
    let outdir = std::env::temp_dir().join("ucutlass_bench_results");
    let mut ctx = ExpCtx::new(&outdir, 12345);

    let figs: Vec<(&str, fn(&mut ExpCtx) -> String)> = vec![
        ("fig3  (geomean, 12 variants)", figures::fig3),
        ("fig4  (Fast-p / Attempt-Fast-p)", figures::fig4),
        ("fig5  (orchestrated vs in-prompt)", figures::fig5),
        ("fig6  (MANTIS ablations)", figures::fig6),
        ("fig7  (scheduler sweeps)", figures::fig7),
        ("fig8  (Pareto frontiers)", figures::fig8),
        ("fig9  (best policies)", figures::fig9),
        ("fig10 (review outcomes)", figures::fig10),
        ("fig11 (LGD breakdown)", figures::fig11),
        ("fig12 (speedup inflation)", figures::fig12),
        ("fig13 (run-to-run variation)", figures::fig13),
        ("fig14 (archive comparison)", figures::fig14),
        ("tab4  (prompt guardrails)", figures::tab4),
    ];
    let t_all = Instant::now();
    for (name, f) in figs {
        let t0 = Instant::now();
        let out = f(&mut ctx);
        let dt = t0.elapsed();
        let first = out.lines().next().unwrap_or("");
        println!("{name:38} {:>8.2?}   {first}", dt);
    }
    println!("\ntotal (with run-log cache): {:.2?}", t_all.elapsed());
    println!("results written to {}", outdir.display());
}
