//! Hot-path micro-benchmarks (L3 perf deliverable, DESIGN.md §6).
//!
//! criterion is not in the offline vendor set, so this is a small
//! hand-rolled harness: warmup + N timed iterations, median-of-batches
//! ns/op, printed as a table. Run with `cargo bench` (harness = false).

use std::hint::black_box;
use std::time::Instant;

use ucutlass_repro::agent::controller::{run_problem, ControllerKind, Env, VariantSpec};
use ucutlass_repro::agent::policy::{select_move, TILES};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::dsl;
use ucutlass_repro::eval::{AnalyticEvaluator, EvalRequest, Evaluator, Oracle, WorkManifest};
use ucutlass_repro::exec;
use ucutlass_repro::experiments::runner::{main_variants, Bench as SuiteBench};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::kernelbench::suite;
use ucutlass_repro::perfmodel::{CandidateConfig, CompiledCostModel, ConfigBatch, PerfModel};
use ucutlass_repro::scheduler::{self, Policy};
use ucutlass_repro::sol::{analyze, H100_SXM};
use ucutlass_repro::util::rng::Pcg32;

/// Time `f` over batches; report median batch ns/op.
fn bench(name: &str, iters_per_batch: usize, batches: usize, mut f: impl FnMut()) {
    // warmup
    for _ in 0..iters_per_batch.min(100) {
        f();
    }
    let mut per_op: Vec<f64> = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters_per_batch {
            f();
        }
        per_op.push(t0.elapsed().as_nanos() as f64 / iters_per_batch as f64);
    }
    per_op.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = per_op[per_op.len() / 2];
    let ops_per_s = 1e9 / med;
    println!("{name:40} {med:>12.0} ns/op  {ops_per_s:>12.0} ops/s");
}

const GEMM_SRC: &str = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
    .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
    .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)\
    .with_stages(2).with_scheduler(kernel=tma_cooperative, epilogue=auto)\
    >> bias() >> relu()";

fn main() {
    println!("== hot-path benchmarks (median ns/op) ==");
    let problems = suite();
    let model = PerfModel::new(H100_SXM.clone());
    let sols: Vec<_> = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
    let compiled = CompiledCostModel::compile(&model, &problems);

    bench("dsl::compile (cold: parse→lower→validate→plan→codegen)", 2_000, 9, || {
        black_box(dsl::compile(black_box(GEMM_SRC)).unwrap());
    });

    bench("dsl::validate_source (agent verdict path)", 2_000, 9, || {
        black_box(dsl::validate_source(black_box(GEMM_SRC)).unwrap());
    });

    bench("dsl::compile (invalid, static reject)", 2_000, 9, || {
        let src = GEMM_SRC.replace("sm_90a", "sm_90");
        black_box(dsl::compile(black_box(&src)).unwrap_err());
    });

    // plan cache: warm lookups vs cold compiles (ADR-001 acceptance —
    // a repeated identical candidate must be at least 5x cheaper)
    let mut cache = dsl::PlanCache::new();
    dsl::compile_cached(GEMM_SRC, &mut cache).unwrap();
    bench("dsl::compile_cached (warm, identical config)", 20_000, 9, || {
        black_box(dsl::compile_cached(black_box(GEMM_SRC), &mut cache).unwrap());
    });
    {
        let iters = 4_000;
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(dsl::compile(black_box(GEMM_SRC)).unwrap());
        }
        let cold_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let mut c = dsl::PlanCache::new();
        dsl::compile_cached(GEMM_SRC, &mut c).unwrap();
        let t1 = Instant::now();
        for _ in 0..iters {
            black_box(dsl::compile_cached(black_box(GEMM_SRC), &mut c).unwrap());
        }
        let warm_ns = t1.elapsed().as_nanos() as f64 / iters as f64;
        println!(
            "{:40} {:>12.0} ns cold  {:>9.0} ns warm  -> {:.1}x cheaper (target >= 5x)",
            "plan cache speedup", cold_ns, warm_ns, cold_ns / warm_ns
        );
    }

    bench("sol::analyze (per problem)", 20_000, 9, || {
        black_box(analyze(black_box(&problems[0]), &H100_SXM));
    });

    let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp16);
    bench("perfmodel::candidate_ms", 50_000, 9, || {
        black_box(model.candidate_ms(black_box(&problems[0]), black_box(&cfg)));
    });

    bench("perfmodel::baseline_ms (8-op graph)", 20_000, 9, || {
        black_box(model.baseline_ms(black_box(&problems[44])));
    });

    // ---- batched vs scalar candidate_ms (ADR-003 acceptance: the batch
    // path must beat per-config scalar calls by hoisting problem terms) ---
    {
        let cfgs: Vec<CandidateConfig> = TILES
            .iter()
            .flat_map(|&t| {
                [
                    CandidateConfig::library(t, dsl::DType::Fp32),
                    CandidateConfig::library(t, dsl::DType::Fp16),
                ]
            })
            .collect();
        let iters = 50_000;
        let t0 = Instant::now();
        for _ in 0..iters {
            for c in &cfgs {
                black_box(model.candidate_ms(black_box(&problems[0]), black_box(c)));
            }
        }
        let scalar_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let t1 = Instant::now();
        for _ in 0..iters {
            black_box(model.candidate_ms_batch(black_box(&problems[0]), black_box(&cfgs)));
        }
        let batch_ns = t1.elapsed().as_nanos() as f64 / iters as f64;
        println!(
            "{:40} {:>12.0} ns scalar  {:>7.0} ns batch -> {:.1}x (batch of {})",
            "candidate_ms: batched vs scalar x20",
            scalar_ns,
            batch_ns,
            scalar_ns / batch_ns.max(1.0),
            cfgs.len()
        );

        // ---- compiled cost model (ADR-006): the pre-lowered evaluator
        // over a reusable struct-of-arrays batch must beat both the scalar
        // loop and the per-call-lowering `candidate_ms_batch` ------------
        use ucutlass_repro::util::json::Json;
        let costs = compiled.problem(0);
        let mut cb = ConfigBatch::with_capacity(cfgs.len());
        let mut out = vec![0.0f64; cfgs.len()];
        let t2 = Instant::now();
        for _ in 0..iters {
            cb.clear();
            cb.reserve(cfgs.len());
            for c in &cfgs {
                cb.push(black_box(c));
            }
            costs.eval_into(&cb, &mut out);
            black_box(&out);
        }
        let compiled_ns = t2.elapsed().as_nanos() as f64 / iters as f64;
        println!(
            "{:40} {:>12.0} ns scalar  {:>7.0} ns compiled -> {:.1}x (batch of {})",
            "candidate_ms: compiled vs scalar x20",
            scalar_ns,
            compiled_ns,
            scalar_ns / compiled_ns.max(1.0),
            cfgs.len()
        );

        // bitwise contract spot-check before publishing numbers
        let batch_vals = model.candidate_ms_batch(&problems[0], &cfgs);
        for (i, c) in cfgs.iter().enumerate() {
            let scalar = model.candidate_ms(&problems[0], c);
            assert_eq!(scalar.to_bits(), batch_vals[i].to_bits());
            assert_eq!(scalar.to_bits(), out[i].to_bits());
        }

        // machine-readable perf trajectory (BENCH_costmodel.json next to
        // Cargo.toml; re-run `cargo bench` to refresh)
        let calls = (iters * cfgs.len()) as u64;
        let mut j = Json::obj();
        j.set("bench", "compiled_cost_model")
            .set("configs", cfgs.len() as u64)
            .set("iters", iters as u64)
            .set("evaluator_calls_per_path", calls)
            .set("scalar_ms", scalar_ns * iters as f64 / 1e6)
            .set("batch_ms", batch_ns * iters as f64 / 1e6)
            .set("compiled_ms", compiled_ns * iters as f64 / 1e6)
            .set("compiled_vs_scalar", scalar_ns / compiled_ns.max(1.0))
            .set("compiled_vs_batch", batch_ns / compiled_ns.max(1.0));
        match std::fs::write("BENCH_costmodel.json", j.to_string()) {
            Ok(()) => println!("(wrote BENCH_costmodel.json)"),
            Err(e) => println!("(could not write BENCH_costmodel.json: {e})"),
        }
    }

    let ev = Oracle::analytic(AnalyticEvaluator::new(&model, &problems, &sols, &compiled));
    let mut rng = Pcg32::new(1, 1);
    bench("policy::select_move (steered, batched)", 10_000, 9, || {
        black_box(select_move(
            &ev,
            0,
            &cfg,
            ModelTier::Mid.params(),
            Some(&sols[0]),
            0.1,
            &mut rng,
        ));
    });

    // ---- eval manifest roundtrip (the shard/merge protocol's serialization
    // hot path: serialize + parse a realistic request manifest) -----------
    {
        use ucutlass_repro::util::rng::{stream, StreamPath};
        let reqs: Vec<EvalRequest> = (0..problems.len())
            .flat_map(|p| {
                TILES.iter().enumerate().map(move |(i, &t)| {
                    EvalRequest::measured(
                        p,
                        CandidateConfig::library(t, dsl::DType::Fp16),
                        StreamPath::new(7, &[stream::MEASURE, p as u64, i as u64]),
                    )
                })
            })
            .collect();
        let manifest = WorkManifest::new(reqs);
        let text = manifest.to_json().to_string();
        let n = manifest.requests.len();
        bench("eval::WorkManifest serialize (590 reqs)", 200, 7, || {
            black_box(manifest.to_json().to_string());
        });
        bench("eval::WorkManifest parse (590 reqs)", 200, 7, || {
            black_box(WorkManifest::parse(black_box(&text)).unwrap());
        });
        let parsed = WorkManifest::parse(&text).unwrap();
        assert_eq!(parsed, manifest, "manifest roundtrip must be lossless ({n} requests)");
        bench("eval::eval_batch (59 problems x 10 cfgs)", 500, 7, || {
            black_box(ev.eval_batch(black_box(&manifest.requests)));
        });
    }

    let env = Env::new(&model, &problems, &sols, &compiled);
    let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
    bench("agent::run_problem (40 attempts)", 50, 7, || {
        black_box(run_problem(&env, &spec, 0, 7));
    });

    // scheduler replay over a realistic log
    let runs: Vec<_> = (0..problems.len()).map(|i| run_problem(&env, &spec, i, 7)).collect();
    let log = ucutlass_repro::agent::RunLog {
        variant: "bench".into(),
        tier_name: "gpt-5".into(),
        price_per_mtok: 1.25,
        runs,
    };
    let pipeline = IntegrityPipeline::default();
    bench("scheduler::replay (59 problems)", 200, 7, || {
        black_box(scheduler::replay(
            &log,
            &Policy { epsilon: 1.0, window: 8 },
            &pipeline,
            7,
        ));
    });

    bench("scheduler::sweep (72 policies)", 5, 5, || {
        black_box(scheduler::sweep(&log, &pipeline, 7));
    });

    bench("integrity::review_run (40 attempts)", 5_000, 9, || {
        black_box(pipeline.review_run(black_box(&log.runs[0]), 7));
    });

    // ---- serial vs parallel multi-variant eval (ADR-002 acceptance:
    // ≥ 2x wall-clock at 4 jobs, bit-identical output) --------------------
    {
        let suite_bench = SuiteBench::new();
        let work: Vec<_> = main_variants(ModelTier::Mid).into_iter().map(|s| (s, None)).collect();
        let t0 = Instant::now();
        let serial = exec::eval_variants(&suite_bench, &work, 7, 1);
        let t_serial = t0.elapsed();
        let t1 = Instant::now();
        let parallel = exec::eval_variants(&suite_bench, &work, 7, 4);
        let t_parallel = t1.elapsed();
        let identical = serial == parallel;
        println!(
            "{:40} {:>9.0} ms serial  {:>7.0} ms @4 jobs -> {:.1}x (target >= 2x), bit-identical: {}",
            "exec::eval_variants (4 variants x 59)",
            t_serial.as_secs_f64() * 1e3,
            t_parallel.as_secs_f64() * 1e3,
            t_serial.as_secs_f64() / t_parallel.as_secs_f64().max(1e-9),
            identical
        );
    }

    // ---- fixed vs online budget (realized savings, not replay) ----------
    {
        let suite_bench = SuiteBench::new();
        let env2 = suite_bench.env();
        let spec2 = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Max);
        let t0 = Instant::now();
        let fixed = scheduler::run_online(&env2, &spec2, 7, &Policy::fixed(), 4);
        let t_fixed = t0.elapsed();
        let t1 = Instant::now();
        let online = scheduler::run_online(&env2, &spec2, 7, &Policy { epsilon: 1.0, window: 8 }, 4);
        let t_online = t1.elapsed();
        println!(
            "{:40} {:>9.0} ms fixed   {:>7.0} ms online -> {:.0}% attempts, {:.0}% tokens saved",
            "scheduler::run_online (e=100%, w=8)",
            t_fixed.as_secs_f64() * 1e3,
            t_online.as_secs_f64() * 1e3,
            online.attempt_savings() * 100.0,
            online.token_savings_vs(&fixed.log) * 100.0
        );
    }

    // ---- interned EvalKey vs string-key lookup (ADR-005 acceptance: the
    // trace-replay hit path must not build strings per request) ----------
    {
        use std::collections::{BTreeMap, HashMap};
        use ucutlass_repro::eval::{EvalKey, EvalResponse};
        use ucutlass_repro::util::rng::{stream, StreamPath};
        let reqs: Vec<EvalRequest> = (0..problems.len())
            .flat_map(|p| {
                TILES.iter().enumerate().map(move |(i, &t)| {
                    EvalRequest::measured(
                        p,
                        CandidateConfig::library(t, dsl::DType::Fp16),
                        StreamPath::new(7, &[stream::MEASURE, p as u64, i as u64]),
                    )
                })
            })
            .collect();
        let responses = ev.eval_batch(&reqs);
        let smap: BTreeMap<String, EvalResponse> =
            reqs.iter().zip(&responses).map(|(r, v)| (r.key(), v.clone())).collect();
        let imap: HashMap<EvalKey, EvalResponse> =
            reqs.iter().zip(&responses).map(|(r, v)| (r.eval_key(), v.clone())).collect();
        let n = reqs.len();
        bench("eval lookup: String key() + BTreeMap (x590)", 200, 7, || {
            for r in &reqs {
                black_box(smap.get(&r.key()));
            }
        });
        bench("eval lookup: interned EvalKey + HashMap (x590)", 200, 7, || {
            for r in &reqs {
                black_box(imap.get(&r.eval_key()));
            }
        });
        assert_eq!(smap.len(), n, "string keys must be collision-free here");
        assert_eq!(imap.len(), n, "interned keys must be collision-free here");
    }

    // ---- single-pass sweep vs per-policy replay (ADR-005 headline) ------
    // One exhausted session pass + 72 offline StopRule grids, against the
    // pre-sweep cost of re-driving sessions per policy. Evaluator-call
    // counts come from a strict recorded-trace replay (TraceMonitor), the
    // exact `repro sweep --trace` scenario. The per-policy side times a
    // 6-policy sample and extrapolates ×12 (clearly labeled `est`).
    {
        use ucutlass_repro::eval::{OwnedAnalytic, RecordingEvaluator, TraceEvaluator};
        use ucutlass_repro::util::json::Json;
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
        let pipeline = IntegrityPipeline::default();
        let seed = 7u64;
        let trace_path = std::env::temp_dir()
            .join(format!("ucutlass_bench_sweep_{}.jsonl", std::process::id()));

        // record the exhausted pass once
        {
            let mut b = SuiteBench::new();
            let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &trace_path).unwrap();
            b.set_oracle(Box::new(rec));
            let env = b.env();
            let _ = scheduler::sweep_sessions(&env, &spec, seed, 1, &pipeline, seed);
        }

        // timed single-pass sweep, strictly from the trace
        let mut b = SuiteBench::new();
        let trace = TraceEvaluator::load(&trace_path).unwrap();
        let sweep_mon = trace.monitor();
        b.set_oracle(Box::new(trace));
        let env = b.env();
        let t0 = Instant::now();
        let run = scheduler::sweep_sessions(&env, &spec, seed, 1, &pipeline, seed);
        let t_sweep = t0.elapsed();
        assert_eq!(run.sweep.results.len(), 72);
        assert_eq!(sweep_mon.misses(), 0);
        let sweep_calls = sweep_mon.served();

        // timed per-policy sample on the same trace (policy run + fixed
        // reference per policy — what 72 × `repro replay schedule` cost)
        let sample: Vec<Policy> = scheduler::policy_grid().into_iter().step_by(12).collect();
        let mut b2 = SuiteBench::new();
        let trace2 = TraceEvaluator::load(&trace_path).unwrap();
        let pp_mon = trace2.monitor();
        b2.set_oracle(Box::new(trace2));
        let env2 = b2.env();
        let t1 = Instant::now();
        for p in &sample {
            black_box(scheduler::run_online(&env2, &spec, seed, p, 1));
            black_box(scheduler::run_online(&env2, &spec, seed, &Policy::fixed(), 1));
        }
        let t_sample = t1.elapsed();
        assert_eq!(pp_mon.misses(), 0);
        let scale = 72.0 / sample.len() as f64;
        let pp_ms_est = t_sample.as_secs_f64() * 1e3 * scale;
        let pp_calls_est = (pp_mon.served() as f64 * scale) as u64;
        println!(
            "{:40} {:>9.0} ms sweep   {:>7.0} ms est 72x per-policy -> {:.1}x; \
             eval calls {} vs est {}",
            "scheduler::sweep_sessions (72 policies)",
            t_sweep.as_secs_f64() * 1e3,
            pp_ms_est,
            pp_ms_est / (t_sweep.as_secs_f64() * 1e3).max(1e-9),
            sweep_calls,
            pp_calls_est,
        );

        // machine-readable perf trajectory (BENCH_sweep.json next to
        // Cargo.toml; re-run `cargo bench` to refresh)
        let mut j = Json::obj();
        j.set("bench", "sweep_vs_per_policy")
            .set("variant", spec.label())
            .set("policies", 72u64)
            .set("sweep_ms", t_sweep.as_secs_f64() * 1e3)
            .set("per_policy_sample", sample.len() as u64)
            .set("per_policy_sample_ms", t_sample.as_secs_f64() * 1e3)
            .set("per_policy_ms_est_72", pp_ms_est)
            .set("sweep_eval_calls", sweep_calls)
            .set("per_policy_eval_calls_est_72", pp_calls_est)
            .set(
                "speedup_est",
                pp_ms_est / (t_sweep.as_secs_f64() * 1e3).max(1e-9),
            );
        match std::fs::write("BENCH_sweep.json", j.to_string()) {
            Ok(()) => println!("(wrote BENCH_sweep.json)"),
            Err(e) => println!("(could not write BENCH_sweep.json: {e})"),
        }
        let _ = std::fs::remove_file(&trace_path);
    }

    // ---- binary eval store vs JSONL trace replay (ADR-008 headline) -----
    // Cold-open cost — the JSONL evaluator parses every line before the
    // first lookup, the store reads header + index + trailer only — and
    // the hit path serving the full suite enumeration from each artifact.
    {
        use ucutlass_repro::eval::{OwnedAnalytic, RecordingEvaluator, TraceEvaluator};
        use ucutlass_repro::store::{CacheMode, CachedEvaluator, EvalStore, StoreWriter};
        use ucutlass_repro::util::json::Json;
        use ucutlass_repro::util::rng::{stream, StreamPath};

        let dtypes = [dsl::DType::Fp32, dsl::DType::Fp16, dsl::DType::Bf16];
        let mut reqs: Vec<EvalRequest> = Vec::new();
        for p in 0..problems.len() {
            reqs.push(EvalRequest::baseline(p));
            reqs.push(EvalRequest::measured_baseline(
                p,
                StreamPath::new(12345, &[stream::MEASURE, stream::FLAT_CONTROLLER, p as u64, 0]),
            ));
            reqs.push(EvalRequest::sol_gap(p));
            for (i, &tile) in TILES.iter().enumerate() {
                for dt in dtypes {
                    let cfg = CandidateConfig::library(tile, dt);
                    reqs.push(EvalRequest::candidate(p, cfg.clone()));
                    reqs.push(
                        EvalRequest::candidate(p, cfg.clone()).with_hash(format!("{i:08x}")),
                    );
                    reqs.push(EvalRequest::measured(
                        p,
                        cfg,
                        StreamPath::new(12345, &[stream::MEASURE, p as u64, i as u64]),
                    ));
                }
            }
        }
        let n = reqs.len();

        let trace_path = std::env::temp_dir()
            .join(format!("ucutlass_bench_store_{}.jsonl", std::process::id()));
        let store_path = std::env::temp_dir()
            .join(format!("ucutlass_bench_store_{}.store", std::process::id()));

        // record both artifacts from one live pass
        let responses = {
            let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &trace_path).unwrap();
            let responses = rec.eval_batch(&reqs);
            drop(rec);
            let mut w = StoreWriter::create(&store_path).unwrap();
            for (r, v) in reqs.iter().zip(&responses) {
                w.append(r, v).unwrap();
            }
            w.finish().unwrap();
            responses
        };

        let t0 = Instant::now();
        let trace = TraceEvaluator::load(&trace_path).unwrap();
        let t_trace_open = t0.elapsed();
        let t1 = Instant::now();
        let store = EvalStore::open(&store_path).unwrap();
        let t_store_open = t1.elapsed();
        assert_eq!(store.len(), n, "enumeration keys must be distinct");
        let trace_bytes = std::fs::metadata(&trace_path).unwrap().len();
        let store_open_bytes = store.open_bytes();
        drop(store);

        // hit path: serve the whole enumeration from each artifact (the
        // store side is a cold CachedEvaluator — preads + decode, no
        // memory layer warm yet)
        let t2 = Instant::now();
        let from_trace = trace.eval_batch(&reqs);
        let t_trace_serve = t2.elapsed();
        assert_eq!(trace.monitor().misses(), 0);
        let cached = CachedEvaluator::open(&store_path, CacheMode::Offline).unwrap();
        let t3 = Instant::now();
        let from_store = cached.eval_batch(&reqs);
        let t_store_serve = t3.elapsed();
        assert_eq!(cached.monitor().misses(), 0);

        // bitwise contract spot-check before publishing numbers
        for ((want, a), b) in responses.iter().zip(&from_trace).zip(&from_store) {
            assert_eq!(a, want);
            assert_eq!(b, want);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }

        let open_ratio = t_trace_open.as_secs_f64() / t_store_open.as_secs_f64().max(1e-9);
        println!(
            "{:40} {:>9.2} ms jsonl   {:>7.2} ms store -> {:.0}x; bytes before first \
             lookup {} vs {}",
            format!("eval store cold open ({n} records)"),
            t_trace_open.as_secs_f64() * 1e3,
            t_store_open.as_secs_f64() * 1e3,
            open_ratio,
            trace_bytes,
            store_open_bytes,
        );
        println!(
            "{:40} {:>9.2} ms jsonl   {:>7.2} ms store (lookup + decode + checksum)",
            format!("eval store hit path (x{n})"),
            t_trace_serve.as_secs_f64() * 1e3,
            t_store_serve.as_secs_f64() * 1e3,
        );

        // machine-readable perf trajectory (BENCH_trace.json next to
        // Cargo.toml; re-run `cargo bench` to refresh)
        let mut j = Json::obj();
        j.set("bench", "eval_store_vs_jsonl_trace")
            .set("records", n as u64)
            .set("jsonl_bytes", trace_bytes)
            .set("jsonl_open_ms", t_trace_open.as_secs_f64() * 1e3)
            .set("jsonl_serve_ms", t_trace_serve.as_secs_f64() * 1e3)
            .set("store_bytes_read_at_open", store_open_bytes)
            .set("store_open_ms", t_store_open.as_secs_f64() * 1e3)
            .set("store_serve_ms", t_store_serve.as_secs_f64() * 1e3)
            .set("open_speedup", open_ratio)
            .set(
                "open_bytes_ratio",
                trace_bytes as f64 / store_open_bytes.max(1) as f64,
            );
        match std::fs::write("BENCH_trace.json", j.to_string()) {
            Ok(()) => println!("(wrote BENCH_trace.json)"),
            Err(e) => println!("(could not write BENCH_trace.json: {e})"),
        }
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&store_path);
    }

    // ---- static-analyzer pruning (ADR-009 headline) ---------------------
    // Twin full-suite sweeps at the same seed, prune-off vs prune-on: the
    // prune-on side must issue strictly fewer evaluator calls (each pruned
    // candidate is one measured trial that never reached the oracle), and
    // the integrity-filtered geomean speedup must be bitwise unchanged.
    {
        use std::sync::atomic::{AtomicU64, Ordering};
        use ucutlass_repro::agent::AttemptOutcome;
        use ucutlass_repro::eval::{EvalResponse, MeasureKind, OwnedAnalytic};
        use ucutlass_repro::util::json::Json;

        struct CountingOracle {
            inner: OwnedAnalytic,
            measured: AtomicU64,
            total: AtomicU64,
        }
        impl Evaluator for CountingOracle {
            fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
                self.total.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                let m = reqs
                    .iter()
                    .filter(|r| matches!(r.kind, MeasureKind::Measured))
                    .count();
                self.measured.fetch_add(m as u64, Ordering::Relaxed);
                self.inner.eval_batch(reqs)
            }
        }

        let seed = 7u64;
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
        let pipeline = IntegrityPipeline::default();
        let sweep = |spec: &VariantSpec| {
            let oracle = CountingOracle {
                inner: OwnedAnalytic::new(),
                measured: AtomicU64::new(0),
                total: AtomicU64::new(0),
            };
            let env =
                Env::new(&model, &problems, &sols, &compiled).with_oracle(Some(&oracle));
            let t0 = Instant::now();
            let runs: Vec<_> =
                (0..problems.len()).map(|i| run_problem(&env, spec, i, seed)).collect();
            let elapsed = t0.elapsed();
            let log = ucutlass_repro::agent::RunLog {
                variant: spec.label(),
                tier_name: spec.tier.name().into(),
                price_per_mtok: 1.25,
                runs,
            };
            (
                log,
                oracle.measured.load(Ordering::Relaxed),
                oracle.total.load(Ordering::Relaxed),
                elapsed,
            )
        };
        let (log_off, measured_off, total_off, t_off) = sweep(&spec);
        let (log_on, measured_on, total_on, t_on) = sweep(&spec.with_prune());
        let pruned: u64 = log_on
            .runs
            .iter()
            .flat_map(|r| &r.attempts)
            .filter(|a| matches!(a.outcome, AttemptOutcome::Pruned { .. }))
            .count() as u64;
        let g_off = pipeline.filtered_geomean(&log_off, seed);
        let g_on = pipeline.filtered_geomean(&log_on, seed);
        assert!(pruned > 0, "the suite sweep must exercise the prune gate");
        assert_eq!(
            measured_off - measured_on,
            pruned,
            "each pruned attempt must save exactly one measured trial"
        );
        assert!(total_on < total_off, "prune-on must issue strictly fewer evaluator calls");
        assert_eq!(
            g_off.to_bits(),
            g_on.to_bits(),
            "accepted-speedup geomean must be bitwise unchanged under pruning"
        );
        println!(
            "{:40} {:>9} calls off {:>7} calls on -> {} pruned ({:.1}% of measured), \
             geomean {:.4} bitwise-equal",
            "analyze::prune suite sweep (59 problems)",
            total_off,
            total_on,
            pruned,
            pruned as f64 / measured_off.max(1) as f64 * 100.0,
            g_on,
        );

        // machine-readable perf trajectory (BENCH_lint.json next to
        // Cargo.toml; re-run `cargo bench` to refresh)
        let mut j = Json::obj();
        j.set("bench", "analyzer_prune_sweep")
            .set("variant", spec.label())
            .set("problems", problems.len() as u64)
            .set("seed", seed)
            .set("evaluator_calls_off", total_off)
            .set("evaluator_calls_on", total_on)
            .set("measured_trials_off", measured_off)
            .set("measured_trials_on", measured_on)
            .set("pruned_attempts", pruned)
            .set("sweep_ms_off", t_off.as_secs_f64() * 1e3)
            .set("sweep_ms_on", t_on.as_secs_f64() * 1e3)
            .set("filtered_geomean", g_on)
            .set("geomean_bitwise_equal", g_off.to_bits() == g_on.to_bits());
        match std::fs::write("BENCH_lint.json", j.to_string()) {
            Ok(()) => println!("(wrote BENCH_lint.json)"),
            Err(e) => println!("(could not write BENCH_lint.json: {e})"),
        }
    }

    // ---- run-journal WAL (ADR-010) --------------------------------------
    // The durability tax a journaled run pays per landed shard: one framed
    // append with write + flush + sync_data, against the same append with
    // no journal at all (free). Plus the recovery side: scanning and
    // checksum-verifying the whole journal at resume.
    {
        use ucutlass_repro::journal::{scan_journal, JournalWriter};
        use ucutlass_repro::util::json::Json;

        let path = std::env::temp_dir()
            .join(format!("ucutlass_bench_journal_{}.journal", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // a shard-record-shaped payload: ~2 KB of JSON, the realistic
        // per-landed-shard frame a fleet coordinator writes
        let payload = {
            let mut o = Json::obj();
            o.set("kind", "shard").set("token", 0u64).set("index", 7u64).set(
                "shard",
                Json::Str("x".repeat(2000)),
            );
            o.to_string()
        };
        let appends = 400usize;
        let mut w = JournalWriter::create(&path).unwrap();
        let t0 = Instant::now();
        for _ in 0..appends {
            w.append(payload.as_bytes()).unwrap();
        }
        let t_append = t0.elapsed();
        drop(w);
        let t1 = Instant::now();
        let scan = scan_journal(&path).unwrap();
        let t_scan = t1.elapsed();
        assert_eq!(scan.records.len(), appends, "every appended frame must scan back");
        let append_us = t_append.as_secs_f64() * 1e6 / appends as f64;
        let scan_us = t_scan.as_secs_f64() * 1e6 / appends as f64;
        println!(
            "{:40} {:>9.0} us/append (fsync)  {:>7.1} us/record scan ({} x {} B)",
            "journal WAL append + recovery scan", append_us, scan_us, appends,
            payload.len(),
        );

        // machine-readable perf trajectory (BENCH_journal.json next to
        // Cargo.toml; re-run `cargo bench` to refresh)
        let mut j = Json::obj();
        j.set("bench", "run_journal_wal")
            .set("appends", appends as u64)
            .set("payload_bytes", payload.len() as u64)
            .set("append_us_fsync", append_us)
            .set("scan_us_per_record", scan_us)
            .set("journal_bytes", std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0));
        match std::fs::write("BENCH_journal.json", j.to_string()) {
            Ok(()) => println!("(wrote BENCH_journal.json)"),
            Err(e) => println!("(could not write BENCH_journal.json: {e})"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
