//! Out-of-process evaluation: JSON work manifests, response shards, and
//! deterministic merge (ADR-003; ROADMAP "shard `eval_variants` across
//! processes/machines").
//!
//! Two layers share one discipline — work is identified by a stable key,
//! shards are produced independently, and the merge re-emits results in
//! the single-process order, so `shard × N + merge` is bit-identical to
//! one process doing everything:
//!
//! * **Request level** — [`WorkManifest`] lists [`EvalRequest`]s;
//!   [`evaluate_shard`] answers the subset a worker owns (stable
//!   assignment by request-key hash); [`merge`] recombines shards into
//!   exactly `eval_batch(manifest.requests)`. [`ManifestEvaluator`] is the
//!   `Evaluator` face of this cycle: it records unanswered requests as
//!   pending work and serves answered ones from the merged responses.
//! * **Suite level** — [`SuiteWork`] names an `exec::eval_variants` job
//!   (variant specs + seed); [`suite_shard`] runs the session tasks whose
//!   rank falls in the worker's residue class, [`suite_merge`] reassembles
//!   the full [`RunLog`]s field-for-field identical to the single-process
//!   result (the CI golden test). Sequentially-coupled variants
//!   (orchestrated + cross-memory) stay whole-variant tasks, exactly as in
//!   the parallel engine (ADR-002).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Mutex;

use crate::agent::controller::VariantSpec;
use crate::agent::{ProblemRun, RunLog};
use crate::exec;
use crate::experiments::runner::Bench;
use crate::mantis::MantisConfig;
use crate::util::json::Json;

use super::{EvalKey, EvalRequest, EvalResponse, Evaluator};

// ===========================================================================
// Request-level protocol
// ===========================================================================

/// Manifest/shard wire-format version. Version 2 switched response keys
/// from canonical strings to interned 32-hex [`EvalKey`]s and shard
/// assignment from FNV-64-of-string to the interned key (ADR-005) —
/// version-1 artifacts (and mixed-version worker fleets, which would
/// compute a different partition) are rejected with a clear error
/// instead of a `bad response` parse failure or a silently skewed merge.
pub const MANIFEST_VERSION: u64 = 2;

/// A JSON-serializable list of pending evaluation requests.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkManifest {
    pub version: u64,
    pub requests: Vec<EvalRequest>,
}

impl WorkManifest {
    pub fn new(requests: Vec<EvalRequest>) -> WorkManifest {
        WorkManifest { version: MANIFEST_VERSION, requests }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", self.version)
            .set("requests", Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()));
        o
    }

    pub fn parse(text: &str) -> Result<WorkManifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "manifest: unsupported version {version} (this build reads version \
                 {MANIFEST_VERSION}; re-generate the manifest with this build)"
            ));
        }
        let requests = j
            .get("requests")
            .and_then(|r| r.as_arr())
            .ok_or("manifest: missing requests array")?
            .iter()
            .map(|r| EvalRequest::from_json(r).ok_or_else(|| format!("bad request: {r}")))
            .collect::<Result<Vec<_>, String>>()?;
        Ok(WorkManifest { version, requests })
    }
}

/// One worker's completed responses.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseShard {
    pub index: usize,
    pub of: usize,
    pub responses: Vec<EvalResponse>,
}

impl ResponseShard {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", MANIFEST_VERSION)
            .set("index", self.index)
            .set("of", self.of)
            .set("responses", Json::Arr(self.responses.iter().map(|r| r.to_json()).collect()));
        o
    }

    pub fn parse(text: &str) -> Result<ResponseShard, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "shard: unsupported version {version} (this build reads version \
                 {MANIFEST_VERSION}; re-evaluate the shard with this build)"
            ));
        }
        Ok(ResponseShard {
            index: j.get("index").and_then(|v| v.as_u64()).ok_or("shard: missing index")?
                as usize,
            of: j.get("of").and_then(|v| v.as_u64()).ok_or("shard: missing of")? as usize,
            responses: j
                .get("responses")
                .and_then(|r| r.as_arr())
                .ok_or("shard: missing responses")?
                .iter()
                .map(|r| EvalResponse::from_json(r).ok_or_else(|| format!("bad response: {r}")))
                .collect::<Result<Vec<_>, String>>()?,
        })
    }
}

/// Stable shard assignment: the interned request key mod `of` (ADR-005;
/// previously FNV-64 of the key string — the interned form hashes the
/// same canonical fields without building the string). Every worker
/// computes the same partition from the manifest alone — no coordinator
/// state.
pub fn shard_assignment(key: EvalKey, of: usize) -> usize {
    key.shard(of)
}

/// Evaluate the manifest subset assigned to shard `index` of `of`.
pub fn evaluate_shard<E: Evaluator>(
    inner: &E,
    manifest: &WorkManifest,
    index: usize,
    of: usize,
) -> ResponseShard {
    let assigned: Vec<EvalRequest> = manifest
        .requests
        .iter()
        .filter(|r| shard_assignment(r.eval_key(), of) == index)
        .cloned()
        .collect();
    ResponseShard { index, of, responses: inner.eval_batch(&assigned) }
}

/// Merge completed shards back into the single-process answer: one
/// response per manifest request, in manifest order (the output order is
/// the manifest's, so the interned-key map needs no sorting to stay
/// deterministic). Responses are deduplicated by interned key;
/// conflicting payloads for one key or missing keys are errors. For any
/// deterministic backend, `merge(manifest, shards) ==
/// inner.eval_batch(&manifest.requests)` exactly.
pub fn merge(
    manifest: &WorkManifest,
    shards: &[ResponseShard],
) -> Result<Vec<EvalResponse>, String> {
    let mut by_key: HashMap<EvalKey, EvalResponse> =
        HashMap::with_capacity(shards.iter().map(|s| s.responses.len()).sum());
    for s in shards {
        for r in &s.responses {
            match by_key.get(&r.key) {
                Some(prev) if *prev != *r => {
                    return Err(format!("conflicting responses for key {}", r.key));
                }
                _ => {
                    by_key.insert(r.key, r.clone());
                }
            }
        }
    }
    manifest
        .requests
        .iter()
        .map(|q| {
            by_key
                .get(&q.eval_key())
                .cloned()
                .ok_or_else(|| format!("missing response for key {}", q.key()))
        })
        .collect()
}

/// The out-of-process [`Evaluator`]: requests it cannot answer from its
/// merged-response store are recorded as pending work (answered in-band
/// with `pass == false`, detail `"pending"`), to be written out with
/// [`ManifestEvaluator::pending_manifest`], farmed to workers, merged, and
/// loaded back — after which the same call sites get real answers.
///
/// The pending list is a `Mutex` (not `RefCell`) so the evaluator is
/// `Send + Sync` and can be installed as a bench oracle and shared across
/// the execution pool's worker threads, like every other backend.
#[derive(Default)]
pub struct ManifestEvaluator {
    pending: Mutex<Vec<EvalRequest>>,
    completed: HashMap<EvalKey, EvalResponse>,
}

impl ManifestEvaluator {
    pub fn new() -> ManifestEvaluator {
        ManifestEvaluator::default()
    }

    /// Load merged responses (serving store) from a manifest + shards.
    pub fn with_responses(
        manifest: &WorkManifest,
        shards: &[ResponseShard],
    ) -> Result<ManifestEvaluator, String> {
        Ok(ManifestEvaluator {
            pending: Mutex::new(Vec::new()),
            completed: merged_by_key(manifest, shards)?,
        })
    }

    /// The pending work recorded so far, deduplicated by interned key in
    /// first-seen order.
    pub fn pending_manifest(&self) -> WorkManifest {
        let mut seen = HashSet::new();
        let reqs = self
            .pending
            .lock()
            .expect("pending-work lock")
            .iter()
            .filter(|r| seen.insert(r.eval_key()))
            .cloned()
            .collect();
        WorkManifest::new(reqs)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.lock().expect("pending-work lock").len()
    }
}

impl Evaluator for ManifestEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        reqs.iter()
            .map(|r| match self.completed.get(&r.eval_key()) {
                Some(resp) => resp.clone(),
                None => {
                    self.pending.lock().expect("pending-work lock").push(r.clone());
                    EvalResponse::error(r.eval_key(), "pending")
                }
            })
            .collect()
    }
}

/// [`merge`] folded into a by-key lookup store — the shared construction
/// behind both serving evaluators.
fn merged_by_key(
    manifest: &WorkManifest,
    shards: &[ResponseShard],
) -> Result<HashMap<EvalKey, EvalResponse>, String> {
    let merged = merge(manifest, shards)?;
    let mut by_key = HashMap::with_capacity(merged.len());
    for r in merged {
        by_key.insert(r.key, r);
    }
    Ok(by_key)
}

/// Read-only evaluator over an already-merged response set (no pending
/// recording): the pure replay face.
pub struct MergedEvaluator {
    by_key: HashMap<EvalKey, EvalResponse>,
}

impl MergedEvaluator {
    pub fn new(
        manifest: &WorkManifest,
        shards: &[ResponseShard],
    ) -> Result<MergedEvaluator, String> {
        Ok(MergedEvaluator { by_key: merged_by_key(manifest, shards)? })
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }
}

impl Evaluator for MergedEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        reqs.iter()
            .map(|r| match self.by_key.get(&r.eval_key()) {
                Some(resp) => resp.clone(),
                None => EvalResponse::error(r.eval_key(), "not in merged response set"),
            })
            .collect()
    }
}

// ===========================================================================
// Suite-level protocol (`repro shard` / `repro merge`)
// ===========================================================================

/// A suite evaluation job: what `exec::eval_variants` runs, serialized.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteWork {
    pub seed: u64,
    /// Suite size the job was defined against (guards shard/merge skew).
    pub problems: usize,
    pub work: Vec<(VariantSpec, Option<MantisConfig>)>,
}

impl SuiteWork {
    pub fn single(spec: VariantSpec, cfg: Option<MantisConfig>, seed: u64, problems: usize) -> SuiteWork {
        SuiteWork { seed, problems, work: vec![(spec, cfg)] }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seed", format!("{:x}", self.seed)).set("problems", self.problems).set(
            "work",
            Json::Arr(
                self.work
                    .iter()
                    .map(|(spec, cfg)| {
                        let mut w = Json::obj();
                        w.set("spec", spec.to_json())
                            .set("mantis", cfg.as_ref().map(|c| c.to_json()).unwrap_or(Json::Null));
                        w
                    })
                    .collect(),
            ),
        );
        o
    }

    pub fn from_json(j: &Json) -> Result<SuiteWork, String> {
        let seed = j
            .get("seed")
            .and_then(|s| s.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("suite work: missing seed")?;
        let problems = j
            .get("problems")
            .and_then(|p| p.as_u64())
            .ok_or("suite work: missing problems")? as usize;
        let work = j
            .get("work")
            .and_then(|w| w.as_arr())
            .ok_or("suite work: missing work array")?
            .iter()
            .map(|w| {
                let spec = VariantSpec::from_json(
                    w.get("spec").ok_or("work item: missing spec")?,
                )?;
                let cfg = match w.get("mantis") {
                    Some(Json::Null) | None => None,
                    Some(c) => Some(MantisConfig::from_json(c)?),
                };
                Ok((spec, cfg))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SuiteWork { seed, problems, work })
    }
}

/// One completed suite task: its key plus the resulting problem runs (one
/// for an independent task, the whole suite for a whole-variant task).
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteTaskResult {
    pub key: String,
    pub runs: Vec<ProblemRun>,
}

/// One worker's share of a suite job. Self-describing: carries the job so
/// `repro merge` needs nothing but shard files.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteShard {
    pub work: SuiteWork,
    pub index: usize,
    pub of: usize,
    pub results: Vec<SuiteTaskResult>,
}

impl SuiteShard {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("work", self.work.to_json()).set("index", self.index).set("of", self.of).set(
            "results",
            Json::Arr(
                self.results
                    .iter()
                    .map(|r| {
                        let mut t = Json::obj();
                        t.set("key", r.key.clone()).set(
                            "runs",
                            Json::Arr(r.runs.iter().map(|run| run.to_json()).collect()),
                        );
                        t
                    })
                    .collect(),
            ),
        );
        o
    }

    pub fn parse(text: &str) -> Result<SuiteShard, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let work = SuiteWork::from_json(j.get("work").ok_or("shard: missing work")?)?;
        let index =
            j.get("index").and_then(|v| v.as_u64()).ok_or("shard: missing index")? as usize;
        let of = j.get("of").and_then(|v| v.as_u64()).ok_or("shard: missing of")? as usize;
        // one plan cache across the whole shard: repeated configurations
        // reconstruct their KernelPlan once
        let mut plans = crate::dsl::PlanCache::new();
        let results = j
            .get("results")
            .and_then(|r| r.as_arr())
            .ok_or("shard: missing results")?
            .iter()
            .map(|t| {
                let key = t
                    .get("key")
                    .and_then(|k| k.as_str())
                    .ok_or("task result: missing key")?
                    .to_string();
                let runs = t
                    .get("runs")
                    .and_then(|r| r.as_arr())
                    .ok_or("task result: missing runs")?
                    .iter()
                    .map(|run| ProblemRun::from_json(run, &mut plans))
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(SuiteTaskResult { key, runs })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SuiteShard { work, index, of, results })
    }
}

/// Run shard `index` of `of`: the suite tasks whose rank (in the
/// deterministic `exec::suite_tasks` enumeration) falls in this worker's
/// residue class.
pub fn suite_shard(bench: &Bench, work: &SuiteWork, index: usize, of: usize) -> SuiteShard {
    assert_eq!(
        bench.problems.len(),
        work.problems,
        "suite size mismatch between job and bench"
    );
    let tasks = exec::suite_tasks(&work.work, work.problems);
    let results = tasks
        .iter()
        .enumerate()
        .filter(|(rank, _)| rank % of.max(1) == index)
        .map(|(_, t)| SuiteTaskResult {
            key: t.key(),
            runs: exec::run_suite_task(bench, &work.work, *t, work.seed),
        })
        .collect();
    SuiteShard { work: work.clone(), index, of, results }
}

/// Merge suite shards into the full per-variant [`RunLog`]s, in variant
/// order with runs in problem order — field-for-field identical to
/// `exec::eval_variants(bench, &work, seed, 1)` (the CI golden test).
pub fn suite_merge(shards: &[SuiteShard]) -> Result<Vec<RunLog>, String> {
    let first = shards.first().ok_or("no shards to merge")?;
    let work_json = first.work.to_json().to_string();
    let mut by_key: BTreeMap<String, Vec<ProblemRun>> = BTreeMap::new();
    for s in shards {
        if s.of != first.of {
            return Err(format!("shard count mismatch: {} vs {}", s.of, first.of));
        }
        if s.work.to_json().to_string() != work_json {
            return Err(format!("shard {} belongs to a different job", s.index));
        }
        for r in &s.results {
            if by_key.insert(r.key.clone(), r.runs.clone()).is_some() {
                return Err(format!("duplicate task {}", r.key));
            }
        }
    }
    let tasks = exec::suite_tasks(&first.work.work, first.work.problems);
    let mut logs = Vec::with_capacity(first.work.work.len());
    for (v, (spec, _)) in first.work.work.iter().enumerate() {
        let mut runs: Vec<ProblemRun> = Vec::new();
        for t in tasks.iter().filter(|t| t.variant == v) {
            let got = by_key
                .remove(&t.key())
                .ok_or_else(|| format!("missing task {} (incomplete shard set?)", t.key()))?;
            match t.problem {
                Some(_) => {
                    if got.len() != 1 {
                        return Err(format!("task {}: expected 1 run, got {}", t.key(), got.len()));
                    }
                    runs.extend(got);
                }
                None => runs = got,
            }
        }
        logs.push(exec::assemble_log(spec, runs));
    }
    if let Some(k) = by_key.keys().next() {
        return Err(format!("unexpected task {k} not in the job's task list"));
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::DType;
    use crate::eval::AnalyticEvaluator;
    use crate::perfmodel::CandidateConfig;
    use crate::util::rng::{stream, StreamPath};

    fn requests() -> Vec<EvalRequest> {
        let mut reqs = Vec::new();
        for p in [0usize, 2, 5, 9] {
            reqs.push(EvalRequest::baseline(p));
            for (i, &tile) in crate::agent::policy::TILES.iter().take(4).enumerate() {
                let cfg = CandidateConfig::library(tile, DType::Fp16);
                reqs.push(EvalRequest::candidate(p, cfg.clone()));
                reqs.push(EvalRequest::measured(
                    p,
                    cfg,
                    StreamPath::new(11, &[stream::MEASURE, p as u64, i as u64]),
                ));
            }
        }
        reqs
    }

    #[test]
    fn request_shard_merge_equals_single_batch() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let manifest = WorkManifest::new(requests());
        let single = ev.eval_batch(&manifest.requests);
        for n in [1usize, 2, 3, 5] {
            // roundtrip the manifest and every shard through JSON text
            let manifest2 =
                WorkManifest::parse(&manifest.to_json().to_string()).unwrap();
            assert_eq!(manifest2, manifest);
            let shards: Vec<ResponseShard> = (0..n)
                .map(|i| {
                    let s = evaluate_shard(&ev, &manifest2, i, n);
                    ResponseShard::parse(&s.to_json().to_string()).unwrap()
                })
                .collect();
            let merged = merge(&manifest2, &shards).unwrap();
            assert_eq!(merged, single, "{n} shards must merge to the single-process batch");
        }
    }

    #[test]
    fn merge_rejects_incomplete_and_conflicting_shards() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let manifest = WorkManifest::new(requests());
        let s0 = evaluate_shard(&ev, &manifest, 0, 2);
        let s1 = evaluate_shard(&ev, &manifest, 1, 2);
        assert!(merge(&manifest, &[s0.clone()]).is_err(), "missing shard must fail");
        let mut bad = s1.clone();
        bad.responses[0].value += 1.0;
        assert!(
            merge(&manifest, &[s0.clone(), s1, bad]).is_err(),
            "conflicting payloads must fail"
        );
    }

    #[test]
    fn manifest_evaluator_records_then_serves() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let reqs = requests();

        // phase 1: nothing known, everything pending
        let collector = ManifestEvaluator::new();
        let pending_responses = collector.eval_batch(&reqs);
        assert!(pending_responses.iter().all(|r| !r.pass));
        let manifest = collector.pending_manifest();
        assert_eq!(manifest.requests.len(), reqs.len());

        // phase 2: workers answer, merge, reload
        let shards: Vec<ResponseShard> =
            (0..3).map(|i| evaluate_shard(&ev, &manifest, i, 3)).collect();
        let served = ManifestEvaluator::with_responses(&manifest, &shards).unwrap();
        assert_eq!(served.eval_batch(&reqs), ev.eval_batch(&reqs));
        assert_eq!(served.pending_len(), 0);

        // the read-only replay face agrees too
        let merged = MergedEvaluator::new(&manifest, &shards).unwrap();
        assert_eq!(merged.eval_batch(&reqs), ev.eval_batch(&reqs));
    }

    #[test]
    fn manifest_and_shard_version_gates_reject_v1_artifacts() {
        // version-1 artifacts keyed by canonical strings (pre-ADR-005)
        // must be rejected with a version diagnostic, not a confusing
        // `bad response` error or a silently skewed shard partition
        let err = WorkManifest::parse(r#"{"version":1,"requests":[]}"#).unwrap_err();
        assert!(err.contains("version 1"), "got: {err}");
        let err = WorkManifest::parse(r#"{"requests":[]}"#).unwrap_err();
        assert!(err.contains("version"), "missing version field is version 1: {err}");
        let err =
            ResponseShard::parse(r#"{"index":0,"of":2,"responses":[]}"#).unwrap_err();
        assert!(err.contains("version"), "got: {err}");
        // current-version artifacts round-trip
        let m = WorkManifest::new(Vec::new());
        assert_eq!(m.version, MANIFEST_VERSION);
        assert_eq!(WorkManifest::parse(&m.to_json().to_string()).unwrap(), m);
        let s = ResponseShard { index: 1, of: 3, responses: Vec::new() };
        assert_eq!(ResponseShard::parse(&s.to_json().to_string()).unwrap(), s);
    }

    #[test]
    fn shard_assignment_is_stable_and_total() {
        let reqs = requests();
        for n in [1usize, 2, 7] {
            for r in &reqs {
                let a = shard_assignment(r.eval_key(), n);
                assert!(a < n);
                assert_eq!(a, shard_assignment(r.eval_key(), n), "stable");
            }
        }
    }
}
