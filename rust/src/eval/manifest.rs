//! Out-of-process evaluation: JSON work manifests, response shards, and
//! deterministic merge (ADR-003; ROADMAP "shard `eval_variants` across
//! processes/machines").
//!
//! Two layers share one discipline — work is identified by a stable key,
//! shards are produced independently, and the merge re-emits results in
//! the single-process order, so `shard × N + merge` is bit-identical to
//! one process doing everything:
//!
//! * **Request level** — [`WorkManifest`] lists [`EvalRequest`]s;
//!   [`evaluate_shard`] answers the subset a worker owns (stable
//!   assignment by request-key hash); [`merge`] recombines shards into
//!   exactly `eval_batch(manifest.requests)`. [`ManifestEvaluator`] is the
//!   `Evaluator` face of this cycle: it records unanswered requests as
//!   pending work and serves answered ones from the merged responses.
//! * **Suite level** — [`SuiteWork`] names an `exec::eval_variants` job
//!   (variant specs + seed); [`suite_shard`] runs the session tasks whose
//!   rank falls in the worker's residue class, [`suite_merge`] reassembles
//!   the full [`RunLog`]s field-for-field identical to the single-process
//!   result (the CI golden test). Sequentially-coupled variants
//!   (orchestrated + cross-memory) stay whole-variant tasks, exactly as in
//!   the parallel engine (ADR-002).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Mutex;

use crate::agent::controller::VariantSpec;
use crate::agent::{ProblemRun, RunLog};
use crate::exec;
use crate::experiments::runner::Bench;
use crate::mantis::MantisConfig;
use crate::util::json::Json;

use super::{EvalKey, EvalRequest, EvalResponse, Evaluator};

// ===========================================================================
// Request-level protocol
// ===========================================================================

/// Manifest/shard wire-format version. Version 2 switched response keys
/// from canonical strings to interned 32-hex [`EvalKey`]s and shard
/// assignment from FNV-64-of-string to the interned key (ADR-005) —
/// version-1 artifacts (and mixed-version worker fleets, which would
/// compute a different partition) are rejected with a clear error
/// instead of a `bad response` parse failure or a silently skewed merge.
pub const MANIFEST_VERSION: u64 = 2;

/// Upper bound on a serialized manifest/shard artifact this build will
/// parse. A full-suite shard is a few MB; 64 MiB is far above any
/// legitimate artifact while still rejecting a runaway (or hostile) input
/// before `Json::parse` materializes it. The fleet protocol derives its
/// line cap from this same bound (ADR-007), so "too big for the wire" and
/// "too big for the parser" are one limit.
pub const MAX_ARTIFACT_BYTES: usize = 64 << 20;

/// Shared guard for every `parse(text)` entry point in this module.
fn check_artifact_len(text: &str, what: &str) -> Result<(), String> {
    if text.len() > MAX_ARTIFACT_BYTES {
        return Err(format!(
            "{what}: artifact is {} bytes, over the {MAX_ARTIFACT_BYTES}-byte limit",
            text.len()
        ));
    }
    Ok(())
}

/// A JSON-serializable list of pending evaluation requests.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkManifest {
    pub version: u64,
    pub requests: Vec<EvalRequest>,
}

impl WorkManifest {
    pub fn new(requests: Vec<EvalRequest>) -> WorkManifest {
        WorkManifest { version: MANIFEST_VERSION, requests }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", self.version)
            .set("requests", Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()));
        o
    }

    pub fn parse(text: &str) -> Result<WorkManifest, String> {
        check_artifact_len(text, "manifest")?;
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "manifest: unsupported version {version} (this build reads version \
                 {MANIFEST_VERSION}; re-generate the manifest with this build)"
            ));
        }
        let requests = j
            .get("requests")
            .and_then(|r| r.as_arr())
            .ok_or("manifest: missing requests array")?
            .iter()
            .map(|r| EvalRequest::from_json(r).ok_or_else(|| format!("bad request: {r}")))
            .collect::<Result<Vec<_>, String>>()?;
        Ok(WorkManifest { version, requests })
    }
}

/// One worker's completed responses.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseShard {
    pub index: usize,
    pub of: usize,
    pub responses: Vec<EvalResponse>,
}

impl ResponseShard {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", MANIFEST_VERSION)
            .set("index", self.index)
            .set("of", self.of)
            .set("responses", Json::Arr(self.responses.iter().map(|r| r.to_json()).collect()));
        o
    }

    pub fn parse(text: &str) -> Result<ResponseShard, String> {
        check_artifact_len(text, "shard")?;
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "shard: unsupported version {version} (this build reads version \
                 {MANIFEST_VERSION}; re-evaluate the shard with this build)"
            ));
        }
        let index =
            j.get("index").and_then(|v| v.as_u64()).ok_or("shard: missing index")? as usize;
        let of = j.get("of").and_then(|v| v.as_u64()).ok_or("shard: missing of")? as usize;
        check_shard_shape(index, of)?;
        let responses = j
            .get("responses")
            .and_then(|r| r.as_arr())
            .ok_or("shard: missing responses")?
            .iter()
            .map(|r| EvalResponse::from_json(r).ok_or_else(|| format!("bad response: {r}")))
            .collect::<Result<Vec<_>, String>>()?;
        let mut seen = HashSet::with_capacity(responses.len());
        for r in &responses {
            if !seen.insert(r.key) {
                return Err(format!("shard: duplicate response key {}", r.key));
            }
        }
        Ok(ResponseShard { index, of, responses })
    }
}

/// Shape validation every shard parse shares: `index` must name one of
/// `of >= 1` shards. Out-of-range artifacts are hostile or corrupt — an
/// in-band error, never a skewed merge.
fn check_shard_shape(index: usize, of: usize) -> Result<(), String> {
    if of == 0 {
        return Err("shard: of must be >= 1".into());
    }
    if index >= of {
        return Err(format!("shard: index {index} out of range for of {of}"));
    }
    Ok(())
}

/// Stable shard assignment: the interned request key mod `of` (ADR-005;
/// previously FNV-64 of the key string — the interned form hashes the
/// same canonical fields without building the string). Every worker
/// computes the same partition from the manifest alone — no coordinator
/// state.
pub fn shard_assignment(key: EvalKey, of: usize) -> usize {
    key.shard(of)
}

/// Evaluate the manifest subset assigned to shard `index` of `of`.
/// Repeated manifest requests are answered once (first occurrence): the
/// emitted shard carries one response per key, matching the duplicate-key
/// rejection in [`ResponseShard::parse`] so a round-tripped shard is
/// always re-readable. [`merge`] serves duplicate requests from the one
/// stored response, so merged output is unaffected.
pub fn evaluate_shard<E: Evaluator>(
    inner: &E,
    manifest: &WorkManifest,
    index: usize,
    of: usize,
) -> ResponseShard {
    let mut seen = HashSet::new();
    let assigned: Vec<EvalRequest> = manifest
        .requests
        .iter()
        .filter(|r| shard_assignment(r.eval_key(), of) == index)
        .filter(|r| seen.insert(r.eval_key()))
        .cloned()
        .collect();
    ResponseShard { index, of, responses: inner.eval_batch(&assigned) }
}

/// Merge completed shards back into the single-process answer: one
/// response per manifest request, in manifest order (the output order is
/// the manifest's, so the interned-key map needs no sorting to stay
/// deterministic). Responses are deduplicated by interned key;
/// conflicting payloads for one key or missing keys are errors. For any
/// deterministic backend, `merge(manifest, shards) ==
/// inner.eval_batch(&manifest.requests)` exactly.
pub fn merge(
    manifest: &WorkManifest,
    shards: &[ResponseShard],
) -> Result<Vec<EvalResponse>, String> {
    let mut by_key: HashMap<EvalKey, EvalResponse> =
        HashMap::with_capacity(shards.iter().map(|s| s.responses.len()).sum());
    for s in shards {
        for r in &s.responses {
            match by_key.get(&r.key) {
                Some(prev) if *prev != *r => {
                    return Err(format!("conflicting responses for key {}", r.key));
                }
                _ => {
                    by_key.insert(r.key, r.clone());
                }
            }
        }
    }
    manifest
        .requests
        .iter()
        .map(|q| {
            by_key
                .get(&q.eval_key())
                .cloned()
                .ok_or_else(|| format!("missing response for key {}", q.key()))
        })
        .collect()
}

/// The out-of-process [`Evaluator`]: requests it cannot answer from its
/// merged-response store are recorded as pending work (answered in-band
/// with `pass == false`, detail `"pending"`), to be written out with
/// [`ManifestEvaluator::pending_manifest`], farmed to workers, merged, and
/// loaded back — after which the same call sites get real answers.
///
/// The pending list is a `Mutex` (not `RefCell`) so the evaluator is
/// `Send + Sync` and can be installed as a bench oracle and shared across
/// the execution pool's worker threads, like every other backend.
#[derive(Default)]
pub struct ManifestEvaluator {
    pending: Mutex<Vec<EvalRequest>>,
    completed: HashMap<EvalKey, EvalResponse>,
}

impl ManifestEvaluator {
    pub fn new() -> ManifestEvaluator {
        ManifestEvaluator::default()
    }

    /// Load merged responses (serving store) from a manifest + shards.
    pub fn with_responses(
        manifest: &WorkManifest,
        shards: &[ResponseShard],
    ) -> Result<ManifestEvaluator, String> {
        Ok(ManifestEvaluator {
            pending: Mutex::new(Vec::new()),
            completed: merged_by_key(manifest, shards)?,
        })
    }

    /// The pending work recorded so far, deduplicated by interned key in
    /// first-seen order.
    pub fn pending_manifest(&self) -> WorkManifest {
        let mut seen = HashSet::new();
        let reqs = self
            .pending
            .lock()
            .expect("pending-work lock")
            .iter()
            .filter(|r| seen.insert(r.eval_key()))
            .cloned()
            .collect();
        WorkManifest::new(reqs)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.lock().expect("pending-work lock").len()
    }
}

impl Evaluator for ManifestEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        reqs.iter()
            .map(|r| match self.completed.get(&r.eval_key()) {
                Some(resp) => resp.clone(),
                None => {
                    self.pending.lock().expect("pending-work lock").push(r.clone());
                    EvalResponse::error(r.eval_key(), "pending")
                }
            })
            .collect()
    }
}

/// [`merge`] folded into a by-key lookup store — the shared construction
/// behind both serving evaluators.
fn merged_by_key(
    manifest: &WorkManifest,
    shards: &[ResponseShard],
) -> Result<HashMap<EvalKey, EvalResponse>, String> {
    let merged = merge(manifest, shards)?;
    let mut by_key = HashMap::with_capacity(merged.len());
    for r in merged {
        by_key.insert(r.key, r);
    }
    Ok(by_key)
}

/// Read-only evaluator over an already-merged response set (no pending
/// recording): the pure replay face.
pub struct MergedEvaluator {
    by_key: HashMap<EvalKey, EvalResponse>,
}

impl MergedEvaluator {
    pub fn new(
        manifest: &WorkManifest,
        shards: &[ResponseShard],
    ) -> Result<MergedEvaluator, String> {
        Ok(MergedEvaluator { by_key: merged_by_key(manifest, shards)? })
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }
}

impl Evaluator for MergedEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        reqs.iter()
            .map(|r| match self.by_key.get(&r.eval_key()) {
                Some(resp) => resp.clone(),
                None => EvalResponse::error(r.eval_key(), "not in merged response set"),
            })
            .collect()
    }
}

// ===========================================================================
// Suite-level protocol (`repro shard` / `repro merge`)
// ===========================================================================

/// A suite evaluation job: what `exec::eval_variants` runs, serialized.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteWork {
    pub seed: u64,
    /// Suite size the job was defined against (guards shard/merge skew).
    pub problems: usize,
    pub work: Vec<(VariantSpec, Option<MantisConfig>)>,
}

impl SuiteWork {
    pub fn single(spec: VariantSpec, cfg: Option<MantisConfig>, seed: u64, problems: usize) -> SuiteWork {
        SuiteWork { seed, problems, work: vec![(spec, cfg)] }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seed", format!("{:x}", self.seed)).set("problems", self.problems).set(
            "work",
            Json::Arr(
                self.work
                    .iter()
                    .map(|(spec, cfg)| {
                        let mut w = Json::obj();
                        w.set("spec", spec.to_json())
                            .set("mantis", cfg.as_ref().map(|c| c.to_json()).unwrap_or(Json::Null));
                        w
                    })
                    .collect(),
            ),
        );
        o
    }

    pub fn from_json(j: &Json) -> Result<SuiteWork, String> {
        let seed = j
            .get("seed")
            .and_then(|s| s.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("suite work: missing seed")?;
        let problems = j
            .get("problems")
            .and_then(|p| p.as_u64())
            .ok_or("suite work: missing problems")? as usize;
        let work = j
            .get("work")
            .and_then(|w| w.as_arr())
            .ok_or("suite work: missing work array")?
            .iter()
            .map(|w| {
                let spec = VariantSpec::from_json(
                    w.get("spec").ok_or("work item: missing spec")?,
                )?;
                let cfg = match w.get("mantis") {
                    Some(Json::Null) | None => None,
                    Some(c) => Some(MantisConfig::from_json(c)?),
                };
                Ok((spec, cfg))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SuiteWork { seed, problems, work })
    }
}

/// One completed suite task: its key plus the resulting problem runs (one
/// for an independent task, the whole suite for a whole-variant task).
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteTaskResult {
    pub key: String,
    pub runs: Vec<ProblemRun>,
}

/// One worker's share of a suite job. Self-describing: carries the job so
/// `repro merge` needs nothing but shard files.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteShard {
    pub work: SuiteWork,
    pub index: usize,
    pub of: usize,
    pub results: Vec<SuiteTaskResult>,
}

impl SuiteShard {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", MANIFEST_VERSION)
            .set("work", self.work.to_json())
            .set("index", self.index)
            .set("of", self.of)
            .set(
            "results",
            Json::Arr(
                self.results
                    .iter()
                    .map(|r| {
                        let mut t = Json::obj();
                        t.set("key", r.key.clone()).set(
                            "runs",
                            Json::Arr(r.runs.iter().map(|run| run.to_json()).collect()),
                        );
                        t
                    })
                    .collect(),
            ),
        );
        o
    }

    pub fn parse(text: &str) -> Result<SuiteShard, String> {
        check_artifact_len(text, "shard")?;
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }

    /// Parse an already-decoded shard object — the form the fleet protocol
    /// embeds in `result` messages (ADR-007). Same gates as [`parse`]
    /// minus the text-length cap (the wire layer enforces its own).
    pub fn from_json(j: &Json) -> Result<SuiteShard, String> {
        // Suite shards were introduced unversioned; treat a missing field
        // as version 1 and reject it, same convention as WorkManifest —
        // a mixed-version fleet must fail loudly, not merge skewed work.
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(format!(
                "shard: unsupported version {version} (this build reads version \
                 {MANIFEST_VERSION}; re-run the shard with this build)"
            ));
        }
        let work = SuiteWork::from_json(j.get("work").ok_or("shard: missing work")?)?;
        let index =
            j.get("index").and_then(|v| v.as_u64()).ok_or("shard: missing index")? as usize;
        let of = j.get("of").and_then(|v| v.as_u64()).ok_or("shard: missing of")? as usize;
        check_shard_shape(index, of)?;
        // one plan cache across the whole shard: repeated configurations
        // reconstruct their KernelPlan once
        let mut plans = crate::dsl::PlanCache::new();
        let mut seen = HashSet::new();
        let results = j
            .get("results")
            .and_then(|r| r.as_arr())
            .ok_or("shard: missing results")?
            .iter()
            .map(|t| {
                let key = t
                    .get("key")
                    .and_then(|k| k.as_str())
                    .ok_or("task result: missing key")?
                    .to_string();
                if !seen.insert(key.clone()) {
                    return Err(format!("shard: duplicate task {key}"));
                }
                let runs = t
                    .get("runs")
                    .and_then(|r| r.as_arr())
                    .ok_or("task result: missing runs")?
                    .iter()
                    .map(|run| ProblemRun::from_json(run, &mut plans))
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(SuiteTaskResult { key, runs })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SuiteShard { work, index, of, results })
    }
}

/// Run shard `index` of `of`: the suite tasks whose rank (in the
/// deterministic `exec::suite_tasks` enumeration) falls in this worker's
/// residue class.
pub fn suite_shard(bench: &Bench, work: &SuiteWork, index: usize, of: usize) -> SuiteShard {
    assert_eq!(
        bench.problems.len(),
        work.problems,
        "suite size mismatch between job and bench"
    );
    let tasks = exec::suite_tasks(&work.work, work.problems);
    let results = tasks
        .iter()
        .enumerate()
        .filter(|(rank, _)| rank % of.max(1) == index)
        .map(|(_, t)| SuiteTaskResult {
            key: t.key(),
            runs: exec::run_suite_task(bench, &work.work, *t, work.seed),
        })
        .collect();
    SuiteShard { work: work.clone(), index, of, results }
}

/// Incremental suite merger: shards land one at a time (in any order, from
/// any worker) and the final logs are assembled once every shard index is
/// present. This is the state the fleet coordinator carries while workers
/// stream results in (ADR-007); [`suite_merge`] is the batch face over the
/// same code, so the fleet inherits the shard/merge golden property — its
/// output is whatever `suite_merge` of the same shards would produce,
/// which is field-for-field the single-process `eval_variants` result —
/// by construction rather than by a parallel implementation.
pub struct SuiteMerge {
    work: SuiteWork,
    work_json: String,
    of: usize,
    by_key: BTreeMap<String, Vec<ProblemRun>>,
    landed: HashSet<usize>,
}

impl SuiteMerge {
    /// Start a merge for `of >= 1` shards of `work`.
    pub fn new(work: &SuiteWork, of: usize) -> SuiteMerge {
        SuiteMerge {
            work: work.clone(),
            work_json: work.to_json().to_string(),
            of: of.max(1),
            by_key: BTreeMap::new(),
            landed: HashSet::new(),
        }
    }

    /// Has shard `index` already been merged? (The coordinator's duplicate
    /// filter: first completion wins, later copies are discarded.)
    pub fn landed(&self, index: usize) -> bool {
        self.landed.contains(&index)
    }

    /// Every shard index present?
    pub fn complete(&self) -> bool {
        self.landed.len() == self.of
    }

    /// Shard indices still outstanding, ascending.
    pub fn missing(&self) -> Vec<usize> {
        (0..self.of).filter(|i| !self.landed.contains(i)).collect()
    }

    /// Would [`SuiteMerge::add`] accept this shard? Same checks, same
    /// error strings, no mutation. This is the write-ahead seam the
    /// journaled coordinator needs (ADR-010): validate first, journal
    /// the shard durably, *then* merge — so a journal only ever holds
    /// shards its own replay will accept.
    pub fn check(&self, shard: &SuiteShard) -> Result<(), String> {
        if shard.of != self.of {
            return Err(format!("shard count mismatch: {} vs {}", shard.of, self.of));
        }
        check_shard_shape(shard.index, shard.of)?;
        if shard.work.to_json().to_string() != self.work_json {
            return Err(format!("shard {} belongs to a different job", shard.index));
        }
        if self.landed.contains(&shard.index) {
            return Err(format!("shard {} already merged", shard.index));
        }
        let mut in_shard: HashSet<&str> = HashSet::new();
        for r in &shard.results {
            if self.by_key.contains_key(&r.key) || !in_shard.insert(&r.key) {
                return Err(format!("duplicate task {}", r.key));
            }
        }
        Ok(())
    }

    /// Merge one shard; returns the number of task results it landed.
    /// Rejects shards from a different job, with a different shard count,
    /// already-merged indices, and duplicate task keys — all in-band.
    pub fn add(&mut self, shard: &SuiteShard) -> Result<usize, String> {
        self.check(shard)?;
        self.landed.insert(shard.index);
        for r in &shard.results {
            self.by_key.insert(r.key.clone(), r.runs.clone());
        }
        Ok(shard.results.len())
    }

    /// Assemble the full per-variant [`RunLog`]s, in variant order with
    /// runs in problem order. Missing or unexpected tasks are errors.
    pub fn finish(mut self) -> Result<Vec<RunLog>, String> {
        let tasks = exec::suite_tasks(&self.work.work, self.work.problems);
        let mut logs = Vec::with_capacity(self.work.work.len());
        for (v, (spec, _)) in self.work.work.iter().enumerate() {
            let mut runs: Vec<ProblemRun> = Vec::new();
            for t in tasks.iter().filter(|t| t.variant == v) {
                let got = self.by_key.remove(&t.key()).ok_or_else(|| {
                    format!("missing task {} (incomplete shard set?)", t.key())
                })?;
                match t.problem {
                    Some(_) => {
                        if got.len() != 1 {
                            return Err(format!(
                                "task {}: expected 1 run, got {}",
                                t.key(),
                                got.len()
                            ));
                        }
                        runs.extend(got);
                    }
                    None => runs = got,
                }
            }
            logs.push(exec::assemble_log(spec, runs));
        }
        if let Some(k) = self.by_key.keys().next() {
            return Err(format!("unexpected task {k} not in the job's task list"));
        }
        Ok(logs)
    }
}

/// Merge suite shards into the full per-variant [`RunLog`]s, in variant
/// order with runs in problem order — field-for-field identical to
/// `exec::eval_variants(bench, &work, seed, 1)` (the CI golden test).
/// Batch face of [`SuiteMerge`].
pub fn suite_merge(shards: &[SuiteShard]) -> Result<Vec<RunLog>, String> {
    let first = shards.first().ok_or("no shards to merge")?;
    let mut m = SuiteMerge::new(&first.work, first.of);
    for s in shards {
        m.add(s)?;
    }
    m.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::DType;
    use crate::eval::AnalyticEvaluator;
    use crate::perfmodel::CandidateConfig;
    use crate::util::rng::{stream, StreamPath};

    fn requests() -> Vec<EvalRequest> {
        let mut reqs = Vec::new();
        for p in [0usize, 2, 5, 9] {
            reqs.push(EvalRequest::baseline(p));
            for (i, &tile) in crate::agent::policy::TILES.iter().take(4).enumerate() {
                let cfg = CandidateConfig::library(tile, DType::Fp16);
                reqs.push(EvalRequest::candidate(p, cfg.clone()));
                reqs.push(EvalRequest::measured(
                    p,
                    cfg,
                    StreamPath::new(11, &[stream::MEASURE, p as u64, i as u64]),
                ));
            }
        }
        reqs
    }

    #[test]
    fn request_shard_merge_equals_single_batch() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let manifest = WorkManifest::new(requests());
        let single = ev.eval_batch(&manifest.requests);
        for n in [1usize, 2, 3, 5] {
            // roundtrip the manifest and every shard through JSON text
            let manifest2 =
                WorkManifest::parse(&manifest.to_json().to_string()).unwrap();
            assert_eq!(manifest2, manifest);
            let shards: Vec<ResponseShard> = (0..n)
                .map(|i| {
                    let s = evaluate_shard(&ev, &manifest2, i, n);
                    ResponseShard::parse(&s.to_json().to_string()).unwrap()
                })
                .collect();
            let merged = merge(&manifest2, &shards).unwrap();
            assert_eq!(merged, single, "{n} shards must merge to the single-process batch");
        }
    }

    #[test]
    fn merge_rejects_incomplete_and_conflicting_shards() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let manifest = WorkManifest::new(requests());
        let s0 = evaluate_shard(&ev, &manifest, 0, 2);
        let s1 = evaluate_shard(&ev, &manifest, 1, 2);
        assert!(merge(&manifest, &[s0.clone()]).is_err(), "missing shard must fail");
        let mut bad = s1.clone();
        bad.responses[0].value += 1.0;
        assert!(
            merge(&manifest, &[s0.clone(), s1, bad]).is_err(),
            "conflicting payloads must fail"
        );
    }

    #[test]
    fn manifest_evaluator_records_then_serves() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let reqs = requests();

        // phase 1: nothing known, everything pending
        let collector = ManifestEvaluator::new();
        let pending_responses = collector.eval_batch(&reqs);
        assert!(pending_responses.iter().all(|r| !r.pass));
        let manifest = collector.pending_manifest();
        assert_eq!(manifest.requests.len(), reqs.len());

        // phase 2: workers answer, merge, reload
        let shards: Vec<ResponseShard> =
            (0..3).map(|i| evaluate_shard(&ev, &manifest, i, 3)).collect();
        let served = ManifestEvaluator::with_responses(&manifest, &shards).unwrap();
        assert_eq!(served.eval_batch(&reqs), ev.eval_batch(&reqs));
        assert_eq!(served.pending_len(), 0);

        // the read-only replay face agrees too
        let merged = MergedEvaluator::new(&manifest, &shards).unwrap();
        assert_eq!(merged.eval_batch(&reqs), ev.eval_batch(&reqs));
    }

    #[test]
    fn manifest_and_shard_version_gates_reject_v1_artifacts() {
        // version-1 artifacts keyed by canonical strings (pre-ADR-005)
        // must be rejected with a version diagnostic, not a confusing
        // `bad response` error or a silently skewed shard partition
        let err = WorkManifest::parse(r#"{"version":1,"requests":[]}"#).unwrap_err();
        assert!(err.contains("version 1"), "got: {err}");
        let err = WorkManifest::parse(r#"{"requests":[]}"#).unwrap_err();
        assert!(err.contains("version"), "missing version field is version 1: {err}");
        let err =
            ResponseShard::parse(r#"{"index":0,"of":2,"responses":[]}"#).unwrap_err();
        assert!(err.contains("version"), "got: {err}");
        // current-version artifacts round-trip
        let m = WorkManifest::new(Vec::new());
        assert_eq!(m.version, MANIFEST_VERSION);
        assert_eq!(WorkManifest::parse(&m.to_json().to_string()).unwrap(), m);
        let s = ResponseShard { index: 1, of: 3, responses: Vec::new() };
        assert_eq!(ResponseShard::parse(&s.to_json().to_string()).unwrap(), s);
    }

    #[test]
    fn evaluate_shard_answers_duplicate_requests_once() {
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let mut reqs = requests();
        reqs.push(reqs[0].clone());
        reqs.push(reqs[3].clone());
        let manifest = WorkManifest::new(reqs.clone());
        let shards: Vec<ResponseShard> = (0..2)
            .map(|i| {
                let s = evaluate_shard(&ev, &manifest, i, 2);
                // one response per key → the shard re-parses cleanly
                ResponseShard::parse(&s.to_json().to_string()).unwrap()
            })
            .collect();
        // and the merge still answers every request, duplicates included
        let merged = merge(&manifest, &shards).unwrap();
        assert_eq!(merged, ev.eval_batch(&reqs));
    }

    #[test]
    fn response_shard_parse_rejects_bad_shape_and_duplicates() {
        let err = ResponseShard::parse(
            r#"{"version":2,"index":3,"of":2,"responses":[]}"#,
        )
        .unwrap_err();
        assert!(err.contains("out of range"), "got: {err}");
        let err =
            ResponseShard::parse(r#"{"version":2,"index":0,"of":0,"responses":[]}"#).unwrap_err();
        assert!(err.contains("of must be"), "got: {err}");
        // duplicate response keys are hostile/corrupt, not mergeable
        let bench = Bench::new();
        let ev =
            AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
        let manifest = WorkManifest::new(requests());
        let mut s = evaluate_shard(&ev, &manifest, 0, 1);
        s.responses.push(s.responses[0].clone());
        let err = ResponseShard::parse(&s.to_json().to_string()).unwrap_err();
        assert!(err.contains("duplicate response key"), "got: {err}");
    }

    #[test]
    fn incremental_suite_merge_is_order_independent() {
        use crate::agent::controller::{ControllerKind, VariantSpec};
        use crate::agent::ModelTier;
        let bench = Bench::new();
        let work = SuiteWork::single(
            VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini),
            None,
            7,
            bench.problems.len(),
        );
        let n = 3;
        let shards: Vec<SuiteShard> = (0..n).map(|i| suite_shard(&bench, &work, i, n)).collect();
        let batch = suite_merge(&shards).unwrap();
        // land the shards out of order, checking progress as they arrive
        let mut m = SuiteMerge::new(&work, n);
        assert_eq!(m.missing(), vec![0, 1, 2]);
        for &i in &[2usize, 0, 1] {
            assert!(!m.landed(i));
            m.add(&shards[i]).unwrap();
            assert!(m.landed(i));
        }
        assert!(m.complete());
        assert_eq!(m.finish().unwrap(), batch);
        // duplicate shard indices are rejected in-band
        let mut m = SuiteMerge::new(&work, n);
        m.add(&shards[0]).unwrap();
        let err = m.add(&shards[0]).unwrap_err();
        assert!(err.contains("already merged"), "got: {err}");
    }

    #[test]
    fn suite_shard_version_gate_rejects_unversioned_artifacts() {
        let bench = Bench::new();
        let work = SuiteWork::single(
            crate::agent::controller::VariantSpec::new(
                crate::agent::controller::ControllerKind::Mi,
                false,
                crate::agent::ModelTier::Mini,
            ),
            None,
            1,
            bench.problems.len(),
        );
        let shard = suite_shard(&bench, &work, 0, bench.problems.len());
        let mut j = shard.to_json();
        // current artifact round-trips …
        assert_eq!(SuiteShard::parse(&j.to_string()).unwrap(), shard);
        // … an unversioned (pre-fleet) artifact is version 1 and rejected
        if let Json::Obj(m) = &mut j {
            m.remove("version");
        }
        let err = SuiteShard::parse(&j.to_string()).unwrap_err();
        assert!(err.contains("version 1"), "got: {err}");
    }

    #[test]
    fn shard_assignment_is_stable_and_total() {
        let reqs = requests();
        for n in [1usize, 2, 7] {
            for r in &reqs {
                let a = shard_assignment(r.eval_key(), n);
                assert!(a < n);
                assert_eq!(a, shard_assignment(r.eval_key(), n), "stable");
            }
        }
    }
}
