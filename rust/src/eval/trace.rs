//! Recorded-trace evaluation: record every measurement of a real run into
//! a JSONL artifact, then replay experiments offline from it (ADR-004).
//!
//! The paper's efficiency results come from re-running budgeting/steering
//! policies over the *same* measurements; persisting the responses of one
//! real run makes every later experiment an offline lookup instead of a
//! re-evaluation. Two backends implement the cycle on top of the
//! [`Evaluator`] API (ADR-003):
//!
//! * [`RecordingEvaluator`] wraps any inner backend and appends each
//!   `(EvalRequest, EvalResponse)` pair — deduplicated by the canonical
//!   [`EvalRequest::key`] — to the trace file as it evaluates;
//! * [`TraceEvaluator`] loads a trace and serves responses by key, with a
//!   [`MissPolicy`]: `Strict` answers misses with an in-band error
//!   response (provable replay — nothing outside the trace is consulted),
//!   `Fallthrough` delegates misses to a live backend and extends the
//!   trace (incremental re-runs).
//!
//! Trace format: line 1 is the header `{"trace":"ucutlass-eval",
//! "version":2}`; every further line is `{"req":…,"resp":…}` using the
//! exact `EvalRequest`/`EvalResponse` JSON of ADR-003 (u64 seeds and
//! stream components as hex strings, response keys as 32-hex interned
//! [`EvalKey`]s since version 2 (ADR-005), floats in shortest-roundtrip
//! form, so replayed values are bit-identical to the recorded ones). Keys
//! are stable across processes and job counts: measurement noise is named
//! by the request's derived [`crate::util::rng::StreamPath`], never by
//! in-process draw order, which is what makes a trace recorded at
//! `--jobs 4` replayable at `--jobs 1` and vice versa.
//!
//! The serving path is allocation-free per request (ADR-005): lookups go
//! through `HashMap<EvalKey, EvalResponse>` with keys computed by the
//! zero-allocation [`EvalRequest::eval_key`], and a hit clones a response
//! whose `detail` is a shared `Arc<str>` — no `String` is built anywhere
//! on the hit path. String keys appear only in miss diagnostics.
//!
//! Both backends expose a shared [`TraceMonitor`] handle so the caller
//! that boxed them into a [`Bench`](crate::experiments::Bench) oracle can
//! still ask, after the run, whether recording hit an I/O error or replay
//! hit a miss — the `Evaluator` contract itself never panics and never
//! returns out-of-band errors.

use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::kernelbench::{suite, Problem};
use crate::perfmodel::{CompiledCostModel, PerfModel};
use crate::sol::{analyze, GpuSpec, SolAnalysis, H100_SXM};
use crate::util::json::Json;

use super::{AnalyticEvaluator, DynEvaluator, EvalKey, EvalRequest, EvalResponse, Evaluator};

/// Trace format version (the header line's `version` field). Version 2
/// switched response keys from canonical strings to interned 32-hex
/// [`EvalKey`]s (ADR-005); version-1 traces must be re-recorded.
pub const TRACE_VERSION: u64 = 2;

// ===========================================================================
// Owned analytic backend
// ===========================================================================

/// The analytic oracle as one owned value (model + problems + SOL
/// analyses, compiled costs). [`AnalyticEvaluator`] is four borrows into a
/// [`Bench`](crate::experiments::Bench); an oracle boxed *into* a `Bench`
/// cannot borrow the bench that holds it, so the recording/fallthrough
/// backends own this standalone copy instead.
///
/// `new()` mirrors `Bench::new()` exactly (same `H100_SXM`, same
/// deterministic suite), so its answers are bit-identical to a default
/// bench's analytic path. A bench built on a different GPU
/// (`Bench::on`) must install an oracle built with [`OwnedAnalytic::on`]
/// for the **same** `GpuSpec` — otherwise the recorded responses
/// silently come from the wrong hardware model.
pub struct OwnedAnalytic {
    model: PerfModel,
    problems: Vec<Problem>,
    sols: Vec<SolAnalysis>,
    /// Per-problem compiled costs, lowered once at construction (ADR-006).
    compiled: CompiledCostModel,
}

impl OwnedAnalytic {
    pub fn new() -> OwnedAnalytic {
        Self::on(H100_SXM.clone())
    }

    pub fn on(gpu: GpuSpec) -> OwnedAnalytic {
        let problems = suite();
        let sols = problems.iter().map(|p| analyze(p, &gpu)).collect();
        let model = PerfModel::new(gpu);
        let compiled = CompiledCostModel::compile(&model, &problems);
        OwnedAnalytic { model, problems, sols, compiled }
    }
}

impl Default for OwnedAnalytic {
    fn default() -> Self {
        Self::new()
    }
}

impl Evaluator for OwnedAnalytic {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        AnalyticEvaluator::new(&self.model, &self.problems, &self.sols, &self.compiled)
            .eval_batch(reqs)
    }
}

// ===========================================================================
// Monitor
// ===========================================================================

#[derive(Debug, Default)]
struct MonitorState {
    path: String,
    /// Responses served from the loaded trace.
    served: u64,
    /// Unique pairs appended to the trace (recording or fallthrough).
    recorded: u64,
    /// Requests a `Strict` trace could not answer.
    misses: u64,
    first_miss: Option<String>,
    io_error: Option<String>,
}

/// Shared post-run status of a recording/replaying backend. The backend is
/// usually boxed into a bench as `Box<DynEvaluator>`, so the caller keeps
/// this handle to inspect the outcome after the run — the in-band
/// complement to the `Evaluator` contract's "never panic" rule.
#[derive(Clone, Default)]
pub struct TraceMonitor(Arc<Mutex<MonitorState>>);

impl TraceMonitor {
    fn with_path(path: &Path) -> TraceMonitor {
        let m = TraceMonitor::default();
        m.lock().path = path.display().to_string();
        m
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MonitorState> {
        self.0.lock().expect("trace monitor lock")
    }

    pub fn served(&self) -> u64 {
        self.lock().served
    }

    pub fn recorded(&self) -> u64 {
        self.lock().recorded
    }

    pub fn misses(&self) -> u64 {
        self.lock().misses
    }

    pub fn first_miss(&self) -> Option<String> {
        self.lock().first_miss.clone()
    }

    pub fn io_error(&self) -> Option<String> {
        self.lock().io_error.clone()
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        let s = self.lock();
        format!(
            "trace {}: {} served, {} recorded, {} miss(es)",
            s.path, s.served, s.recorded, s.misses
        )
    }

    /// In-band verdict after a traced run: recording I/O failures and
    /// strict-replay misses become `Err` (the CLI maps this to a nonzero
    /// exit code).
    pub fn check(&self) -> Result<(), String> {
        let s = self.lock();
        if let Some(e) = &s.io_error {
            return Err(format!("trace {}: {e}", s.path));
        }
        if s.misses > 0 {
            return Err(format!(
                "trace {}: {} request(s) missing (first: {}) — the trace does not cover \
                 this run; re-record it, or replay with --live to fall through to the \
                 analytic backend and extend the trace",
                s.path,
                s.misses,
                s.first_miss.as_deref().unwrap_or("?"),
            ));
        }
        Ok(())
    }
}

// ===========================================================================
// Recording
// ===========================================================================

/// One canonical trace line. `pub(crate)` so the binary store's
/// `repro cache export` bridge (`store::export_jsonl`) emits bytes a
/// recorder would have — the two writers cannot drift apart.
pub(crate) fn pair_to_line(req: &EvalRequest, resp: &EvalResponse) -> String {
    let mut o = Json::obj();
    o.set("req", req.to_json()).set("resp", resp.to_json());
    o.to_string()
}

pub(crate) fn header_line() -> String {
    let mut o = Json::obj();
    o.set("trace", "ucutlass-eval").set("version", TRACE_VERSION);
    o.to_string()
}

/// Write one line per pair; returns (lines written, first I/O error).
/// Shared by the recording sink and the fallthrough appender so their
/// write bookkeeping cannot drift apart.
fn write_pair_lines<W: Write>(
    out: &mut W,
    pairs: &[(&EvalRequest, &EvalResponse)],
) -> (u64, Option<String>) {
    let mut wrote = 0u64;
    for &(req, resp) in pairs {
        if let Err(e) = writeln!(out, "{}", pair_to_line(req, resp)) {
            return (wrote, Some(e.to_string()));
        }
        wrote += 1;
    }
    (wrote, None)
}

/// Fold one append's outcome into the monitor (first error wins).
fn record_outcome(monitor: &TraceMonitor, wrote: u64, io_error: Option<String>) {
    let mut s = monitor.lock();
    s.recorded += wrote;
    if s.io_error.is_none() {
        s.io_error = io_error;
    }
}

/// Explicit-flush cadence: bounds data loss on a crash without paying a
/// flush syscall per batch (the agent hot loop records one line per
/// scalar evaluation). `BufWriter` still flushes itself when its buffer
/// fills; the final flush happens on [`RecordingEvaluator`]'s `Drop`,
/// where errors are recorded in the monitor rather than swallowed.
const FLUSH_EVERY_LINES: u32 = 512;

struct Sink {
    /// Opened lazily on the first recorded batch, so a traced command
    /// that fails argument validation before evaluating anything leaves
    /// an existing trace file untouched.
    out: Option<BufWriter<File>>,
    path: std::path::PathBuf,
    /// Interned-key dedup set: membership costs no string building.
    seen: HashSet<EvalKey>,
    unflushed: u32,
}

impl Sink {
    /// Create-and-truncate the file + write the header on first use.
    fn ensure_open(&mut self) -> Result<&mut BufWriter<File>, String> {
        if self.out.is_none() {
            let file = File::create(&self.path)
                .map_err(|e| format!("cannot create: {e}"))?;
            let mut out = BufWriter::new(file);
            writeln!(out, "{}", header_line())
                .map_err(|e| format!("cannot write header: {e}"))?;
            self.out = Some(out);
        }
        Ok(self.out.as_mut().expect("just opened"))
    }

    /// Append the deduplicated pairs; I/O failures land in the monitor
    /// (responses still flow — a broken disk must not corrupt the run).
    fn append(&mut self, pairs: &[(&EvalRequest, &EvalResponse)], monitor: &TraceMonitor) {
        let fresh: Vec<(&EvalRequest, &EvalResponse)> = pairs
            .iter()
            .copied()
            .filter(|(req, _)| self.seen.insert(req.eval_key()))
            .collect();
        if fresh.is_empty() {
            return;
        }
        let (wrote, mut io_error) = match self.ensure_open() {
            Err(e) => (0, Some(e)),
            Ok(out) => write_pair_lines(out, &fresh),
        };
        self.unflushed += wrote as u32;
        if io_error.is_none() && self.unflushed >= FLUSH_EVERY_LINES {
            io_error = self.flush().err();
        }
        record_outcome(monitor, wrote, io_error);
    }

    fn flush(&mut self) -> Result<(), String> {
        self.unflushed = 0;
        match &mut self.out {
            None => Ok(()),
            Some(out) => out.flush().map_err(|e| e.to_string()),
        }
    }
}

/// Wraps any backend and appends every `(request, response)` pair it
/// answers to a JSONL trace, deduplicated by the canonical request key.
/// Transparent: responses are returned unmodified, so a recorded run is
/// field-for-field identical to the same run without the recorder.
///
/// The trace file is created (truncating any previous one) on the
/// **first recorded batch**, and fully flushed when the recorder is
/// dropped — load a recorded trace only after dropping the recorder (or
/// after [`RecordingEvaluator::flush`]).
pub struct RecordingEvaluator<E> {
    inner: E,
    sink: Mutex<Sink>,
    monitor: TraceMonitor,
}

impl<E: Evaluator> RecordingEvaluator<E> {
    /// Start recording to `path`. The file itself is created lazily (see
    /// the type docs); creation failures surface through the monitor.
    pub fn create(inner: E, path: impl AsRef<Path>) -> Result<RecordingEvaluator<E>, String> {
        let path = path.as_ref();
        Ok(RecordingEvaluator {
            inner,
            sink: Mutex::new(Sink {
                out: None,
                path: path.to_path_buf(),
                seen: HashSet::new(),
                unflushed: 0,
            }),
            monitor: TraceMonitor::with_path(path),
        })
    }

    /// Flush buffered trace lines to disk now (also happens on `Drop`).
    pub fn flush(&self) -> Result<(), String> {
        self.sink.lock().expect("trace sink lock").flush()
    }

    /// Shared status handle (keep it before boxing the recorder away).
    pub fn monitor(&self) -> TraceMonitor {
        self.monitor.clone()
    }
}

impl<E> Drop for RecordingEvaluator<E> {
    fn drop(&mut self) {
        // final flush; unlike BufWriter's own Drop, errors are recorded
        // in-band so the CLI's post-run check still reports them
        if let Ok(mut sink) = self.sink.lock() {
            if let Err(e) = sink.flush() {
                let mut s = self.monitor.lock();
                if s.io_error.is_none() {
                    s.io_error = Some(e);
                }
            }
        }
    }
}

impl<E: Evaluator> Evaluator for RecordingEvaluator<E> {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        let resps = self.inner.eval_batch(reqs);
        let pairs: Vec<(&EvalRequest, &EvalResponse)> = reqs.iter().zip(&resps).collect();
        self.sink.lock().expect("trace sink lock").append(&pairs, &self.monitor);
        resps
    }
}

// ===========================================================================
// Replay
// ===========================================================================

/// What a [`TraceEvaluator`] does with a request its trace cannot answer.
pub enum MissPolicy {
    /// Answer in-band with `pass == false` and count the miss: the replay
    /// provably consulted nothing but the trace.
    Strict,
    /// Delegate to a live backend and append its answer to the trace, so
    /// an incrementally changed run only pays for the new measurements.
    ///
    /// Extending a JSONL trace re-parses the whole file on open (the
    /// serving map and the appender's dedup set are both rebuilt from a
    /// full `parse_trace` pass). That is inherent to the line format;
    /// when extension cost matters, use the binary store instead
    /// (`store::CachedEvaluator` in write-through mode), whose
    /// `StoreWriter::extend` seeds dedup and offsets from the store's
    /// index footer without re-reading a single record payload.
    Fallthrough(Box<DynEvaluator>),
}

/// Serves responses from a loaded trace by interned request key
/// ([`EvalKey`]): the hit path builds no strings and performs no heap
/// allocations per request (ADR-005).
pub struct TraceEvaluator {
    by_key: HashMap<EvalKey, EvalResponse>,
    /// Responses added by `Fallthrough` after load (kept apart so `by_key`
    /// stays lock-free on the hot serving path; `Strict` replay never
    /// takes this lock at all).
    extra: Mutex<HashMap<EvalKey, EvalResponse>>,
    policy: MissPolicy,
    /// Open appender when the policy extends the trace.
    appender: Option<Mutex<BufWriter<File>>>,
    monitor: TraceMonitor,
}

impl TraceEvaluator {
    /// Load a trace for strict replay.
    pub fn load(path: impl AsRef<Path>) -> Result<TraceEvaluator, String> {
        Self::load_with(path, MissPolicy::Strict)
    }

    /// Load a trace with an explicit miss policy.
    pub fn load_with(
        path: impl AsRef<Path>,
        policy: MissPolicy,
    ) -> Result<TraceEvaluator, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("trace {}: {e}", path.display()))?;
        let by_key = parse_trace(&text, &path.display().to_string())?;
        let appender = match &policy {
            MissPolicy::Strict => None,
            MissPolicy::Fallthrough(_) => {
                let file = OpenOptions::new()
                    .append(true)
                    .open(path)
                    .map_err(|e| format!("trace {}: cannot append: {e}", path.display()))?;
                Some(Mutex::new(BufWriter::new(file)))
            }
        };
        Ok(TraceEvaluator {
            by_key,
            extra: Mutex::new(HashMap::new()),
            policy,
            appender,
            monitor: TraceMonitor::with_path(path),
        })
    }

    /// Distinct request keys the loaded trace answers (before extension).
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Shared status handle (keep it before boxing the evaluator away).
    pub fn monitor(&self) -> TraceMonitor {
        self.monitor.clone()
    }
}

/// Parse trace text into deduplicated `(request, response)` pairs in
/// file order, with full validation (version gate, per-line JSON, key
/// match, conflicting-duplicate rejection). Every malformed line is an
/// in-band error naming its 1-based line number. An identical duplicate
/// line is skipped (first occurrence wins), so the pair list holds each
/// key exactly once — which is what `store::import_jsonl` relies on to
/// rebuild a binary store deterministically.
pub(crate) fn parse_trace_pairs(
    text: &str,
    origin: &str,
) -> Result<Vec<(EvalRequest, EvalResponse)>, String> {
    let lines = text.as_bytes().iter().filter(|&&b| b == b'\n').count() + 1;
    let mut by_key: HashMap<EvalKey, EvalResponse> = HashMap::with_capacity(lines);
    let mut pairs = Vec::with_capacity(lines);
    for (idx, raw) in text.lines().enumerate() {
        let n = idx + 1;
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| format!("trace {origin}: line {n}: corrupt trace line ({e})"))?;
        if j.get("trace").is_some() {
            let version = j.get("version").and_then(|v| v.as_u64());
            if version != Some(TRACE_VERSION) {
                return Err(format!(
                    "trace {origin}: line {n}: unsupported trace version {version:?} \
                     (this build reads version {TRACE_VERSION})"
                ));
            }
            continue;
        }
        let req = j
            .get("req")
            .and_then(EvalRequest::from_json)
            .ok_or_else(|| format!("trace {origin}: line {n}: malformed request"))?;
        let resp = j
            .get("resp")
            .and_then(EvalResponse::from_json)
            .ok_or_else(|| format!("trace {origin}: line {n}: malformed response"))?;
        let key = req.eval_key();
        if resp.key != key {
            return Err(format!(
                "trace {origin}: line {n}: response key `{}` does not match its request \
                 key `{key}` ({})",
                resp.key,
                req.key()
            ));
        }
        if let Some(prev) = by_key.get(&key) {
            if *prev != resp {
                return Err(format!(
                    "trace {origin}: line {n}: conflicting responses for key {} ({})",
                    key,
                    req.key()
                ));
            }
            continue; // identical duplicate: first occurrence wins
        }
        by_key.insert(key, resp.clone());
        pairs.push((req, resp));
    }
    Ok(pairs)
}

/// Parse trace text into the serving map (the replay path keeps only
/// responses; the pair form above preserves requests and order for the
/// binary-store bridge).
fn parse_trace(text: &str, origin: &str) -> Result<HashMap<EvalKey, EvalResponse>, String> {
    let pairs = parse_trace_pairs(text, origin)?;
    let mut by_key = HashMap::with_capacity(pairs.len());
    for (req, resp) in pairs {
        by_key.insert(req.eval_key(), resp);
    }
    Ok(by_key)
}

impl Evaluator for TraceEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        // Interned-key lookups only: no string is built for a hit, and the
        // clone that materializes the owned response at the output
        // boundary is allocation-free (`detail` is a shared Arc).
        let keys: Vec<EvalKey> = reqs.iter().map(|r| r.eval_key()).collect();
        let mut out: Vec<Option<EvalResponse>> = match &self.policy {
            // strict replay never extends, so `extra` is always empty —
            // skip its lock entirely on the hot path
            MissPolicy::Strict => keys.iter().map(|k| self.by_key.get(k).cloned()).collect(),
            MissPolicy::Fallthrough(_) => {
                let extra = self.extra.lock().expect("trace extra lock");
                keys.iter()
                    .map(|k| self.by_key.get(k).or_else(|| extra.get(k)).cloned())
                    .collect()
            }
        };
        let hits = out.iter().filter(|o| o.is_some()).count() as u64;
        self.monitor.lock().served += hits;

        let missed: Vec<usize> =
            (0..reqs.len()).filter(|&i| out[i].is_none()).collect();
        if missed.is_empty() {
            return out.into_iter().map(|o| o.expect("all hits")).collect();
        }

        match &self.policy {
            MissPolicy::Strict => {
                let mut s = self.monitor.lock();
                for &i in &missed {
                    s.misses += 1;
                    if s.first_miss.is_none() {
                        // diagnostics are the one place the string key
                        // survives (the miss path is cold by definition)
                        s.first_miss = Some(reqs[i].key());
                    }
                }
                drop(s);
                for &i in &missed {
                    out[i] = Some(EvalResponse::error(
                        keys[i],
                        format!("trace miss: {}", reqs[i].key()),
                    ));
                }
            }
            MissPolicy::Fallthrough(inner) => {
                let sub: Vec<EvalRequest> = missed.iter().map(|&i| reqs[i].clone()).collect();
                let answers = inner.eval_batch(&sub);
                let mut extra = self.extra.lock().expect("trace extra lock");
                let mut fresh: Vec<(&EvalRequest, &EvalResponse)> = Vec::new();
                for (&i, resp) in missed.iter().zip(&answers) {
                    if !extra.contains_key(&keys[i]) && !self.by_key.contains_key(&keys[i]) {
                        fresh.push((&reqs[i], resp));
                        extra.insert(keys[i], resp.clone());
                    }
                    out[i] = Some(resp.clone());
                }
                drop(extra);
                if let Some(appender) = &self.appender {
                    // extension is the exception path (misses are rare on
                    // an incremental re-run), so flush immediately for
                    // durability rather than on a cadence
                    let mut w = appender.lock().expect("trace appender lock");
                    let (wrote, mut io_error) = write_pair_lines(&mut *w, &fresh);
                    if io_error.is_none() {
                        io_error = w.flush().err().map(|e| e.to_string());
                    }
                    record_outcome(&self.monitor, wrote, io_error);
                }
            }
        }
        out.into_iter().map(|o| o.expect("all filled")).collect()
    }
}

// ===========================================================================
// CLI plumbing
// ===========================================================================

/// How a `repro record` / `repro replay` invocation uses the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// Evaluate live (analytic backend) and record everything.
    Record,
    /// Serve strictly from the trace; misses are in-band errors and fail
    /// the command after the run.
    ReplayStrict,
    /// Serve from the trace, falling through to the analytic backend on
    /// misses and extending the trace.
    ReplayExtend,
}

/// Build the boxed oracle + status handle for one traced CLI run.
pub fn trace_session(
    mode: TraceMode,
    path: impl AsRef<Path>,
) -> Result<(Box<DynEvaluator>, TraceMonitor), String> {
    match mode {
        TraceMode::Record => {
            let rec = RecordingEvaluator::create(OwnedAnalytic::new(), path)?;
            let monitor = rec.monitor();
            Ok((Box::new(rec), monitor))
        }
        TraceMode::ReplayStrict => {
            let trace = TraceEvaluator::load(path)?;
            let monitor = trace.monitor();
            Ok((Box::new(trace), monitor))
        }
        TraceMode::ReplayExtend => {
            let trace = TraceEvaluator::load_with(
                path,
                MissPolicy::Fallthrough(Box::new(OwnedAnalytic::new())),
            )?;
            let monitor = trace.monitor();
            Ok((Box::new(trace), monitor))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::DType;
    use crate::perfmodel::CandidateConfig;
    use crate::util::rng::{stream, StreamPath};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ucutlass_{name}_{}.jsonl", std::process::id()))
    }

    fn requests() -> Vec<EvalRequest> {
        let mut reqs = Vec::new();
        for p in [0usize, 2, 7] {
            reqs.push(EvalRequest::baseline(p));
            reqs.push(EvalRequest::sol_gap(p));
            for (i, &tile) in crate::agent::policy::TILES.iter().take(3).enumerate() {
                let cfg = CandidateConfig::library(tile, DType::Fp16);
                reqs.push(EvalRequest::candidate(p, cfg.clone()));
                reqs.push(EvalRequest::measured(
                    p,
                    cfg,
                    StreamPath::new(0xFFEE_DDCC_BBAA_9988, &[stream::MEASURE, p as u64, i as u64]),
                ));
            }
        }
        reqs
    }

    #[test]
    fn record_then_replay_serves_identical_responses() {
        let path = tmp("roundtrip");
        let live = OwnedAnalytic::new();
        let reqs = requests();
        let reference = live.eval_batch(&reqs);

        let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
        let mon = rec.monitor();
        // recording is transparent, including across repeated batches
        assert_eq!(rec.eval_batch(&reqs), reference);
        assert_eq!(rec.eval_batch(&reqs), reference);
        assert_eq!(mon.recorded() as usize, reqs.len(), "dedup by key, not by call");
        drop(rec); // final flush happens on drop
        assert!(mon.io_error().is_none());

        let trace = TraceEvaluator::load(&path).unwrap();
        assert_eq!(trace.len(), reqs.len());
        let replayed = trace.eval_batch(&reqs);
        assert_eq!(replayed, reference, "replayed responses must be bit-identical");
        assert_eq!(trace.monitor().misses(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn strict_miss_is_an_in_band_error_not_a_panic() {
        let path = tmp("strict_miss");
        let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
        rec.eval_batch(&[EvalRequest::baseline(0)]);
        drop(rec);

        let trace = TraceEvaluator::load(&path).unwrap();
        let mon = trace.monitor();
        let unknown = EvalRequest::baseline(33);
        let resp = trace.eval(&unknown);
        assert!(!resp.pass);
        assert!(resp.detail.as_deref().unwrap_or("").contains("trace miss"));
        assert_eq!(mon.misses(), 1);
        assert_eq!(mon.first_miss().as_deref(), Some(unknown.key().as_str()));
        assert!(mon.check().is_err(), "strict replay with misses must fail the run check");
        // hits still serve
        assert!(trace.eval(&EvalRequest::baseline(0)).pass);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fallthrough_answers_live_and_extends_the_trace() {
        let path = tmp("fallthrough");
        let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
        let reqs = requests();
        rec.eval_batch(&reqs[..4]);
        drop(rec);

        let live = OwnedAnalytic::new();
        let reference = live.eval_batch(&reqs);
        let trace = TraceEvaluator::load_with(
            &path,
            MissPolicy::Fallthrough(Box::new(OwnedAnalytic::new())),
        )
        .unwrap();
        let mon = trace.monitor();
        assert_eq!(trace.eval_batch(&reqs), reference);
        assert_eq!(mon.misses(), 0, "fallthrough answers are not misses");
        assert_eq!(mon.recorded() as usize, reqs.len() - 4);
        assert!(mon.check().is_ok());
        drop(trace);

        // the extended trace now covers everything strictly
        let strict = TraceEvaluator::load(&path).unwrap();
        assert_eq!(strict.len(), reqs.len());
        assert_eq!(strict.eval_batch(&reqs), reference);
        assert_eq!(strict.monitor().misses(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupted_and_truncated_lines_report_their_line_number() {
        let path = tmp("corrupt");
        let good = {
            let live = OwnedAnalytic::new();
            let req = EvalRequest::baseline(1);
            let resp = live.eval(&req);
            pair_to_line(&req, &resp)
        };
        // line 3 is truncated mid-object (a partially-flushed record)
        let text = format!("{}\n{good}\n{}\n", header_line(), &good[..good.len() / 2]);
        std::fs::write(&path, text).unwrap();
        let err = TraceEvaluator::load(&path).unwrap_err();
        assert!(err.contains("line 3"), "got: {err}");
        assert!(err.contains("corrupt"), "got: {err}");

        // valid JSON that is not a (req, resp) pair is named too
        std::fs::write(&path, format!("{}\n{{\"x\":1}}\n", header_line())).unwrap();
        let err = TraceEvaluator::load(&path).unwrap_err();
        assert!(err.contains("line 2") && err.contains("malformed request"), "got: {err}");

        // a response stored under the wrong request key is an error, not a
        // silently-wrong replay
        let req = EvalRequest::baseline(1);
        let mut resp = OwnedAnalytic::new().eval(&req);
        resp.key = EvalRequest::baseline(2).eval_key();
        std::fs::write(&path, format!("{}\n{}\n", header_line(), pair_to_line(&req, &resp)))
            .unwrap();
        let err = TraceEvaluator::load(&path).unwrap_err();
        assert!(err.contains("does not match"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unsupported_version_and_missing_file_error_in_band() {
        let path = tmp("version");
        std::fs::write(&path, "{\"trace\":\"ucutlass-eval\",\"version\":99}\n").unwrap();
        let err = TraceEvaluator::load(&path).unwrap_err();
        assert!(err.contains("version"), "got: {err}");
        let _ = std::fs::remove_file(&path);

        assert!(TraceEvaluator::load("definitely-missing-trace.jsonl").is_err());
    }

    #[test]
    fn conflicting_duplicate_keys_are_rejected() {
        let path = tmp("conflict");
        let live = OwnedAnalytic::new();
        let req = EvalRequest::baseline(1);
        let resp = live.eval(&req);
        let mut other = resp.clone();
        other.value += 1.0;
        std::fs::write(
            &path,
            format!(
                "{}\n{}\n{}\n",
                header_line(),
                pair_to_line(&req, &resp),
                pair_to_line(&req, &other)
            ),
        )
        .unwrap();
        let err = TraceEvaluator::load(&path).unwrap_err();
        assert!(err.contains("conflicting"), "got: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_session_modes_construct() {
        let path = tmp("session");
        {
            let (oracle, mon) = trace_session(TraceMode::Record, &path).unwrap();
            oracle.eval_batch(&[EvalRequest::baseline(0)]);
            assert_eq!(mon.recorded(), 1);
            assert!(mon.check().is_ok());
        }
        {
            let (oracle, mon) = trace_session(TraceMode::ReplayStrict, &path).unwrap();
            assert!(oracle.eval(&EvalRequest::baseline(0)).pass);
            assert!(!oracle.eval(&EvalRequest::baseline(1)).pass);
            assert!(mon.check().is_err());
        }
        {
            let (oracle, mon) = trace_session(TraceMode::ReplayExtend, &path).unwrap();
            assert!(oracle.eval(&EvalRequest::baseline(1)).pass);
            assert!(mon.check().is_ok());
        }
        let _ = std::fs::remove_file(&path);
    }
}
