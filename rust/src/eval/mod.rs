//! The unified evaluation backend API (ADR-003).
//!
//! Every layer above the DSL used to call concrete measurement structs
//! directly — `PerfModel` for analytic timing, `Runtime` for PJRT
//! execution — one candidate at a time from five different places. This
//! module makes the measurement oracle a pluggable component behind one
//! trait:
//!
//! * [`Evaluator`] — `eval_batch(&[EvalRequest]) -> Vec<EvalResponse>`,
//!   with scalar [`Evaluator::eval`] as a default method;
//! * [`EvalRequest`] / [`EvalResponse`] — serializable units carrying the
//!   problem id, the `KernelPlan` config hash (or a canonical config
//!   fingerprint for raw candidates), the seed-stream path of the
//!   measurement noise, and the measurement kind;
//! * [`AnalyticEvaluator`] — wraps [`PerfModel`] plus a per-problem
//!   [`CompiledCostModel`] cache (ADR-006): candidate batches run through
//!   pre-lowered branch-free evaluators over struct-of-arrays
//!   [`ConfigBatch`]es, bit-identical to the scalar model;
//! * [`PjrtEvaluator`] — wraps the PJRT [`Runtime`] behind the existing
//!   `pjrt` feature gate (numeric validation of candidate configs against
//!   their AOT artifacts);
//! * [`manifest::ManifestEvaluator`] — the out-of-process backend: records
//!   pending requests into a JSON work manifest and serves responses
//!   merged back from completed shards (`repro shard` / `repro merge`);
//! * [`trace::RecordingEvaluator`] / [`trace::TraceEvaluator`] — the
//!   record/replay backends (ADR-004): persist every `(request, response)`
//!   pair of a real run to a JSONL trace and replay experiments offline
//!   from it (`repro record` / `repro replay`);
//! * `store::CachedEvaluator` (ADR-008, in the sibling [`crate::store`]
//!   module) — the persistent cross-run face: a binary content-addressed
//!   store layered memory → disk → live backend with write-through
//!   (`repro … --cache PATH`), bridging losslessly to the JSONL trace
//!   via `repro cache export`/`import`.
//!
//! Requests are *identities*, not closures: the measurement noise of a
//! `Measured` request comes from the derived RNG stream its
//! [`StreamPath`] names, so replaying a serialized request in another
//! process reproduces the in-process value bit-for-bit — the property the
//! shard/merge protocol, the recorded-trace backend, and their golden
//! tests rest on.
//!
//! Request identity has two faces (ADR-005): the canonical *string key*
//! ([`EvalRequest::key`]) for humans and diagnostics, and the interned
//! [`EvalKey`] — a process-stable 128-bit FNV-1a digest over the same
//! canonical fields, computed with zero heap allocations — that every
//! serving store (`TraceEvaluator`, `ManifestEvaluator`, shard
//! assignment, recorder dedup) actually indexes by. Two requests have
//! equal `EvalKey`s exactly when their string keys are equal (a
//! consistency test pins it over the full suite enumeration).

pub mod manifest;
pub mod trace;

pub use manifest::{ManifestEvaluator, MergedEvaluator, ResponseShard, WorkManifest};
pub use trace::{
    MissPolicy, OwnedAnalytic, RecordingEvaluator, TraceEvaluator, TraceMode, TraceMonitor,
};

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::kernelbench::Problem;
use crate::perfmodel::{
    measurement_noise, CandidateConfig, CompiledCostModel, ConfigBatch, PerfModel,
};
use crate::runtime::Runtime;
use crate::sol::SolAnalysis;
use crate::util::json::Json;
use crate::util::rng::StreamPath;
use crate::util::Fnv128;

/// What a request asks the backend to produce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeasureKind {
    /// The problem's library (PyTorch-eager) reference time. Noiseless
    /// without a stream; the measured baseline when a stream is present.
    Baseline,
    /// A candidate config's modeled runtime, noiseless (the policy /
    /// Nominate estimation path).
    Candidate,
    /// A candidate config's runtime with measurement noise drawn from the
    /// request's stream (the profile-an-attempt path).
    Measured,
    /// Speed-of-light headroom: baseline (or candidate, when a config is
    /// present) over the FP16-augmented SOL bound — dimensionless.
    SolGap,
}

impl MeasureKind {
    pub fn name(&self) -> &'static str {
        match self {
            MeasureKind::Baseline => "baseline",
            MeasureKind::Candidate => "candidate",
            MeasureKind::Measured => "measured",
            MeasureKind::SolGap => "sol_gap",
        }
    }

    pub fn parse(s: &str) -> Option<MeasureKind> {
        match s {
            "baseline" => Some(MeasureKind::Baseline),
            "candidate" => Some(MeasureKind::Candidate),
            "measured" => Some(MeasureKind::Measured),
            "sol_gap" => Some(MeasureKind::SolGap),
            _ => None,
        }
    }
}

/// Interned request identity (ADR-005): a deterministic, process-stable
/// FNV-1a 128 digest over the request's canonical fields, computed with
/// zero heap allocations. This is what the hot serving paths key by —
/// `HashMap<EvalKey, _>` lookups instead of building 3–5 `String`s per
/// request and probing a `BTreeMap<String, _>`. The string form
/// ([`EvalRequest::key`]) remains authoritative for humans: JSON traces
/// still carry full requests, and diagnostics print string keys.
///
/// Stability guarantee: the digest depends only on the canonical field
/// byte encoding (little-endian integers, length-prefixed names, f64
/// bits) and the published FNV constants — never on `std::hash`
/// randomization or build layout — so keys recorded by one process serve
/// lookups in any other.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EvalKey(pub u128);

impl EvalKey {
    /// 32-hex-digit form — the JSON wire format of response keys, and the
    /// only place the interned key is ever turned into a string.
    pub fn to_hex(self) -> String {
        format!("{:032x}", self.0)
    }

    pub fn parse_hex(s: &str) -> Option<EvalKey> {
        if s.len() != 32 {
            return None;
        }
        u128::from_str_radix(s, 16).ok().map(EvalKey)
    }

    /// Stable shard assignment (replaces FNV-64 over the string key):
    /// every worker computes the same partition from the key alone.
    pub fn shard(self, of: usize) -> usize {
        (self.0 as u64 % of.max(1) as u64) as usize
    }
}

impl fmt::Debug for EvalKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "EvalKey({:032x})", self.0)
    }
}

impl fmt::Display for EvalKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// One evaluation request: a serializable identity, not a closure.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalRequest {
    /// Index of the problem in the suite.
    pub problem: usize,
    pub kind: MeasureKind,
    /// The candidate config (required for Candidate/Measured).
    pub config: Option<CandidateConfig>,
    /// `KernelPlan::config_hash` when the config came from a compiled DSL
    /// plan; raw candidates fall back to [`CandidateConfig::fingerprint`]
    /// in the request key.
    pub config_hash: Option<String>,
    /// Seed-stream path of the measurement noise (Baseline/Measured).
    pub stream: Option<StreamPath>,
}

impl EvalRequest {
    /// Noiseless library baseline.
    pub fn baseline(problem: usize) -> EvalRequest {
        EvalRequest { problem, kind: MeasureKind::Baseline, config: None, config_hash: None, stream: None }
    }

    /// Baseline with measurement noise from `at`.
    pub fn measured_baseline(problem: usize, at: StreamPath) -> EvalRequest {
        EvalRequest {
            problem,
            kind: MeasureKind::Baseline,
            config: None,
            config_hash: None,
            stream: Some(at),
        }
    }

    /// Noiseless candidate estimate.
    pub fn candidate(problem: usize, config: CandidateConfig) -> EvalRequest {
        EvalRequest {
            problem,
            kind: MeasureKind::Candidate,
            config: Some(config),
            config_hash: None,
            stream: None,
        }
    }

    /// Candidate measurement with noise from `at`.
    pub fn measured(problem: usize, config: CandidateConfig, at: StreamPath) -> EvalRequest {
        EvalRequest {
            problem,
            kind: MeasureKind::Measured,
            config: Some(config),
            config_hash: None,
            stream: Some(at),
        }
    }

    /// SOL headroom of the baseline (no config) for a problem.
    pub fn sol_gap(problem: usize) -> EvalRequest {
        EvalRequest { problem, kind: MeasureKind::SolGap, config: None, config_hash: None, stream: None }
    }

    /// Attach the compiled plan's config hash (DSL-derived candidates).
    pub fn with_hash(mut self, hash: impl Into<String>) -> EvalRequest {
        self.config_hash = Some(hash.into());
        self
    }

    /// Interned identity (ADR-005): the allocation-free digest every
    /// serving store indexes by. Hashes exactly the fields the string
    /// [`EvalRequest::key`] serializes, in the same order, so
    /// `a.eval_key() == b.eval_key()` iff `a.key() == b.key()` (pinned by
    /// a consistency test over the full suite enumeration; the one
    /// theoretical exception is NaN-valued config floats, which share a
    /// string form but not a bit pattern — no real request carries NaN).
    pub fn eval_key(&self) -> EvalKey {
        let mut h = Fnv128::new();
        h.write_u64(self.problem as u64);
        h.write_str(self.kind.name());
        match &self.config {
            None => {
                h.write_u8(0);
            }
            Some(c) => {
                // the same canonical fields `CandidateConfig::fingerprint`
                // serializes, hashed directly (no intermediate string)
                h.write_u8(1);
                h.write_u64(c.tile.0).write_u64(c.tile.1).write_u64(c.tile.2);
                h.write_str(c.compute_dtype.name());
                h.write_u8(c.tensor_cores as u8);
                h.write_u8(c.fused_epilogue as u8);
                h.write_f64(c.fusion_coverage);
                h.write_str(c.scheduler.name());
                h.write_u64(c.stages);
                h.write_f64(c.quality);
            }
        }
        match &self.config_hash {
            None => {
                h.write_u8(0);
            }
            Some(s) => {
                h.write_u8(1);
                h.write_str(s);
            }
        }
        match &self.stream {
            None => {
                h.write_u8(0);
            }
            Some(s) => {
                h.write_u8(1);
                h.write_u64(s.seed);
                h.write_u64(s.path.len() as u64);
                for &c in &s.path {
                    h.write_u64(c);
                }
            }
        }
        EvalKey(h.finish())
    }

    /// Stable request key, human-readable string form: the identity the
    /// shard/merge protocol orders and matches responses by. Two requests
    /// with equal keys are the same measurement and receive byte-identical
    /// responses from any deterministic backend. The config fingerprint is
    /// always part of the key when a config is present — a plan's
    /// `config_hash` alone would under-identify measured configs, which
    /// carry integration-level fields (fusion coverage, quality) the DSL
    /// plan does not express. Hot paths use the interned [`EvalKey`] form
    /// ([`EvalRequest::eval_key`]); this string survives in diagnostics
    /// and trace-miss reports only.
    pub fn key(&self) -> String {
        let cfg = match (&self.config_hash, &self.config) {
            (Some(h), Some(c)) => format!("{h}+{}", c.fingerprint()),
            (Some(h), None) => h.clone(),
            (None, Some(c)) => c.fingerprint(),
            (None, None) => "-".to_string(),
        };
        let stream = match &self.stream {
            Some(s) => {
                let comps: Vec<String> = s.path.iter().map(|c| format!("{c:x}")).collect();
                format!("s{:x}:{}", s.seed, comps.join("."))
            }
            None => "-".to_string(),
        };
        format!("p{:04}|{}|{}|{}", self.problem, self.kind.name(), cfg, stream)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("problem", self.problem)
            .set("kind", self.kind.name())
            .set("config", self.config.as_ref().map(|c| c.to_json()).unwrap_or(Json::Null))
            .set(
                "config_hash",
                self.config_hash.as_ref().map(|h| Json::Str(h.clone())).unwrap_or(Json::Null),
            )
            .set("stream", self.stream.as_ref().map(stream_to_json).unwrap_or(Json::Null));
        o
    }

    pub fn from_json(j: &Json) -> Option<EvalRequest> {
        Some(EvalRequest {
            problem: j.get("problem")?.as_u64()? as usize,
            kind: MeasureKind::parse(j.get("kind")?.as_str()?)?,
            config: match j.get("config") {
                Some(Json::Null) | None => None,
                Some(c) => Some(CandidateConfig::from_json(c)?),
            },
            config_hash: match j.get("config_hash") {
                Some(Json::Null) | None => None,
                Some(h) => Some(h.as_str()?.to_string()),
            },
            stream: match j.get("stream") {
                Some(Json::Null) | None => None,
                Some(s) => Some(stream_from_json(s)?),
            },
        })
    }
}

/// `u64` values (seeds, stream components) are serialized as hex strings:
/// JSON numbers are f64 and would silently lose bits above 2^53, which
/// would break exact out-of-process replay.
fn stream_to_json(s: &StreamPath) -> Json {
    let mut o = Json::obj();
    o.set("seed", format!("{:x}", s.seed)).set(
        "path",
        Json::Arr(s.path.iter().map(|c| Json::Str(format!("{c:x}"))).collect()),
    );
    o
}

fn stream_from_json(j: &Json) -> Option<StreamPath> {
    let seed = u64::from_str_radix(j.get("seed")?.as_str()?, 16).ok()?;
    let path = j
        .get("path")?
        .as_arr()?
        .iter()
        .map(|c| u64::from_str_radix(c.as_str()?, 16).ok())
        .collect::<Option<Vec<u64>>>()?;
    Some(StreamPath { seed, path })
}

/// One evaluation result.
///
/// Carries the *interned* request key (ADR-005) so the serving stores
/// never rebuild strings; `detail` is a shared `Arc<str>` so cloning a
/// stored response on the replay hit path performs zero heap allocations.
/// In JSON the key travels as its 32-hex-digit string form
/// ([`EvalKey::to_hex`]).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalResponse {
    /// The interned request key this answers ([`EvalRequest::eval_key`]).
    pub key: EvalKey,
    /// The measurement: milliseconds for Baseline/Candidate/Measured, a
    /// dimensionless ratio for SolGap, the max abs error for the PJRT
    /// backend. `0.0` on error.
    pub value: f64,
    /// Did the evaluation succeed (and, for PJRT, pass numeric
    /// validation)?
    pub pass: bool,
    /// Backend annotation: the selected AOT variant, an error message, …
    pub detail: Option<Arc<str>>,
}

impl EvalResponse {
    /// Callers pass the key they already computed for the request — no
    /// request is keyed twice in one batch (and never through the string
    /// path).
    pub fn ok(key: EvalKey, value: f64) -> EvalResponse {
        EvalResponse { key, value, pass: true, detail: None }
    }

    pub fn error(key: EvalKey, msg: impl Into<String>) -> EvalResponse {
        EvalResponse { key, value: 0.0, pass: false, detail: Some(Arc::from(msg.into())) }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("key", self.key.to_hex())
            .set("value", self.value)
            .set("pass", self.pass)
            .set(
                "detail",
                self.detail.as_ref().map(|d| Json::Str(d.to_string())).unwrap_or(Json::Null),
            );
        o
    }

    pub fn from_json(j: &Json) -> Option<EvalResponse> {
        Some(EvalResponse {
            key: EvalKey::parse_hex(j.get("key")?.as_str()?)?,
            value: j.get("value")?.as_f64()?,
            pass: j.get("pass")?.as_bool()?,
            detail: match j.get("detail") {
                Some(Json::Null) | None => None,
                Some(d) => Some(Arc::from(d.as_str()?)),
            },
        })
    }
}

/// The pluggable measurement oracle. Implementations must be
/// deterministic per request: equal requests yield equal responses,
/// regardless of batch composition — that is what makes shard/merge
/// bit-identical to a single-process run.
pub trait Evaluator {
    /// Evaluate a batch. `out.len() == reqs.len()`; `out[i]` answers
    /// `reqs[i]`. Errors are in-band (`pass == false`), never panics.
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse>;

    /// Scalar convenience: a one-element batch.
    fn eval(&self, req: &EvalRequest) -> EvalResponse {
        self.eval_batch(std::slice::from_ref(req))
            .pop()
            .expect("eval_batch returns one response per request")
    }
}

/// The boxable/shareable evaluator type: every backend the execution
/// engine can carry across its worker threads (a `Bench` oracle is one
/// `Box<DynEvaluator>`; every `Env` borrows it as `&DynEvaluator`).
pub type DynEvaluator = dyn Evaluator + Send + Sync;

/// The measurement oracle agent call sites actually hold: the analytic
/// fast path plus an optional backend override. With no override, scalar
/// [`Oracle::value`] calls take [`AnalyticEvaluator::value`] — no key
/// strings, no response vectors (the `run_attempt` hot loop). With an
/// override (record/replay, ADR-004; a manifest store, ADR-003), *every*
/// evaluation — scalar and batched — routes through the backend, which is
/// what lets a strict trace replay prove nothing was computed live.
#[derive(Clone, Copy)]
pub struct Oracle<'a> {
    analytic: AnalyticEvaluator<'a>,
    backend: Option<&'a DynEvaluator>,
}

impl<'a> Oracle<'a> {
    /// Plain analytic oracle (no override).
    pub fn analytic(analytic: AnalyticEvaluator<'a>) -> Oracle<'a> {
        Oracle { analytic, backend: None }
    }

    /// Oracle with an optional backend override.
    pub fn with_backend(
        analytic: AnalyticEvaluator<'a>,
        backend: Option<&'a DynEvaluator>,
    ) -> Oracle<'a> {
        Oracle { analytic, backend }
    }

    /// Is a backend override installed (i.e. are responses *not* computed
    /// by the in-process analytic model)?
    pub fn is_overridden(&self) -> bool {
        self.backend.is_some()
    }

    /// Scalar value for the agent hot loop. See
    /// [`AnalyticEvaluator::value`] for the fast path's contract; with a
    /// backend override this is `backend.eval(req).value`, so a failed
    /// response contributes its in-band `0.0` (the run-level monitor — not
    /// this call — reports the failure).
    pub fn value(&self, req: &EvalRequest) -> f64 {
        match self.backend {
            None => self.analytic.value(req),
            Some(b) => b.eval(req).value,
        }
    }

    /// The borrowed analytic evaluator, but *only* when no backend
    /// override is installed. Callers with a pre-lowered [`ConfigBatch`]
    /// (move-pool scoring, Nominate rounds) take this to skip
    /// `EvalRequest` construction entirely; `None` means a backend must
    /// see every request (record/replay transparency — ADR-004), so the
    /// caller falls back to the batched request path. Values are bitwise
    /// equal either way, so artifacts and RNG draws do not depend on which
    /// path ran.
    pub fn direct(&self) -> Option<&AnalyticEvaluator<'a>> {
        match self.backend {
            None => Some(&self.analytic),
            Some(_) => None,
        }
    }
}

impl Evaluator for Oracle<'_> {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        match self.backend {
            None => self.analytic.eval_batch(reqs),
            Some(b) => b.eval_batch(reqs),
        }
    }
}

// ===========================================================================
// Analytic backend
// ===========================================================================

/// [`PerfModel`]-backed evaluator — the default measurement oracle of the
/// whole reproduction. `Copy` (four shared references), so sessions
/// construct one per call site at zero cost.
///
/// Candidate timings go through the borrowed [`CompiledCostModel`]: every
/// problem is lowered exactly once by whoever owns the model/suite pair
/// (`Bench`, `OwnedAnalytic`, a test fixture), and every evaluator built
/// from that owner reuses the same lowering (ADR-006 cache keying — the
/// key is the problem's index, position-stable like `sols`).
#[derive(Clone, Copy)]
pub struct AnalyticEvaluator<'a> {
    pub model: &'a PerfModel,
    pub problems: &'a [Problem],
    /// Per-problem SOL analyses (same order as `problems`).
    pub sols: &'a [SolAnalysis],
    /// Per-problem compiled costs (same order as `problems`).
    pub compiled: &'a CompiledCostModel,
}

impl<'a> AnalyticEvaluator<'a> {
    pub fn new(
        model: &'a PerfModel,
        problems: &'a [Problem],
        sols: &'a [SolAnalysis],
        compiled: &'a CompiledCostModel,
    ) -> AnalyticEvaluator<'a> {
        debug_assert_eq!(problems.len(), compiled.len(), "compiled cache must cover the suite");
        AnalyticEvaluator { model, problems, sols, compiled }
    }

    /// Evaluate a pre-lowered config batch against one problem, appending
    /// `batch.len()` candidate timings to `out` — the allocation-free lane
    /// the move-selection policy and MANTIS Nominate use with a reusable
    /// scratch batch. Bit-identical to `candidate` requests through
    /// [`Evaluator::eval_batch`].
    pub fn candidate_batch_into(&self, problem: usize, batch: &ConfigBatch, out: &mut Vec<f64>) {
        let start = out.len();
        out.resize(start + batch.len(), 0.0);
        self.compiled.problem(problem).eval_into(batch, &mut out[start..]);
    }

    /// Scalar value for the agent hot loop: computes the same number
    /// `eval(req).value` would (a test pins the equivalence) without the
    /// batch path's bucketing map, response vector, or key-string
    /// construction — `run_attempt` calls this several times per attempt.
    /// Panics on malformed requests, which would be a programming error at
    /// an in-process call site (the in-band-error path is `eval_batch`).
    pub fn value(&self, req: &EvalRequest) -> f64 {
        let problem = &self.problems[req.problem];
        match req.kind {
            MeasureKind::Baseline => {
                let t = self.model.baseline_ms(problem);
                match &req.stream {
                    Some(at) => t * measurement_noise(at),
                    None => t,
                }
            }
            MeasureKind::Candidate => {
                let cfg = req.config.as_ref().expect("candidate request without a config");
                self.compiled.problem(req.problem).candidate_ms(cfg)
            }
            MeasureKind::Measured => {
                let cfg = req.config.as_ref().expect("measured request without a config");
                let at =
                    req.stream.as_ref().expect("measured request without a noise stream");
                self.compiled.problem(req.problem).candidate_ms(cfg) * measurement_noise(at)
            }
            MeasureKind::SolGap => {
                let sol = self.sols[req.problem].t_sol_fp16_ms;
                let t = match &req.config {
                    Some(cfg) => self.compiled.problem(req.problem).candidate_ms(cfg),
                    None => self.model.baseline_ms(problem),
                };
                t / sol
            }
        }
    }

    /// `key` is the caller's precomputed [`EvalRequest::eval_key`] —
    /// threaded through so one batch never keys a request twice.
    fn respond(&self, req: &EvalRequest, key: EvalKey, candidate_ms: Option<f64>) -> EvalResponse {
        if req.problem >= self.problems.len() {
            return EvalResponse::error(key, format!("unknown problem index {}", req.problem));
        }
        let problem = &self.problems[req.problem];
        match req.kind {
            MeasureKind::Baseline => {
                let t = self.model.baseline_ms(problem);
                let t = match &req.stream {
                    Some(at) => t * measurement_noise(at),
                    None => t,
                };
                EvalResponse::ok(key, t)
            }
            MeasureKind::Candidate => match candidate_ms {
                Some(t) => EvalResponse::ok(key, t),
                None => EvalResponse::error(key, "candidate request without a config"),
            },
            MeasureKind::Measured => match (candidate_ms, &req.stream) {
                (Some(t), Some(at)) => EvalResponse::ok(key, t * measurement_noise(at)),
                (Some(_), None) => {
                    EvalResponse::error(key, "measured request without a noise stream")
                }
                (None, _) => EvalResponse::error(key, "measured request without a config"),
            },
            MeasureKind::SolGap => {
                let sol = self.sols[req.problem].t_sol_fp16_ms;
                let t = match &req.config {
                    Some(cfg) => self.compiled.problem(req.problem).candidate_ms(cfg),
                    None => self.model.baseline_ms(problem),
                };
                EvalResponse::ok(key, t / sol)
            }
        }
    }
}

impl Evaluator for AnalyticEvaluator<'_> {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        // Vectorized path: bucket candidate-bearing requests by problem and
        // run each bucket through the problem's pre-lowered compiled costs
        // (ADR-006) — configs are lowered into a reused struct-of-arrays
        // batch instead of cloned, and the inner loop is branch-free.
        let mut buckets: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, r) in reqs.iter().enumerate() {
            if matches!(r.kind, MeasureKind::Candidate | MeasureKind::Measured)
                && r.config.is_some()
                && r.problem < self.problems.len()
            {
                buckets.entry(r.problem).or_default().push(i);
            }
        }
        let mut candidate_ms: Vec<Option<f64>> = vec![None; reqs.len()];
        let mut batch = ConfigBatch::new();
        let mut out = Vec::new();
        for (p, idxs) in &buckets {
            batch.clear();
            batch.reserve(idxs.len());
            for &i in idxs {
                batch.push(reqs[i].config.as_ref().expect("bucketed"));
            }
            out.clear();
            out.resize(idxs.len(), 0.0);
            self.compiled.problem(*p).eval_into(&batch, &mut out);
            for (&i, &v) in idxs.iter().zip(&out) {
                candidate_ms[i] = Some(v);
            }
        }
        reqs.iter()
            .enumerate()
            .map(|(i, r)| self.respond(r, r.eval_key(), candidate_ms[i]))
            .collect()
    }
}

// ===========================================================================
// PJRT backend
// ===========================================================================

/// [`Runtime`]-backed evaluator: maps a candidate config onto the nearest
/// AOT artifact variant and numerically validates it against the problem's
/// reference. Responses carry the max abs error in `value` and the
/// validation verdict in `pass`.
///
/// Mirrors the runtime's graceful-skip story: when the artifact directory
/// is missing or the build lacks the `pjrt` feature, construction still
/// succeeds and every request is answered with an in-band error response,
/// so the trait contract (batch ≡ mapped scalar) holds in every build.
pub struct PjrtEvaluator {
    rt: Option<Mutex<Runtime>>,
    problems: Vec<Problem>,
    unavailable: Option<String>,
}

impl PjrtEvaluator {
    pub fn open(dir: impl AsRef<Path>, problems: Vec<Problem>) -> PjrtEvaluator {
        match Runtime::open(dir) {
            Ok(rt) => PjrtEvaluator { rt: Some(Mutex::new(rt)), problems, unavailable: None },
            Err(e) => {
                PjrtEvaluator { rt: None, problems, unavailable: Some(e.to_string()) }
            }
        }
    }

    /// Is a real executor behind this evaluator?
    pub fn available(&self) -> bool {
        self.rt.is_some()
    }

    fn eval_one(&self, rt: &mut Runtime, req: &EvalRequest) -> EvalResponse {
        let key = req.eval_key();
        if !matches!(req.kind, MeasureKind::Candidate | MeasureKind::Measured) {
            return EvalResponse::error(
                key,
                format!("kind `{}` unsupported by the PJRT backend", req.kind.name()),
            );
        }
        let Some(cfg) = &req.config else {
            return EvalResponse::error(key, "candidate request without a config");
        };
        let Some(problem) = self.problems.get(req.problem) else {
            return EvalResponse::error(key, format!("unknown problem index {}", req.problem));
        };
        let Some(artifact) = problem.artifact else {
            return EvalResponse::error(key, format!("{}: no AOT artifact", problem.id));
        };
        let Some(prob) = rt.manifest.problems.get(artifact).cloned() else {
            return EvalResponse::error(key, format!("artifact {artifact} not in manifest"));
        };
        let Some(variant) = Runtime::select_variant_for(&prob, cfg.tile, cfg.compute_dtype)
        else {
            return EvalResponse::error(key, format!("{artifact}: no variants"));
        };
        // validation inputs are seeded from the request's stream seed so a
        // replayed request validates on identical data
        let seed = req.stream.as_ref().map(|s| s.seed).unwrap_or(0);
        match rt.validate_variant(artifact, &variant, seed) {
            Ok(rep) => EvalResponse {
                key,
                value: rep.max_abs_err,
                pass: rep.pass,
                detail: Some(Arc::from(format!("{artifact}/{variant}"))),
            },
            Err(e) => EvalResponse::error(key, e.to_string()),
        }
    }
}

impl Evaluator for PjrtEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        match &self.rt {
            None => {
                let msg = self.unavailable.as_deref().unwrap_or("PJRT unavailable");
                reqs.iter().map(|r| EvalResponse::error(r.eval_key(), msg)).collect()
            }
            Some(rt) => {
                // one lock per batch: the executable cache amortizes across
                // the whole batch
                let mut rt = rt.lock().expect("pjrt runtime lock");
                reqs.iter().map(|r| self.eval_one(&mut rt, r)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::DType;
    use crate::kernelbench::suite;
    use crate::sol::{analyze, H100_SXM};
    use crate::util::rng::stream;

    struct Fx {
        model: PerfModel,
        problems: Vec<Problem>,
        sols: Vec<SolAnalysis>,
        compiled: CompiledCostModel,
    }

    impl Fx {
        fn new() -> Fx {
            let problems = suite();
            let sols = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
            let model = PerfModel::new(H100_SXM.clone());
            let compiled = CompiledCostModel::compile(&model, &problems);
            Fx { model, problems, sols, compiled }
        }

        fn ev(&self) -> AnalyticEvaluator<'_> {
            AnalyticEvaluator::new(&self.model, &self.problems, &self.sols, &self.compiled)
        }
    }

    #[test]
    fn value_fast_path_equals_eval() {
        // the scalar fast path must compute exactly what the batch path
        // answers, for every kind
        let fx = Fx::new();
        let ev = fx.ev();
        let cfg = CandidateConfig::library((128, 64, 32), DType::Fp32);
        let at = StreamPath::new(9, &[stream::MEASURE, 0, 4]);
        for req in [
            EvalRequest::baseline(2),
            EvalRequest::measured_baseline(2, at.clone()),
            EvalRequest::candidate(2, cfg.clone()),
            EvalRequest::measured(2, cfg.clone(), at),
            EvalRequest::sol_gap(2),
            EvalRequest::candidate(2, cfg).with_hash("deadbeef"),
        ] {
            let r = ev.eval(&req);
            assert!(r.pass);
            assert_eq!(ev.value(&req), r.value, "{}", req.key());
        }
    }

    #[test]
    fn analytic_kinds_match_model() {
        let fx = Fx::new();
        let ev = fx.ev();
        let cfg = CandidateConfig::library((128, 128, 64), DType::Fp16);
        let p = 0usize;
        assert_eq!(
            ev.value(&EvalRequest::baseline(p)),
            fx.model.baseline_ms(&fx.problems[p])
        );
        assert_eq!(
            ev.value(&EvalRequest::candidate(p, cfg.clone())),
            fx.model.candidate_ms(&fx.problems[p], &cfg)
        );
        let at = StreamPath::new(7, &[stream::MEASURE, 1, 2, 0]);
        assert_eq!(
            ev.value(&EvalRequest::measured(p, cfg.clone(), at.clone())),
            fx.model.measure_ms(&fx.problems[p], &cfg, &at)
        );
        assert_eq!(
            ev.value(&EvalRequest::sol_gap(p)),
            fx.model.baseline_ms(&fx.problems[p]) / fx.sols[p].t_sol_fp16_ms
        );
    }

    #[test]
    fn analytic_batch_equals_mapped_scalar() {
        let fx = Fx::new();
        let ev = fx.ev();
        let mut reqs = Vec::new();
        for p in [0usize, 3, 11, 40] {
            reqs.push(EvalRequest::baseline(p));
            reqs.push(EvalRequest::sol_gap(p));
            for (i, &tile) in crate::agent::policy::TILES.iter().enumerate() {
                let cfg = CandidateConfig::library(tile, DType::Fp32);
                reqs.push(EvalRequest::candidate(p, cfg.clone()));
                reqs.push(EvalRequest::measured(
                    p,
                    cfg,
                    StreamPath::new(5, &[stream::MEASURE, p as u64, i as u64]),
                ));
            }
        }
        // malformed requests answer in-band, in place
        reqs.push(EvalRequest {
            problem: 1,
            kind: MeasureKind::Candidate,
            config: None,
            config_hash: None,
            stream: None,
        });
        reqs.push(EvalRequest::baseline(10_000));
        let batch = ev.eval_batch(&reqs);
        assert_eq!(batch.len(), reqs.len());
        for (r, b) in reqs.iter().zip(&batch) {
            assert_eq!(*b, ev.eval(r), "batch must equal scalar for {}", r.key());
        }
        assert!(!batch[batch.len() - 1].pass);
        assert!(!batch[batch.len() - 2].pass);
    }

    #[test]
    fn request_key_distinguishes_identities() {
        let cfg = CandidateConfig::library((128, 128, 64), DType::Fp16);
        let a = EvalRequest::candidate(3, cfg.clone());
        let b = EvalRequest::candidate(4, cfg.clone());
        let c = EvalRequest::measured(3, cfg.clone(), StreamPath::new(7, &[8, 1]));
        let d = EvalRequest::measured(3, cfg.clone(), StreamPath::new(7, &[8, 2]));
        let e = EvalRequest::candidate(3, cfg).with_hash("deadbeef");
        let keys = [a.key(), b.key(), c.key(), d.key(), e.key()];
        let set: std::collections::HashSet<&String> = keys.iter().collect();
        assert_eq!(set.len(), keys.len(), "all keys distinct: {keys:?}");
        let ikeys = [a.eval_key(), b.eval_key(), c.eval_key(), d.eval_key(), e.eval_key()];
        let iset: std::collections::HashSet<&EvalKey> = ikeys.iter().collect();
        assert_eq!(iset.len(), ikeys.len(), "all interned keys distinct: {ikeys:?}");
        // same identity → same key, in both forms
        let a2 = EvalRequest::candidate(3, CandidateConfig::library((128, 128, 64), DType::Fp16));
        assert_eq!(a.key(), a2.key());
        assert_eq!(a.eval_key(), a2.eval_key());
    }

    /// The full suite enumeration every backend actually serves: baselines
    /// (measured + noiseless), SOL gaps, and the whole tile × dtype
    /// candidate/measured grid per problem, with and without plan hashes.
    fn full_enumeration() -> Vec<EvalRequest> {
        let problems = suite();
        let mut reqs = Vec::new();
        for p in 0..problems.len() {
            reqs.push(EvalRequest::baseline(p));
            reqs.push(EvalRequest::measured_baseline(
                p,
                StreamPath::new(12345, &[stream::MEASURE, stream::FLAT_CONTROLLER, p as u64, 0]),
            ));
            reqs.push(EvalRequest::sol_gap(p));
            for (i, &tile) in crate::agent::policy::TILES.iter().enumerate() {
                for dtype in [DType::Fp32, DType::Fp16, DType::Bf16] {
                    let cfg = CandidateConfig::library(tile, dtype);
                    reqs.push(EvalRequest::candidate(p, cfg.clone()));
                    reqs.push(
                        EvalRequest::candidate(p, cfg.clone()).with_hash(format!("{i:08x}")),
                    );
                    reqs.push(EvalRequest::measured(
                        p,
                        cfg,
                        StreamPath::new(12345, &[stream::MEASURE, p as u64, i as u64]),
                    ));
                }
            }
        }
        reqs
    }

    #[test]
    fn golden_compiled_equals_batch_equals_scalar_over_the_suite_enumeration() {
        // ADR-006 bitwise-equivalence contract: for every candidate-bearing
        // request of the full suite enumeration, the compiled evaluator,
        // the batched entry point, and the scalar generic path produce the
        // same bit pattern — so RunLogs, sweep grids, and recorded traces
        // are byte-identical across the three paths.
        let fx = Fx::new();
        let reqs = full_enumeration();
        assert!(reqs.len() > 5_000, "enumeration must be non-trivial: {}", reqs.len());
        let responses = fx.ev().eval_batch(&reqs);
        let mut candidates = 0usize;
        for (r, resp) in reqs.iter().zip(&responses) {
            let Some(cfg) = &r.config else { continue };
            candidates += 1;
            let p = &fx.problems[r.problem];
            let scalar = fx.model.candidate_ms(p, cfg);
            let batch = fx.model.candidate_ms_batch(p, std::slice::from_ref(cfg))[0];
            let compiled = fx.compiled.problem(r.problem).candidate_ms(cfg);
            assert_eq!(scalar.to_bits(), batch.to_bits(), "{}", r.key());
            assert_eq!(scalar.to_bits(), compiled.to_bits(), "{}", r.key());
            // and the value the evaluator actually served is built on the
            // same bits (Measured scales by the request's noise stream)
            let served = match (r.kind, &r.stream) {
                (MeasureKind::Measured, Some(at)) => scalar * measurement_noise(at),
                _ => scalar,
            };
            assert_eq!(served.to_bits(), resp.value.to_bits(), "{}", r.key());
        }
        assert!(candidates > 5_000, "candidate coverage must be non-trivial: {candidates}");
    }

    #[test]
    fn eval_key_is_equivalent_to_string_key_over_the_suite_enumeration() {
        // ADR-005 consistency contract: over the full suite enumeration,
        // the interned key partitions requests exactly like the canonical
        // string key — same string ⇒ same EvalKey, distinct strings ⇒
        // distinct EvalKeys (collision-freedom)
        use std::collections::HashMap;
        let reqs = full_enumeration();
        assert!(reqs.len() > 5_000, "enumeration must be non-trivial: {}", reqs.len());
        let mut by_ikey: HashMap<EvalKey, String> = HashMap::with_capacity(reqs.len());
        for r in &reqs {
            let s = r.key();
            match by_ikey.get(&r.eval_key()) {
                None => {
                    by_ikey.insert(r.eval_key(), s);
                }
                Some(prev) => assert_eq!(
                    *prev, s,
                    "EvalKey collision: `{prev}` and `{s}` share {:?}",
                    r.eval_key()
                ),
            }
        }
        // distinct strings got distinct interned keys
        let strings: std::collections::HashSet<&String> = by_ikey.values().collect();
        assert_eq!(strings.len(), by_ikey.len());
        // determinism: recomputing any key reproduces it
        for r in reqs.iter().take(64) {
            assert_eq!(r.eval_key(), r.eval_key());
        }
    }

    #[test]
    fn eval_key_process_stability_golden_vectors() {
        // pinned against an independent (Python) FNV-1a 128 reference over
        // the documented canonical field encoding: these digests must
        // never change, or recorded traces stop serving across builds
        assert_eq!(
            EvalRequest::baseline(3).eval_key(),
            EvalKey(0x4b7c_e53d_a388_8ea3_d8e4_cb76_db6f_9fc3),
        );
        let cfg = CandidateConfig::library((128, 64, 32), DType::Fp16);
        assert_eq!(
            EvalRequest::candidate(2, cfg).with_hash("deadbeef").eval_key(),
            EvalKey(0xd862_1e5b_c593_b477_2f01_4792_0a68_8777),
        );
        assert_eq!(
            EvalRequest::measured_baseline(
                1,
                StreamPath::new(0xFFEE_DDCC_BBAA_9988, &[8, 2, 0x1_0000_0001]),
            )
            .eval_key(),
            EvalKey(0x49d6_a5c3_3776_adeb_0524_6be4_3de1_e927),
        );
    }

    #[test]
    fn eval_key_hex_roundtrip() {
        for k in [EvalKey(0), EvalKey(u128::MAX), EvalRequest::baseline(7).eval_key()] {
            let hex = k.to_hex();
            assert_eq!(hex.len(), 32);
            assert_eq!(EvalKey::parse_hex(&hex), Some(k));
        }
        assert_eq!(EvalKey::parse_hex("xyz"), None);
        assert_eq!(EvalKey::parse_hex(""), None);
        assert_eq!(EvalKey::parse_hex(&"f".repeat(33)), None);
        // shard assignment is total and stable
        let k = EvalRequest::baseline(7).eval_key();
        for of in [1usize, 2, 7] {
            assert!(k.shard(of) < of);
            assert_eq!(k.shard(of), k.shard(of));
        }
    }

    #[test]
    fn request_response_json_roundtrip() {
        let cfg = CandidateConfig::library((64, 128, 64), DType::Bf16);
        // a seed above 2^53 must survive serialization exactly
        let at = StreamPath::new(0xFFEE_DDCC_BBAA_9988, &[stream::MEASURE, 2, 0x1_0000_0001]);
        let reqs = [
            EvalRequest::baseline(1),
            EvalRequest::measured_baseline(1, at.clone()),
            EvalRequest::candidate(2, cfg.clone()).with_hash("abc123"),
            EvalRequest::measured(3, cfg, at),
            EvalRequest::sol_gap(4),
        ];
        for r in &reqs {
            let parsed =
                EvalRequest::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(*r, parsed);
            assert_eq!(r.key(), parsed.key());
            assert_eq!(r.eval_key(), parsed.eval_key());
        }
        let resp = EvalResponse {
            key: reqs[0].eval_key(),
            value: 1.2345678901234567,
            pass: true,
            detail: Some("x/y".into()),
        };
        let parsed =
            EvalResponse::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(resp, parsed);
    }

    #[test]
    fn stream_json_rejects_malformed_hex_in_band() {
        // seeds/components travel as hex-u64 strings; malformed, negative,
        // overflowing, and mistyped inputs must fail in-band (None), never
        // panic and never silently truncate
        for bad in [
            r#"{"seed":"zz","path":[]}"#,                  // non-hex digits
            r#"{"seed":"1ffffffffffffffff","path":[]}"#,   // 17 hex digits: > u64::MAX
            r#"{"seed":"-1","path":[]}"#,                  // negative
            r#"{"seed":"","path":[]}"#,                    // empty
            r#"{"seed":12,"path":[]}"#,                    // JSON number, not hex string
            r#"{"path":["a"]}"#,                           // missing seed
            r#"{"seed":"a"}"#,                             // missing path
            r#"{"seed":"a","path":"10"}"#,                 // path not an array
            r#"{"seed":"a","path":["10","zz"]}"#,          // bad component
            r#"{"seed":"a","path":["10",7]}"#,             // non-string component
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(stream_from_json(&j).is_none(), "must reject: {bad}");
        }
        // boundary values round-trip exactly
        for seed in [0u64, u64::MAX, 1 << 63, (1 << 53) + 1] {
            let at = StreamPath::new(seed, &[u64::MAX, 0]);
            let parsed = stream_from_json(&Json::parse(&stream_to_json(&at).to_string()).unwrap());
            assert_eq!(parsed.as_ref(), Some(&at));
        }
    }

    #[test]
    fn request_from_json_rejects_negative_and_fractional_indices() {
        // a negative problem index must not truncate to 0 (Json::as_u64 is
        // strict); same for fractional indices
        for bad in [
            r#"{"problem":-1,"kind":"baseline","config":null,"config_hash":null,"stream":null}"#,
            r#"{"problem":1.5,"kind":"baseline","config":null,"config_hash":null,"stream":null}"#,
            r#"{"problem":"3","kind":"baseline","config":null,"config_hash":null,"stream":null}"#,
            r#"{"problem":3,"kind":"nonsense","config":null,"config_hash":null,"stream":null}"#,
            r#"{"kind":"baseline","config":null,"config_hash":null,"stream":null}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(EvalRequest::from_json(&j).is_none(), "must reject: {bad}");
        }
    }

    #[test]
    fn pjrt_evaluator_degrades_gracefully() {
        // no artifacts/ (or no pjrt feature): constructible, answers every
        // request with an in-band error, batch ≡ scalar still holds
        let ev = PjrtEvaluator::open("definitely-not-a-directory", suite());
        if ev.available() {
            return; // a real artifact dir exists here; covered elsewhere
        }
        let cfg = CandidateConfig::library((64, 64, 64), DType::Fp32);
        let reqs =
            [EvalRequest::candidate(0, cfg.clone()), EvalRequest::baseline(0), EvalRequest::sol_gap(1)];
        let batch = ev.eval_batch(&reqs);
        for (r, b) in reqs.iter().zip(&batch) {
            assert!(!b.pass);
            assert_eq!(*b, ev.eval(r));
        }
    }
}
