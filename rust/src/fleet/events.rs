//! Machine-readable coordinator event log (ADR-007).
//!
//! Every coordinator decision — spawn, assign, result, duplicate-discard,
//! timeout, retry, quarantine, merge — is recorded as one JSON object
//! with a monotonic `t_ms` timestamp. The log is always kept in memory
//! (tests assert on it: "the crash schedule must produce exactly one
//! respawn event") and optionally streamed as JSONL to a sink
//! (`repro serve --events PATH`) for later observability work.

use crate::util::json::Json;
use std::io::Write;
use std::sync::Mutex;
use std::time::Instant;

pub struct EventLog {
    t0: Instant,
    inner: Mutex<Inner>,
}

struct Inner {
    events: Vec<Json>,
    sink: Option<Box<dyn Write + Send>>,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog::new()
    }
}

impl EventLog {
    /// In-memory only.
    pub fn new() -> EventLog {
        EventLog {
            t0: Instant::now(),
            inner: Mutex::new(Inner { events: Vec::new(), sink: None }),
        }
    }

    /// Also stream each event as one JSON line to `sink`.
    pub fn with_sink(sink: Box<dyn Write + Send>) -> EventLog {
        EventLog {
            t0: Instant::now(),
            inner: Mutex::new(Inner { events: Vec::new(), sink: Some(sink) }),
        }
    }

    /// Record one event. `fill` adds the kind-specific fields; `event`
    /// and `t_ms` are stamped here so every record has them.
    pub fn emit(&self, kind: &str, fill: impl FnOnce(&mut Json)) {
        let mut o = Json::obj();
        o.set("event", kind).set("t_ms", self.t0.elapsed().as_millis() as u64);
        fill(&mut o);
        let mut inner = self.inner.lock().expect("event log lock");
        if let Some(sink) = inner.sink.as_mut() {
            // sink failures must not take the fleet down mid-run; the
            // in-memory log stays authoritative. Flushed per line
            // (ADR-010): a `kill -9`'d coordinator must leave at worst
            // one torn *final* line, never a buffer of silently lost
            // events.
            let _ = writeln!(sink, "{o}");
            let _ = sink.flush();
        }
        inner.events.push(o);
    }

    /// Snapshot of all events so far.
    pub fn events(&self) -> Vec<Json> {
        self.inner.lock().expect("event log lock").events.clone()
    }

    /// How many events of `kind` have been recorded.
    pub fn count(&self, kind: &str) -> usize {
        self.inner
            .lock()
            .expect("event log lock")
            .events
            .iter()
            .filter(|e| e.get("event").and_then(|k| k.as_str()) == Some(kind))
            .count()
    }

    /// Flush the sink, if any.
    pub fn flush(&self) {
        if let Some(sink) = self.inner.lock().expect("event log lock").sink.as_mut() {
            let _ = sink.flush();
        }
    }
}

/// Parse an `--events` JSONL file back into events, tolerating exactly
/// the damage a crash can inflict: a torn **final** line (no trailing
/// newline, or one that fails to parse) is dropped and reported via the
/// returned flag. A malformed *interior* line cannot come from a crash
/// — per-line flushing means every interior line was written whole — so
/// it is an in-band error, not something to skip silently.
pub fn parse_events_jsonl(text: &str) -> Result<(Vec<Json>, bool), String> {
    let ends_clean = text.is_empty() || text.ends_with('\n');
    let lines: Vec<&str> = text.lines().collect();
    let mut events = Vec::with_capacity(lines.len());
    let mut torn = false;
    for (n, line) in lines.iter().enumerate() {
        let last = n + 1 == lines.len();
        match Json::parse(line) {
            Ok(j) => {
                if last && !ends_clean {
                    // parses, but the newline never landed: treat it as
                    // torn anyway — a longer intended line could have
                    // been cut at a point that still parses
                    torn = true;
                } else {
                    events.push(j);
                }
            }
            Err(_) if last => torn = true,
            Err(e) => {
                return Err(format!("events line {}: {e}", n + 1));
            }
        }
    }
    Ok((events, torn))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// A Write sink backed by shared memory, for asserting on JSONL out.
    struct MemSink(Arc<Mutex<Vec<u8>>>);
    impl Write for MemSink {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(b);
            Ok(b.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn events_carry_kind_time_and_fields() {
        let log = EventLog::new();
        log.emit("assign", |e| {
            e.set("slot", 2usize).set("shard", 7usize);
        });
        log.emit("retry", |e| {
            e.set("shard", 7usize);
        });
        log.emit("assign", |e| {
            e.set("slot", 0usize).set("shard", 8usize);
        });
        assert_eq!(log.count("assign"), 2);
        assert_eq!(log.count("retry"), 1);
        assert_eq!(log.count("quarantine"), 0);
        let ev = log.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].get("slot").and_then(|s| s.as_u64()), Some(2));
        assert!(ev[0].get("t_ms").and_then(|t| t.as_u64()).is_some());
    }

    #[test]
    fn events_jsonl_tolerates_only_a_torn_tail() {
        let whole = "{\"event\":\"spawn\",\"t_ms\":0}\n{\"event\":\"done\",\"t_ms\":9}\n";
        let (ev, torn) = parse_events_jsonl(whole).unwrap();
        assert_eq!(ev.len(), 2);
        assert!(!torn);

        // crash mid-final-line: dropped, flagged, prefix intact
        for cut in 1..whole.len() {
            let text = &whole[..cut];
            let (ev, torn) = parse_events_jsonl(text).unwrap();
            if text.ends_with('\n') {
                assert!(!torn, "cut at a line boundary is clean");
            } else {
                assert!(torn, "cut at byte {cut} must flag a torn tail");
            }
            for e in &ev {
                assert!(e.get("event").is_some());
            }
        }

        // a malformed interior line is corruption, not a crash artifact
        let bad = "{\"event\":\"spawn\"}\nnot json\n{\"event\":\"done\"}\n";
        let err = parse_events_jsonl(bad).unwrap_err();
        assert!(err.contains("line 2"), "got: {err}");

        let (ev, torn) = parse_events_jsonl("").unwrap();
        assert!(ev.is_empty() && !torn);
    }

    #[test]
    fn sink_receives_one_json_line_per_event() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let log = EventLog::with_sink(Box::new(MemSink(Arc::clone(&buf))));
        log.emit("spawn", |e| {
            e.set("slot", 0usize);
        });
        log.emit("done", |_| {});
        log.flush();
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("event").is_some() && j.get("t_ms").is_some());
        }
    }
}
