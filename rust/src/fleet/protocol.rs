//! The fleet wire protocol (ADR-007): length-checked, version-gated,
//! line-delimited JSON over a worker's stdin/stdout.
//!
//! One message per line. Every message carries a `"fleet"` protocol
//! version; a mismatched or missing version is a distinct parse outcome
//! ([`ParseError::Version`]) so the coordinator can quarantine a
//! wrong-build worker instead of retrying it forever. Lines are read
//! through [`read_line_capped`], which enforces [`MAX_LINE_BYTES`]
//! *while reading* — an overlong line is reported without ever being
//! materialized, and the reader resynchronizes at the next newline so one
//! oversized reply cannot wedge the connection.
//!
//! The JSON writer escapes every control character (`\n` included), so a
//! serialized message is always exactly one line; arbitrary `detail`
//! strings cannot break the framing.

use crate::eval::manifest::{SuiteShard, SuiteWork, MAX_ARTIFACT_BYTES};
use crate::util::json::Json;
use std::io::BufRead;

/// Fleet protocol version. Independent of `MANIFEST_VERSION`: the
/// envelope (framing, message kinds) and the payload (shard artifact
/// schema) evolve separately, and each is gated on its own field.
pub const FLEET_PROTOCOL_VERSION: u64 = 1;

/// Line cap: the largest payload is a serialized [`SuiteShard`] (bounded
/// by the artifact cap shared with `repro merge`), plus slack for the
/// message envelope.
pub const MAX_LINE_BYTES: usize = MAX_ARTIFACT_BYTES + 4096;

/// A protocol message. `Assign`/`Shutdown` travel coordinator → worker;
/// `Ready`/`Result`/`Error` travel worker → coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker greeting, sent once on startup. Carries nothing beyond the
    /// version envelope: a `Ready` that parses IS the handshake.
    Ready,
    /// Run `suite_shard(bench, work, index, of)` and reply.
    Assign { job: String, index: usize, of: usize, work: SuiteWork },
    /// A completed shard.
    Result { job: String, index: usize, of: usize, shard: SuiteShard },
    /// In-band worker failure for one assignment (bad work, suite-size
    /// mismatch, …). The coordinator retries the shard elsewhere.
    Error { job: String, index: usize, detail: String },
    /// Coordinator is done with this worker; exit cleanly.
    Shutdown,
}

/// How a received line failed to parse.
#[derive(Debug, Clone, PartialEq)]
pub enum ParseError {
    /// The line is valid JSON with a `fleet` version this build does not
    /// speak — a mixed-version fleet, not line noise.
    Version { got: u64 },
    /// Garbage, truncation, or a structurally invalid message.
    Malformed(String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Version { got } => write!(
                f,
                "protocol version {got} (this build speaks {FLEET_PROTOCOL_VERSION})"
            ),
            ParseError::Malformed(e) => write!(f, "malformed message: {e}"),
        }
    }
}

impl Message {
    fn kind(&self) -> &'static str {
        match self {
            Message::Ready => "ready",
            Message::Assign { .. } => "assign",
            Message::Result { .. } => "result",
            Message::Error { .. } => "error",
            Message::Shutdown => "shutdown",
        }
    }

    pub fn to_json(&self) -> Json {
        self.to_json_v(FLEET_PROTOCOL_VERSION)
    }

    /// Serialize under an explicit protocol version — the fault injector
    /// uses this to script a wrong-version reply (ADR-007).
    pub fn to_json_v(&self, version: u64) -> Json {
        let mut o = Json::obj();
        o.set("fleet", version).set("type", self.kind());
        match self {
            Message::Ready | Message::Shutdown => {}
            Message::Assign { job, index, of, work } => {
                o.set("job", job.as_str())
                    .set("index", *index)
                    .set("of", *of)
                    .set("work", work.to_json());
            }
            Message::Result { job, index, of, shard } => {
                o.set("job", job.as_str())
                    .set("index", *index)
                    .set("of", *of)
                    .set("shard", shard.to_json());
            }
            Message::Error { job, index, detail } => {
                o.set("job", job.as_str()).set("index", *index).set("detail", detail.as_str());
            }
        }
        o
    }

    /// One wire line, newline included.
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string();
        s.push('\n');
        s
    }

    pub fn from_line(line: &str) -> Result<Message, ParseError> {
        let j = Json::parse(line.trim_end_matches(['\n', '\r']))
            .map_err(|e| ParseError::Malformed(e.to_string()))?;
        let version = j.get("fleet").and_then(|v| v.as_u64()).unwrap_or(0);
        if version != FLEET_PROTOCOL_VERSION {
            return Err(ParseError::Version { got: version });
        }
        let kind = j
            .get("type")
            .and_then(|t| t.as_str())
            .ok_or_else(|| ParseError::Malformed("missing type".into()))?;
        let field = |name: &str| -> Result<&Json, ParseError> {
            j.get(name).ok_or_else(|| ParseError::Malformed(format!("{kind}: missing {name}")))
        };
        let str_field = |name: &str| -> Result<String, ParseError> {
            Ok(field(name)?
                .as_str()
                .ok_or_else(|| ParseError::Malformed(format!("{kind}: bad {name}")))?
                .to_string())
        };
        let num_field = |name: &str| -> Result<usize, ParseError> {
            Ok(field(name)?
                .as_u64()
                .ok_or_else(|| ParseError::Malformed(format!("{kind}: bad {name}")))?
                as usize)
        };
        match kind {
            "ready" => Ok(Message::Ready),
            "shutdown" => Ok(Message::Shutdown),
            "assign" => Ok(Message::Assign {
                job: str_field("job")?,
                index: num_field("index")?,
                of: num_field("of")?,
                work: SuiteWork::from_json(field("work")?).map_err(ParseError::Malformed)?,
            }),
            "result" => Ok(Message::Result {
                job: str_field("job")?,
                index: num_field("index")?,
                of: num_field("of")?,
                shard: SuiteShard::from_json(field("shard")?).map_err(ParseError::Malformed)?,
            }),
            "error" => Ok(Message::Error {
                job: str_field("job")?,
                index: num_field("index")?,
                detail: str_field("detail")?,
            }),
            other => Err(ParseError::Malformed(format!("unknown message type `{other}`"))),
        }
    }
}

/// One read outcome from [`read_line_capped`].
#[derive(Debug)]
pub enum LineRead {
    /// A complete line (newline stripped). Invalid UTF-8 is replaced, not
    /// fatal — the resulting string then fails `Json::parse` in-band.
    Line(String),
    /// The stream ended cleanly.
    Eof,
    /// A line exceeded `cap` bytes. The overlong tail has been drained up
    /// to the next newline (or EOF), so the next read starts on a fresh
    /// line; `discarded` is the total size seen before resync.
    Overlong { discarded: usize },
}

/// Read one newline-terminated line of at most `cap` bytes. The cap is
/// enforced during the read — an attacker (or fault injector) writing an
/// unbounded line costs bounded memory here.
pub fn read_line_capped<R: BufRead>(r: &mut R, cap: usize) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let n = r.by_ref().take(cap as u64 + 1).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    } else if buf.len() > cap {
        // over the cap with no newline yet: drain to the next line
        // boundary in bounded chunks, counting but not keeping the tail
        let mut discarded = buf.len();
        buf.clear();
        loop {
            let mut tail = Vec::new();
            let m = r.by_ref().take(1 << 16).read_until(b'\n', &mut tail)?;
            discarded += m;
            if m == 0 || tail.last() == Some(&b'\n') {
                return Ok(LineRead::Overlong { discarded });
            }
        }
    }
    // a final unterminated line (writer died mid-write) is delivered
    // as-is; if truncation broke the JSON it fails to parse, in-band
    Ok(LineRead::Line(String::from_utf8_lossy(&buf).into_owned()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::{ControllerKind, VariantSpec};
    use crate::agent::ModelTier;
    use std::io::BufReader;

    fn work() -> SuiteWork {
        SuiteWork::single(
            VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini),
            None,
            7,
            59,
        )
    }

    #[test]
    fn messages_roundtrip_one_line_each() {
        let msgs = vec![
            Message::Ready,
            Message::Shutdown,
            Message::Assign { job: "j1".into(), index: 3, of: 8, work: work() },
            Message::Result {
                job: "j1".into(),
                index: 3,
                of: 8,
                shard: SuiteShard { work: work(), index: 3, of: 8, results: Vec::new() },
            },
            Message::Error {
                job: "j1".into(),
                index: 3,
                detail: "multi\nline\tdetail \"quoted\"".into(),
            },
        ];
        for m in msgs {
            let line = m.to_line();
            assert_eq!(line.matches('\n').count(), 1, "exactly one newline: {line:?}");
            assert!(line.ends_with('\n'));
            assert_eq!(Message::from_line(&line).unwrap(), m);
        }
    }

    #[test]
    fn version_gate_is_a_distinct_outcome() {
        let wrong = Message::Ready.to_json_v(99).to_string();
        assert_eq!(Message::from_line(&wrong), Err(ParseError::Version { got: 99 }));
        // missing version field → version 0, still the version outcome
        assert_eq!(
            Message::from_line(r#"{"type":"ready"}"#),
            Err(ParseError::Version { got: 0 })
        );
        // garbage is Malformed, not Version
        assert!(matches!(
            Message::from_line("\u{0}\u{7}{]garbage"),
            Err(ParseError::Malformed(_))
        ));
    }

    #[test]
    fn capped_reader_delivers_skips_and_resyncs() {
        let long = "x".repeat(100);
        let input = format!("short\n{long}\nafter\nlast-no-newline");
        let mut r = BufReader::new(input.as_bytes());
        assert!(matches!(read_line_capped(&mut r, 32).unwrap(), LineRead::Line(l) if l == "short"));
        // the 100-byte line exceeds the 32-byte cap: skipped, resynced
        match read_line_capped(&mut r, 32).unwrap() {
            LineRead::Overlong { discarded } => assert!(discarded >= 100, "{discarded}"),
            other => panic!("expected Overlong, got {other:?}"),
        }
        assert!(matches!(read_line_capped(&mut r, 32).unwrap(), LineRead::Line(l) if l == "after"));
        // unterminated final line is still delivered (truncated writes
        // surface as parse errors, not lost bytes)
        assert!(
            matches!(read_line_capped(&mut r, 32).unwrap(), LineRead::Line(l) if l == "last-no-newline")
        );
        assert!(matches!(read_line_capped(&mut r, 32).unwrap(), LineRead::Eof));
    }

    #[test]
    fn capped_reader_handles_overlong_tail_at_eof() {
        let input = "y".repeat(80); // no newline at all, over cap
        let mut r = BufReader::new(input.as_bytes());
        match read_line_capped(&mut r, 16).unwrap() {
            LineRead::Overlong { discarded } => assert_eq!(discarded, 80),
            other => panic!("expected Overlong, got {other:?}"),
        }
        assert!(matches!(read_line_capped(&mut r, 16).unwrap(), LineRead::Eof));
    }

    #[test]
    fn crlf_lines_parse_too() {
        let mut r = BufReader::new("ready\r\n".as_bytes());
        assert!(matches!(read_line_capped(&mut r, 64).unwrap(), LineRead::Line(l) if l == "ready"));
    }
}
