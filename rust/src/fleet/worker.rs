//! The fleet worker loop (ADR-007): read `assign` lines, run
//! `suite_shard`, reply `result` — plus the scripted misbehaviors of the
//! fault-injection harness.
//!
//! [`worker_loop`] is generic over its byte streams, so the `repro
//! worker` subprocess (stdin/stdout) and the in-process test harness
//! ([`super::pipe`]) execute the *same* code — fault-injection tests
//! exercising the in-process harness are testing the very loop a real
//! fleet runs, not a simulation of it.

use crate::eval::manifest::suite_shard;
use crate::experiments::runner::Bench;
use crate::fleet::faults::{Fault, FaultPlan};
use crate::fleet::protocol::{
    read_line_capped, LineRead, Message, ParseError, FLEET_PROTOCOL_VERSION, MAX_LINE_BYTES,
};
use crate::journal::LeaseMonitor;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// Worker configuration: its fault plan, where in the plan it starts
/// (`--fault-offset`: assignments already issued to this slot before a
/// respawn — see `faults` module docs), and an optional coordinator
/// lease to watch (ADR-010): once the lease goes stale the worker exits
/// on its own instead of orphaning — bounded by the lease timeout, not
/// by [`HANG_CAP`].
#[derive(Debug, Clone, Default)]
pub struct WorkerOpts {
    pub faults: FaultPlan,
    pub start_ordinal: u64,
    pub lease: Option<LeaseMonitor>,
}

/// Upper bound on a scripted hang: a hung worker whose coordinator died
/// before killing it must still exit on its own, not orphan in CI.
const HANG_CAP: Duration = Duration::from_secs(120);

/// Drive one worker over a pair of byte streams until EOF, `shutdown`, or
/// an I/O error. The `kill` flag is the in-process stand-in for SIGKILL:
/// the coordinator's link sets it (and closes the input) to terminate a
/// hung worker, mirroring `Child::kill` on the subprocess path.
pub fn worker_loop<R: BufRead, W: Write>(
    bench: &Bench,
    mut input: R,
    mut output: W,
    opts: &WorkerOpts,
    kill: &AtomicBool,
) -> Result<(), String> {
    let send = |out: &mut W, msg: &Message| -> Result<(), String> {
        out.write_all(msg.to_line().as_bytes())
            .and_then(|_| out.flush())
            .map_err(|e| format!("worker write: {e}"))
    };
    send(&mut output, &Message::Ready)?;
    let mut lease = opts.lease.clone();
    let mut received: u64 = 0;
    loop {
        if kill.load(Ordering::Relaxed) {
            return Ok(());
        }
        if lease.as_mut().is_some_and(|m| m.stale()) {
            return Ok(()); // coordinator gone: orphan hygiene
        }
        let line = match read_line_capped(&mut input, MAX_LINE_BYTES)
            .map_err(|e| format!("worker read: {e}"))?
        {
            LineRead::Eof => return Ok(()), // coordinator gone
            LineRead::Overlong { discarded } => {
                send(
                    &mut output,
                    &Message::Error {
                        job: String::new(),
                        index: 0,
                        detail: format!("overlong line ({discarded} bytes)"),
                    },
                )?;
                continue;
            }
            LineRead::Line(l) => l,
        };
        let (job, index, of, work) = match Message::from_line(&line) {
            Ok(Message::Assign { job, index, of, work }) => (job, index, of, work),
            Ok(Message::Shutdown) => return Ok(()),
            Ok(other) => {
                send(
                    &mut output,
                    &Message::Error {
                        job: String::new(),
                        index: 0,
                        detail: format!("unexpected {:?} from coordinator", other),
                    },
                )?;
                continue;
            }
            Err(e) => {
                send(
                    &mut output,
                    &Message::Error { job: String::new(), index: 0, detail: e.to_string() },
                )?;
                continue;
            }
        };
        let ordinal = opts.start_ordinal + received;
        received += 1;
        let fault = opts.faults.fault_at(ordinal);

        // pre-reply faults
        match fault {
            Some(Fault::CrashBeforeReply) => return Ok(()), // EOF at the coordinator
            Some(Fault::HangPastDeadline) => {
                let start = std::time::Instant::now();
                while start.elapsed() < HANG_CAP {
                    if kill.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    if lease.as_mut().is_some_and(|m| m.stale()) {
                        return Ok(()); // even a hung worker honors the lease
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                return Ok(());
            }
            Some(Fault::GarbageLine) => {
                // non-UTF-8 line noise instead of a result
                output
                    .write_all(b"\x00\xff\x07{]garbage\xfe\n")
                    .and_then(|_| output.flush())
                    .map_err(|e| format!("worker write: {e}"))?;
                continue;
            }
            _ => {}
        }

        // in-band validation: a bad assignment is the coordinator's bug
        // (or a hostile peer), never a worker panic
        if of == 0 || index >= of {
            send(
                &mut output,
                &Message::Error {
                    job,
                    index,
                    detail: format!("assign: index {index} out of range for of {of}"),
                },
            )?;
            continue;
        }
        if work.problems != bench.problems.len() {
            send(
                &mut output,
                &Message::Error {
                    job,
                    index,
                    detail: format!(
                        "suite size mismatch: job has {} problems, this build {}",
                        work.problems,
                        bench.problems.len()
                    ),
                },
            )?;
            continue;
        }

        let shard = suite_shard(bench, &work, index, of);
        let reply = Message::Result { job, index, of, shard };

        // reply-shape faults
        match fault {
            Some(Fault::TruncatedLine) => {
                let line = reply.to_line();
                let mut cut = line.len() / 2;
                while !line.is_char_boundary(cut) {
                    cut -= 1;
                }
                output
                    .write_all(line[..cut].as_bytes())
                    .and_then(|_| output.write_all(b"\n"))
                    .and_then(|_| output.flush())
                    .map_err(|e| format!("worker write: {e}"))?;
            }
            Some(Fault::WrongVersion) => {
                let mut line = reply.to_json_v(FLEET_PROTOCOL_VERSION + 1).to_string();
                line.push('\n');
                output
                    .write_all(line.as_bytes())
                    .and_then(|_| output.flush())
                    .map_err(|e| format!("worker write: {e}"))?;
            }
            Some(Fault::DuplicateReply) => {
                send(&mut output, &reply)?;
                send(&mut output, &reply)?;
            }
            _ => send(&mut output, &reply)?,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::{ControllerKind, VariantSpec};
    use crate::agent::ModelTier;
    use crate::eval::manifest::SuiteWork;
    use crate::fleet::pipe::pipe;
    use std::io::BufReader;

    /// Drive a worker thread over in-memory pipes with the given inbound
    /// script; returns the parsed outcome of each reply line.
    fn drive(bench: &Bench, opts: WorkerOpts, inbound: Vec<Message>) -> Vec<Result<Message, ParseError>> {
        let (mut to_worker, worker_in) = pipe();
        let (worker_out, coord_in) = pipe();
        let kill = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                let _ = worker_loop(bench, BufReader::new(worker_in), worker_out, &opts, &kill);
            });
            for m in &inbound {
                to_worker.write_all(m.to_line().as_bytes()).unwrap();
            }
            drop(to_worker); // EOF ends the worker after the script
            let mut replies = Vec::new();
            let mut r = BufReader::new(coord_in);
            loop {
                match read_line_capped(&mut r, MAX_LINE_BYTES).unwrap() {
                    LineRead::Eof => break,
                    LineRead::Overlong { discarded } => {
                        replies.push(Err(ParseError::Malformed(format!("overlong {discarded}"))))
                    }
                    LineRead::Line(l) => replies.push(Message::from_line(&l)),
                }
            }
            replies
        })
    }

    fn tiny_job(bench: &Bench) -> SuiteWork {
        SuiteWork::single(
            VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
            None,
            9,
            bench.problems.len(),
        )
    }

    #[test]
    fn worker_greets_computes_and_shuts_down() {
        let bench = Bench::new();
        let work = tiny_job(&bench);
        let of = crate::exec::suite_tasks(&work.work, work.problems).len();
        let replies = drive(
            &bench,
            WorkerOpts::default(),
            vec![
                Message::Assign { job: "j".into(), index: 4, of, work: work.clone() },
                Message::Shutdown,
            ],
        );
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[0], Ok(Message::Ready));
        match replies[1].as_ref().unwrap() {
            Message::Result { job, index, of: got_of, shard } => {
                assert_eq!((job.as_str(), *index, *got_of), ("j", 4, of));
                assert_eq!(*shard, suite_shard(&bench, &work, 4, of), "must equal the direct call");
            }
            other => panic!("expected result, got {other:?}"),
        }
    }

    #[test]
    fn worker_rejects_bad_assignments_in_band() {
        let bench = Bench::new();
        let mut work = tiny_job(&bench);
        let of = crate::exec::suite_tasks(&work.work, work.problems).len();
        work.problems += 1; // suite-size skew
        let replies = drive(
            &bench,
            WorkerOpts::default(),
            vec![
                Message::Assign { job: "j".into(), index: 0, of, work: work.clone() },
                Message::Assign { job: "j".into(), index: of + 9, of, work: tiny_job(&bench) },
            ],
        );
        assert_eq!(replies.len(), 3, "ready + two in-band errors");
        for r in &replies[1..] {
            assert!(
                matches!(r, Ok(Message::Error { .. })),
                "bad assigns must answer in-band, got {r:?}"
            );
        }
    }

    #[test]
    fn scripted_faults_shape_the_reply_stream() {
        let bench = Bench::new();
        let work = tiny_job(&bench);
        let of = crate::exec::suite_tasks(&work.work, work.problems).len();
        let assign = |i: usize| Message::Assign { job: "j".into(), index: i, of, work: work.clone() };

        // ordinal 0 garbage, 1 truncated, 2 wrong-version, 3 duplicate, 4 clean
        let opts = WorkerOpts {
            faults: FaultPlan::none()
                .with(0, Fault::GarbageLine)
                .with(1, Fault::TruncatedLine)
                .with(2, Fault::WrongVersion)
                .with(3, Fault::DuplicateReply),
            ..WorkerOpts::default()
        };
        let replies = drive(&bench, opts, (0..5).map(assign).collect());
        assert_eq!(replies.len(), 1 + 6, "ready + garbage + truncated + wrong-v + 2 dup + clean");
        assert_eq!(replies[0], Ok(Message::Ready));
        assert!(matches!(replies[1], Err(ParseError::Malformed(_))), "garbage: {:?}", replies[1]);
        assert!(matches!(replies[2], Err(ParseError::Malformed(_))), "truncated: {:?}", replies[2]);
        assert!(
            matches!(replies[3], Err(ParseError::Version { got }) if got == FLEET_PROTOCOL_VERSION + 1),
            "wrong-version: {:?}",
            replies[3]
        );
        assert_eq!(replies[4], replies[5], "duplicate replies are byte-identical");
        assert!(matches!(replies[4], Ok(Message::Result { index: 3, .. })));
        assert!(matches!(replies[6], Ok(Message::Result { index: 4, .. })));

        // crash: EOF right after ready, no reply for the assignment
        let opts = WorkerOpts {
            faults: FaultPlan::none().with(0, Fault::CrashBeforeReply),
            ..WorkerOpts::default()
        };
        let replies = drive(&bench, opts, vec![assign(0)]);
        assert_eq!(replies, vec![Ok(Message::Ready)]);

        // a start offset shifts which assignment the plan hits
        let opts = WorkerOpts {
            faults: FaultPlan::none().with(3, Fault::CrashBeforeReply),
            start_ordinal: 3,
            ..WorkerOpts::default()
        };
        let replies = drive(&bench, opts, vec![assign(0), assign(1)]);
        assert_eq!(replies, vec![Ok(Message::Ready)], "offset 3 makes the first assign ordinal 3");
    }

    #[test]
    fn hung_worker_exits_on_a_stale_lease_long_before_the_hang_cap() {
        let bench = Bench::new();
        let work = tiny_job(&bench);
        let of = crate::exec::suite_tasks(&work.work, work.problems).len();
        // a lease path that never exists: stale after the short timeout,
        // so the hung worker must exit within ~one lease deadline
        let lease_path = std::env::temp_dir().join(format!(
            "ucutlass_worker_{}_never_beats.lease",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&lease_path);
        let opts = WorkerOpts {
            faults: FaultPlan::none().with(0, Fault::HangPastDeadline),
            start_ordinal: 0,
            lease: Some(LeaseMonitor::new(&lease_path, Duration::from_millis(100))),
        };
        let t0 = std::time::Instant::now();
        let replies = drive(
            &bench,
            opts,
            vec![Message::Assign { job: "j".into(), index: 0, of, work: work.clone() }],
        );
        assert_eq!(replies, vec![Ok(Message::Ready)], "the hang swallows the assignment");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "a stale lease must end the hang well before HANG_CAP (took {:?})",
            t0.elapsed()
        );
    }
}
