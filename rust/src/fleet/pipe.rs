//! A std-only in-memory byte pipe (`Read`/`Write` halves over a shared
//! buffer) so the coordinator can drive *in-process* workers through the
//! exact same byte-stream protocol as subprocess workers (ADR-007).
//!
//! Fault-injection tests need to run hundreds of worker lifecycles —
//! spawning a real subprocess per lifecycle would dominate the suite, and
//! `std::io::pipe` landed too recently to rely on. Semantics mirror an OS
//! pipe where the protocol depends on it: dropping the writer delivers
//! EOF (`read` → 0) to the reader, dropping the reader makes writes fail
//! with `BrokenPipe` — so "worker crashed" and "coordinator killed us"
//! look identical in both harnesses.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::{Arc, Condvar, Mutex};

struct Shared {
    buf: Mutex<State>,
    readable: Condvar,
}

struct State {
    data: VecDeque<u8>,
    writer_gone: bool,
    reader_gone: bool,
}

/// Create a unidirectional pipe. A duplex link is two of these.
pub fn pipe() -> (PipeWriter, PipeReader) {
    let shared = Arc::new(Shared {
        buf: Mutex::new(State {
            data: VecDeque::new(),
            writer_gone: false,
            reader_gone: false,
        }),
        readable: Condvar::new(),
    });
    (PipeWriter(Arc::clone(&shared)), PipeReader(shared))
}

pub struct PipeWriter(Arc<Shared>);
pub struct PipeReader(Arc<Shared>);

impl Write for PipeWriter {
    fn write(&mut self, bytes: &[u8]) -> io::Result<usize> {
        let mut st = self.0.buf.lock().expect("pipe lock");
        if st.reader_gone {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "pipe reader dropped"));
        }
        st.data.extend(bytes);
        self.0.readable.notify_all();
        Ok(bytes.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut st = self.0.buf.lock().expect("pipe lock");
        st.writer_gone = true;
        self.0.readable.notify_all();
    }
}

impl Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self.0.buf.lock().expect("pipe lock");
        while st.data.is_empty() {
            if st.writer_gone {
                return Ok(0); // EOF
            }
            st = self.0.readable.wait(st).expect("pipe lock");
        }
        let n = st.data.len().min(out.len());
        for slot in out.iter_mut().take(n) {
            *slot = st.data.pop_front().expect("n bytes available");
        }
        Ok(n)
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut st = self.0.buf.lock().expect("pipe lock");
        st.reader_gone = true;
        st.data.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    #[test]
    fn bytes_cross_threads_and_eof_on_writer_drop() {
        let (mut w, r) = pipe();
        let t = std::thread::spawn(move || {
            let mut lines = Vec::new();
            let mut br = BufReader::new(r);
            let mut line = String::new();
            while br.read_line(&mut line).unwrap() > 0 {
                lines.push(line.trim_end().to_string());
                line.clear();
            }
            lines // read_line returning 0 is EOF from the dropped writer
        });
        w.write_all(b"hello\nworld\n").unwrap();
        drop(w);
        assert_eq!(t.join().unwrap(), vec!["hello", "world"]);
    }

    #[test]
    fn write_after_reader_drop_is_broken_pipe() {
        let (mut w, r) = pipe();
        drop(r);
        let err = w.write_all(b"x").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn blocked_reader_wakes_on_writer_drop() {
        // the crash path: a reader mid-wait must see EOF, not hang
        let (w, mut r) = pipe();
        let t = std::thread::spawn(move || {
            let mut buf = [0u8; 8];
            r.read(&mut buf).unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(w);
        assert_eq!(t.join().unwrap(), 0, "EOF, not a hang");
    }
}
