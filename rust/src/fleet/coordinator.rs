//! The fleet coordinator (ADR-007): drives N workers over the line
//! protocol, assigns shards with SOL-aware admission ordering, enforces
//! per-shard deadlines with bounded exponential backoff, re-issues
//! stragglers to idle workers (first completion wins, duplicates
//! discarded by shard identity), quarantines workers after K consecutive
//! failures, and merges shards incrementally as they land.
//!
//! The merged output is field-for-field identical to single-process
//! [`crate::exec::eval_variants`]: shards are partitions of the same
//! canonical task enumeration, and [`SuiteMerge`] *is* the `repro merge`
//! assembly — the golden shard/merge property of ADR-003 carries over by
//! construction, no matter which worker computed which shard in what
//! order, how many times a shard was retried, or which duplicate landed
//! first.
//!
//! Workers are reached through the [`WorkerLink`] trait with two
//! implementations: real subprocesses (`repro worker`, see
//! [`subprocess_worker_factory`]) and in-process threads over the
//! [`super::pipe`] harness ([`thread_worker_factory`]) running the same
//! [`worker_loop`] byte-for-byte — fault-injection tests exercise the
//! coordinator against genuine protocol traffic without paying a process
//! spawn per lifecycle.

use crate::eval::manifest::{SuiteMerge, SuiteWork};
use crate::eval::{EvalRequest, Evaluator};
use crate::exec::{suite_tasks, SuiteTask};
use crate::experiments::runner::Bench;
use crate::fleet::events::EventLog;
use crate::fleet::faults::FaultPlan;
use crate::fleet::pipe::{pipe, PipeWriter};
use crate::fleet::protocol::{
    read_line_capped, LineRead, Message, ParseError, MAX_LINE_BYTES,
};
use crate::fleet::worker::{worker_loop, WorkerOpts};
use crate::journal::RunJournal;
use crate::scheduler::{Policy, StopRule};
use crate::agent::RunLog;
use crate::util::fnv64;
use crate::util::json::Json;
use std::io::{BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What a link's reader delivered, tagged with the link's spawn token so
/// traffic from a killed predecessor can never be charged to its
/// replacement.
#[derive(Debug)]
pub enum WireEvent {
    Line(String),
    /// A reply exceeded [`MAX_LINE_BYTES`]; the reader resynced.
    Overlong(usize),
    Eof,
    Io(String),
}

/// A live connection to one worker. `send_line` delivers one protocol
/// line; `kill` terminates the worker (SIGKILL / kill-flag + EOF) — after
/// `kill`, remaining traffic from this link is stale by token.
pub trait WorkerLink: Send {
    fn send_line(&mut self, line: &str) -> Result<(), String>;
    fn kill(&mut self);
}

/// Spawns a worker for `slot`, resuming its fault plan at
/// `start_ordinal`, delivering reader events as `(token, event)` on `tx`.
pub type SpawnResult = Result<Box<dyn WorkerLink>, String>;

/// Fleet tuning. Defaults are meant for tests and the mini tier; the CLI
/// maps `--workers/--deadline-ms/--retries` onto this.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub workers: usize,
    /// Per-shard deadline: past it the shard is re-issued (straggler) and
    /// charged a failure; the original worker gets one more deadline of
    /// grace to deliver late before being killed.
    pub deadline: Duration,
    /// Failures a shard may accumulate beyond its first attempt.
    pub retries: usize,
    /// Consecutive failures that quarantine a worker slot.
    pub quarantine_after: usize,
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Shard count; `0` (the default) means one shard per task — the
    /// finest partition, which is what makes admission ordering and
    /// straggler re-issue meaningful.
    pub shards: usize,
    /// Admission policy: shards whose baselines sit inside this SOL band
    /// (little headroom left) are deprioritized ([`StopRule::sol_band`]).
    pub admission: Policy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            workers: 2,
            deadline: Duration::from_secs(30),
            retries: 3,
            quarantine_after: 3,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            shards: 0,
            admission: Policy { epsilon: 1.0, window: 0 },
        }
    }
}

/// Why a fleet run failed. Always in-band: the coordinator never panics
/// on worker misbehavior and never hangs past its retry budget.
#[derive(Debug)]
pub enum FleetError {
    Spawn(String),
    RetriesExhausted { shard: usize, failures: usize, last: String },
    AllWorkersDead { completed: usize, total: usize },
    Merge(String),
    Internal(String),
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Spawn(e) => write!(f, "spawning worker: {e}"),
            FleetError::RetriesExhausted { shard, failures, last } => {
                write!(f, "shard {shard} exhausted its retries ({failures} failures; last: {last})")
            }
            FleetError::AllWorkersDead { completed, total } => {
                write!(f, "all workers dead or quarantined with {completed}/{total} shards merged")
            }
            FleetError::Merge(e) => write!(f, "merging shards: {e}"),
            FleetError::Internal(e) => write!(f, "coordinator: {e}"),
        }
    }
}

/// Counters summarizing one fleet run (also derivable from the event log;
/// kept as plain numbers for `repro serve` output).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetStats {
    pub shards: usize,
    pub assigns: usize,
    pub retries: usize,
    pub timeouts: usize,
    pub duplicates: usize,
    pub respawns: usize,
    pub quarantines: usize,
    /// Shards replayed from a journal at resume (ADR-010) — landed by a
    /// predecessor coordinator, never re-assigned or re-measured.
    pub recovered: usize,
}

impl FleetStats {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("shards", self.shards)
            .set("assigns", self.assigns)
            .set("retries", self.retries)
            .set("timeouts", self.timeouts)
            .set("duplicates", self.duplicates)
            .set("respawns", self.respawns)
            .set("quarantines", self.quarantines)
            .set("recovered", self.recovered);
        o
    }
}

pub struct FleetOutcome {
    pub logs: Vec<RunLog>,
    pub stats: FleetStats,
}

/// SOL-budget-aware admission order (ADR-007): shards are issued ordered
/// by how many of their problems still have SOL headroom — a problem
/// whose *baseline* already sits inside the `(1+ε)` band above FP16 SOL
/// ([`StopRule::sol_band`]) has little left to win, so its work goes to
/// the back of the queue. Whole-variant tasks count headroom across every
/// problem. Ties break by shard index, so the order is deterministic and
/// a permutation of `0..of`.
pub fn admission_order(bench: &Bench, work: &SuiteWork, of: usize, policy: &Policy) -> Vec<usize> {
    let tasks = suite_tasks(&work.work, work.problems);
    let ev = bench.evaluator();
    let headroom: Vec<u64> = (0..bench.problems.len())
        .map(|p| {
            let t_ref = ev.eval(&EvalRequest::baseline(p)).value;
            u64::from(!StopRule::sol_band(policy, t_ref, bench.sols[p].t_sol_fp16_ms))
        })
        .collect();
    let task_headroom = |t: &SuiteTask| -> u64 {
        match t.problem {
            Some(p) => headroom[p],
            None => headroom.iter().sum(),
        }
    };
    // shard s of N owns task ranks r with r % N == s (ADR-003 partition)
    let mut order: Vec<(u64, usize)> = (0..of)
        .map(|s| {
            let h: u64 =
                tasks.iter().skip(s).step_by(of.max(1)).map(task_headroom).sum();
            (h, s)
        })
        .collect();
    order.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    order.into_iter().map(|(_, s)| s).collect()
}

struct Busy {
    shard: usize,
    deadline: Instant,
    /// Set once the deadline passed and the shard was re-issued: the
    /// straggler may still deliver (first completion wins) until this.
    grace: Option<Instant>,
}

struct Slot {
    link: Option<Box<dyn WorkerLink>>,
    token: u64,
    ready: bool,
    busy: Option<Busy>,
    /// Assignments issued to this slot across respawns — the replacement
    /// worker's `start_ordinal`, so a fault plan advances past faults
    /// already injected instead of replaying them forever.
    issued: u64,
    consecutive: usize,
    quarantined: bool,
}

impl Slot {
    fn live(&self) -> bool {
        self.link.is_some() && !self.quarantined
    }
}

struct ShardState {
    queued: bool,
    done: bool,
    failures: usize,
    not_before: Instant,
}

/// Run a suite evaluation across a fleet of workers. `factory` spawns one
/// worker: `(slot, start_ordinal, token, tx)` — deliver reader events as
/// `(token, event)` on `tx`. The merged logs are field-for-field what
/// `eval_variants(bench, &work.work, work.seed, 1)` produces.
pub fn run_fleet<F>(
    bench: &Bench,
    work: &SuiteWork,
    cfg: &FleetConfig,
    factory: F,
    events: &EventLog,
) -> Result<FleetOutcome, FleetError>
where
    F: FnMut(usize, u64, u64, Sender<(u64, WireEvent)>) -> SpawnResult,
{
    run_fleet_journaled(bench, work, cfg, factory, events, None)
}

/// [`run_fleet`] with an optional write-ahead run journal (ADR-010).
///
/// With a journal, the coordinator binds the run identity (writing a
/// fencing `coordinator` token), replays every journaled shard into the
/// merge *before spawning any worker* — recovered shards are never
/// re-assigned, so none of their measurements are re-paid — and then
/// journals each newly landed shard durably before merging it. `kill
/// -9` at any event-loop iteration therefore leaves a journal whose
/// resume produces output byte-identical to the uninterrupted run.
pub fn run_fleet_journaled<F>(
    bench: &Bench,
    work: &SuiteWork,
    cfg: &FleetConfig,
    mut factory: F,
    events: &EventLog,
    journal: Option<&RunJournal>,
) -> Result<FleetOutcome, FleetError>
where
    F: FnMut(usize, u64, u64, Sender<(u64, WireEvent)>) -> SpawnResult,
{
    let n_tasks = suite_tasks(&work.work, work.problems).len();
    if n_tasks == 0 {
        return Ok(FleetOutcome { logs: Vec::new(), stats: FleetStats::default() });
    }
    let of = if cfg.shards == 0 { n_tasks } else { cfg.shards.min(n_tasks) };
    let workers = cfg.workers.max(1);
    let job = format!("{:016x}", fnv64(work.to_json().to_string().as_bytes()));

    let (tx, rx): (Sender<(u64, WireEvent)>, Receiver<(u64, WireEvent)>) =
        std::sync::mpsc::channel();

    let now = Instant::now();
    let mut merge = SuiteMerge::new(work, of);
    let mut stats = FleetStats { shards: of, ..FleetStats::default() };
    let mut shards: Vec<ShardState> = (0..of)
        .map(|_| ShardState { queued: true, done: false, failures: 0, not_before: now })
        .collect();
    let mut queue: Vec<usize> = admission_order(bench, work, of, &cfg.admission);
    let mut next_token: u64 = 0;

    // Journal recovery happens before any worker exists: bind the run
    // identity (in-band refusal if the journal belongs to a different
    // run), replay landed shards into the merge, and drop them from the
    // queue — a recovered shard is never re-assigned, so no landed key
    // is ever re-measured. A journal of an already-complete run
    // reassembles its output without spawning a single worker.
    if let Some(j) = journal {
        let landed = j.bind("serve", &job, of).map_err(FleetError::Internal)?;
        for shard in &landed {
            // check() bounds-checks shard.index before we use it
            if let Err(e) = merge.check(shard) {
                return Err(FleetError::Internal(format!(
                    "replaying journaled shard {}: {e}",
                    shard.index
                )));
            }
            if let Err(e) = merge.add(shard) {
                return Err(FleetError::Internal(format!(
                    "replaying journaled shard {}: {e}",
                    shard.index
                )));
            }
            shards[shard.index].done = true;
            shards[shard.index].queued = false;
            stats.recovered += 1;
            events.emit("recovered", |e| {
                e.set("shard", shard.index);
            });
        }
        queue.retain(|&i| !shards[i].done);
        events.emit("journal", |e| {
            e.set("token", j.token()).set("recovered", stats.recovered);
        });
        if merge.complete() {
            j.record_done()
                .map_err(|e| FleetError::Internal(format!("journal done: {e}")))?;
            events.emit("done", |e| {
                e.set("shards", of);
            });
            let logs = merge.finish().map_err(FleetError::Merge)?;
            return Ok(FleetOutcome { logs, stats });
        }
    }

    let mut spawn = |slot_id: usize,
                     start: u64,
                     next_token: &mut u64,
                     events: &EventLog|
     -> Result<(Box<dyn WorkerLink>, u64), FleetError> {
        let token = *next_token;
        *next_token += 1;
        let link = factory(slot_id, start, token, tx.clone()).map_err(FleetError::Spawn)?;
        events.emit("spawn", |e| {
            e.set("slot", slot_id).set("token", token).set("start_ordinal", start);
        });
        Ok((link, token))
    };

    let mut slots: Vec<Slot> = Vec::with_capacity(workers);
    for s in 0..workers {
        let (link, token) = spawn(s, 0, &mut next_token, events)?;
        slots.push(Slot {
            link: Some(link),
            token,
            ready: false,
            busy: None,
            issued: 0,
            consecutive: 0,
            quarantined: false,
        });
    }

    // Charge one failure to a shard; past the retry budget the run aborts.
    let charge =
        |shards: &mut Vec<ShardState>,
         queue: &mut Vec<usize>,
         stats: &mut FleetStats,
         cfg: &FleetConfig,
         index: usize,
         why: &str,
         events: &EventLog|
         -> Result<(), FleetError> {
            let st = &mut shards[index];
            if st.done {
                return Ok(()); // stale: landed elsewhere already
            }
            st.failures += 1;
            if st.failures > cfg.retries {
                return Err(FleetError::RetriesExhausted {
                    shard: index,
                    failures: st.failures,
                    last: why.to_string(),
                });
            }
            let backoff = cfg
                .backoff_base
                .saturating_mul(1u32 << (st.failures - 1).min(6))
                .min(cfg.backoff_cap);
            st.not_before = Instant::now() + backoff;
            stats.retries += 1;
            events.emit("retry", |e| {
                e.set("shard", index)
                    .set("failures", st.failures)
                    .set("backoff_ms", backoff.as_millis() as u64)
                    .set("why", why);
            });
            if !st.queued {
                st.queued = true;
                queue.push(index);
            }
            Ok(())
        };

    // Worker failure accounting: one more consecutive failure; at the
    // quarantine threshold the slot is retired, otherwise (if `respawn`)
    // it gets a replacement worker resuming its fault plan.
    enum WorkerFate {
        Quarantined,
        Kept,
    }
    let account = |slot: &mut Slot,
                   slot_id: usize,
                   stats: &mut FleetStats,
                   cfg: &FleetConfig,
                   why: &str,
                   events: &EventLog|
     -> WorkerFate {
        slot.consecutive += 1;
        if slot.consecutive >= cfg.quarantine_after {
            slot.quarantined = true;
            if let Some(mut link) = slot.link.take() {
                link.kill();
            }
            slot.busy = None;
            slot.ready = false;
            stats.quarantines += 1;
            events.emit("quarantine", |e| {
                e.set("slot", slot_id).set("consecutive", slot.consecutive).set("why", why);
            });
            WorkerFate::Quarantined
        } else {
            WorkerFate::Kept
        }
    };

    let finish = |slots: &mut Vec<Slot>| {
        for slot in slots.iter_mut() {
            if let Some(mut link) = slot.link.take() {
                let _ = link.send_line(&Message::Shutdown.to_line());
                link.kill();
            }
        }
    };

    loop {
        let now = Instant::now();

        // 1. deadlines and straggler grace
        for s in 0..slots.len() {
            let (index, deadline, grace) = match &slots[s].busy {
                Some(b) => (b.shard, b.deadline, b.grace),
                None => continue,
            };
            if grace.is_none() && now >= deadline {
                if let Some(b) = slots[s].busy.as_mut() {
                    b.grace = Some(now + cfg.deadline);
                }
                stats.timeouts += 1;
                events.emit("timeout", |e| {
                    e.set("slot", s).set("shard", index);
                });
                if let Err(e) =
                    charge(&mut shards, &mut queue, &mut stats, cfg, index, "deadline", events)
                {
                    finish(&mut slots);
                    return Err(e);
                }
            } else if grace.is_some_and(|g| now >= g) {
                // the straggler never delivered: kill and respawn
                events.emit("straggler-kill", |e| {
                    e.set("slot", s).set("shard", index);
                });
                slots[s].busy = None;
                if let Some(mut link) = slots[s].link.take() {
                    link.kill();
                }
                slots[s].ready = false;
                if let WorkerFate::Kept =
                    account(&mut slots[s], s, &mut stats, cfg, "straggler", events)
                {
                    let issued = slots[s].issued;
                    let (link, token) = spawn(s, issued, &mut next_token, events)?;
                    slots[s].link = Some(link);
                    slots[s].token = token;
                    stats.respawns += 1;
                    events.emit("respawn", |e| {
                        e.set("slot", s).set("start_ordinal", issued);
                    });
                }
            }
        }

        // 2. done?
        if merge.complete() {
            finish(&mut slots);
            if let Some(j) = journal {
                j.record_done()
                    .map_err(|e| FleetError::Internal(format!("journal done: {e}")))?;
            }
            events.emit("done", |e| {
                e.set("shards", of);
            });
            let logs = merge.finish().map_err(FleetError::Merge)?;
            return Ok(FleetOutcome { logs, stats });
        }

        // 3. assign idle ready workers, in admission order
        let now = Instant::now();
        for s in 0..slots.len() {
            if !slots[s].live() || !slots[s].ready || slots[s].busy.is_some() {
                continue;
            }
            // first eligible shard in admission order (skip backoffs)
            let Some(qpos) = queue
                .iter()
                .position(|&i| !shards[i].done && now >= shards[i].not_before)
            else {
                break;
            };
            let index = queue.remove(qpos);
            shards[index].queued = false;
            let msg = Message::Assign {
                job: job.clone(),
                index,
                of,
                work: work.clone(),
            };
            slots[s].busy = Some(Busy { shard: index, deadline: now + cfg.deadline, grace: None });
            slots[s].issued += 1;
            stats.assigns += 1;
            events.emit("assign", |e| {
                e.set("slot", s).set("shard", index).set("of", of);
            });
            // A failed send means the worker died between events; its
            // reader's Eof is already in flight and will do the crash
            // accounting (shard failure + respawn/quarantine) exactly once.
            if let Some(link) = slots[s].link.as_mut() {
                let _ = link.send_line(&msg.to_line());
            }
        }

        // 4. graceful degradation floor: anything left to do but nobody
        // alive to do it is an in-band error, not a hang
        if !slots.iter().any(|s| s.live()) {
            let completed = (0..of).filter(|&i| shards[i].done).count();
            finish(&mut slots);
            return Err(FleetError::AllWorkersDead { completed, total: of });
        }

        // 5. wait for traffic, bounded by the nearest timer
        let now = Instant::now();
        let mut wait = Duration::from_millis(100);
        for slot in &slots {
            if let Some(b) = &slot.busy {
                let t = b.grace.unwrap_or(b.deadline);
                wait = wait.min(t.saturating_duration_since(now));
            }
        }
        for st in shards.iter().filter(|st| st.queued && !st.done) {
            wait = wait.min(st.not_before.saturating_duration_since(now));
        }
        let (token, event) = match rx.recv_timeout(wait.max(Duration::from_millis(1))) {
            Ok(ev) => ev,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                return Err(FleetError::Internal("event channel closed".into()))
            }
        };
        let Some(s) = slots.iter().position(|sl| sl.token == token && sl.link.is_some()) else {
            continue; // stale traffic from a killed predecessor
        };

        // a failure of whatever the slot was working on: charge the shard,
        // account the worker, optionally respawn (crash) or not (protocol
        // noise from a live worker)
        macro_rules! failed_assignment {
            ($why:expr, $respawn:expr, $quarantine_now:expr) => {{
                let why: &str = $why;
                if let Some(b) = slots[s].busy.take() {
                    if let Err(e) =
                        charge(&mut shards, &mut queue, &mut stats, cfg, b.shard, why, events)
                    {
                        finish(&mut slots);
                        return Err(e);
                    }
                }
                if $quarantine_now {
                    // wrong-build worker: force the next failure over the
                    // quarantine threshold
                    slots[s].consecutive = cfg.quarantine_after.max(1) - 1;
                }
                let fate = account(&mut slots[s], s, &mut stats, cfg, why, events);
                if $respawn {
                    if let WorkerFate::Kept = fate {
                        slots[s].link = None;
                        slots[s].ready = false;
                        let issued = slots[s].issued;
                        let (link, token) = spawn(s, issued, &mut next_token, events)?;
                        slots[s].link = Some(link);
                        slots[s].token = token;
                        stats.respawns += 1;
                        events.emit("respawn", |e| {
                            e.set("slot", s).set("start_ordinal", issued);
                        });
                    }
                }
            }};
        }

        match event {
            WireEvent::Line(line) => match Message::from_line(&line) {
                Ok(Message::Ready) => {
                    slots[s].ready = true;
                    events.emit("ready", |e| {
                        e.set("slot", s);
                    });
                }
                Ok(Message::Result { job: rjob, index, of: rof, shard }) => {
                    // the envelope must agree with the embedded shard:
                    // `merge.add` bounds-checks shard.index, and this pins
                    // `index` to it, so `shards[index]` below cannot be
                    // out of range even for a hostile reply
                    if rjob != job || rof != of || index != shard.index {
                        events.emit("stale-result", |e| {
                            e.set("slot", s).set("job", rjob.as_str());
                        });
                        continue;
                    }
                    if merge.landed(index) {
                        // first completion won already (straggler re-issue
                        // or a scripted duplicate reply): discard by
                        // shard identity, no failure charged
                        stats.duplicates += 1;
                        events.emit("duplicate", |e| {
                            e.set("slot", s).set("shard", index);
                        });
                        if slots[s].busy.as_ref().map(|b| b.shard) == Some(index) {
                            slots[s].busy = None;
                            slots[s].consecutive = 0;
                        }
                        continue;
                    }
                    // write-ahead discipline (ADR-010): validate first
                    // (a hostile shard must never reach the journal),
                    // journal durably, only then merge. A journal append
                    // failure aborts the run in-band — continuing
                    // un-journaled would break the resume guarantee.
                    match merge.check(&shard) {
                        Ok(()) => {
                            if let Some(j) = journal {
                                if let Err(e) = j.record_shard(&shard) {
                                    finish(&mut slots);
                                    return Err(FleetError::Internal(format!(
                                        "journal append: {e}"
                                    )));
                                }
                            }
                            if let Err(e) = merge.add(&shard) {
                                finish(&mut slots);
                                return Err(FleetError::Internal(format!(
                                    "merge after successful check: {e}"
                                )));
                            }
                            shards[index].done = true;
                            events.emit("merge", |e| {
                                e.set("slot", s)
                                    .set("shard", index)
                                    .set("landed", (0..of).filter(|&i| shards[i].done).count());
                            });
                            if slots[s].busy.as_ref().map(|b| b.shard) == Some(index) {
                                slots[s].busy = None;
                            }
                            slots[s].consecutive = 0;
                        }
                        Err(e) => {
                            failed_assignment!(&format!("bad shard: {e}"), false, false)
                        }
                    }
                }
                Ok(Message::Error { detail, .. }) => {
                    events.emit("worker-error", |e| {
                        e.set("slot", s).set("detail", detail.as_str());
                    });
                    failed_assignment!(&format!("worker error: {detail}"), false, false)
                }
                Ok(other) => {
                    failed_assignment!(&format!("unexpected {} from worker", other_kind(&other)), false, false)
                }
                Err(ParseError::Version { got }) => {
                    // a wrong-build worker: retrying it is hopeless, so it
                    // goes straight to quarantine
                    events.emit("parse-error", |e| {
                        e.set("slot", s).set("detail", format!("protocol version {got}"));
                    });
                    failed_assignment!(&format!("protocol version {got}"), false, true)
                }
                Err(ParseError::Malformed(e)) => {
                    events.emit("parse-error", |e2| {
                        e2.set("slot", s).set("detail", e.as_str());
                    });
                    failed_assignment!(&format!("malformed reply: {e}"), false, false)
                }
            },
            WireEvent::Overlong(n) => {
                events.emit("parse-error", |e| {
                    e.set("slot", s).set("detail", format!("overlong reply ({n} bytes)"));
                });
                failed_assignment!("overlong reply", false, false)
            }
            WireEvent::Eof | WireEvent::Io(_) => {
                let why = match &event {
                    WireEvent::Io(e) => format!("worker i/o: {e}"),
                    _ => "worker exited".to_string(),
                };
                events.emit("crash", |e| {
                    e.set("slot", s).set("why", why.as_str());
                });
                if let Some(mut link) = slots[s].link.take() {
                    link.kill();
                }
                slots[s].ready = false;
                failed_assignment!(&why, true, false)
            }
        }
    }
}

fn other_kind(m: &Message) -> &'static str {
    match m {
        Message::Ready => "ready",
        Message::Assign { .. } => "assign",
        Message::Result { .. } => "result",
        Message::Error { .. } => "error",
        Message::Shutdown => "shutdown",
    }
}

// ---------------------------------------------------------------------------
// subprocess workers (`repro worker`)
// ---------------------------------------------------------------------------

struct ProcessLink {
    child: std::process::Child,
    stdin: Option<std::process::ChildStdin>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl WorkerLink for ProcessLink {
    fn send_line(&mut self, line: &str) -> Result<(), String> {
        let stdin = self.stdin.as_mut().ok_or("worker stdin closed")?;
        stdin
            .write_all(line.as_bytes())
            .and_then(|_| stdin.flush())
            .map_err(|e| format!("worker stdin: {e}"))
    }

    fn kill(&mut self) {
        self.stdin = None; // EOF first, for a clean exit
        let _ = self.child.kill();
        let _ = self.child.wait();
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

impl Drop for ProcessLink {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Spawn `repro worker` subprocesses from `exe` (normally
/// `std::env::current_exe()`), one per slot, forwarding each slot's fault
/// spec (empty string = well-behaved) and the respawn `--fault-offset`.
/// `extra_args` are appended to every worker's command line verbatim —
/// `repro serve` uses this to hand workers the shared eval cache
/// (`--cache PATH [--offline]`, ADR-008) so no fleet node re-measures a
/// landed key.
pub fn subprocess_worker_factory(
    exe: std::path::PathBuf,
    fault_specs: Vec<String>,
    extra_args: Vec<String>,
) -> impl FnMut(usize, u64, u64, Sender<(u64, WireEvent)>) -> SpawnResult {
    move |slot, start_ordinal, token, tx| {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker");
        if let Some(spec) = fault_specs.get(slot).filter(|s| !s.is_empty()) {
            cmd.arg("--faults").arg(spec);
        }
        if start_ordinal > 0 {
            cmd.arg("--fault-offset").arg(start_ordinal.to_string());
        }
        for a in &extra_args {
            cmd.arg(a);
        }
        cmd.stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit());
        let mut child = cmd.spawn().map_err(|e| format!("spawn {}: {e}", exe.display()))?;
        let stdin = child.stdin.take().ok_or("no worker stdin")?;
        let stdout = child.stdout.take().ok_or("no worker stdout")?;
        let reader = std::thread::spawn(move || {
            let mut r = BufReader::new(stdout);
            loop {
                match read_line_capped(&mut r, MAX_LINE_BYTES) {
                    Ok(LineRead::Eof) => {
                        let _ = tx.send((token, WireEvent::Eof));
                        break;
                    }
                    Ok(LineRead::Line(l)) => {
                        if tx.send((token, WireEvent::Line(l))).is_err() {
                            break;
                        }
                    }
                    Ok(LineRead::Overlong { discarded }) => {
                        if tx.send((token, WireEvent::Overlong(discarded))).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send((token, WireEvent::Io(e.to_string())));
                        break;
                    }
                }
            }
        });
        Ok(Box::new(ProcessLink { child, stdin: Some(stdin), reader: Some(reader) }))
    }
}

// ---------------------------------------------------------------------------
// in-process workers (threads over the pipe harness)
// ---------------------------------------------------------------------------

struct ThreadLink {
    writer: Option<PipeWriter>,
    kill_flag: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl WorkerLink for ThreadLink {
    fn send_line(&mut self, line: &str) -> Result<(), String> {
        let w = self.writer.as_mut().ok_or("worker input closed")?;
        w.write_all(line.as_bytes()).map_err(|e| format!("worker input: {e}"))
    }

    fn kill(&mut self) {
        // flag first (a hung worker polls it), then EOF its input
        self.kill_flag.store(true, Ordering::Relaxed);
        self.writer = None;
    }
}

impl Drop for ThreadLink {
    fn drop(&mut self) {
        self.kill();
        // joins are bounded: killed workers exit at their next kill-flag
        // poll / EOF read, and the reader ends at the worker's EOF
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// In-process worker fleet: each slot runs [`worker_loop`] on a thread
/// over a pair of in-memory pipes — the same bytes, framing, faults, and
/// crash semantics as subprocess workers, minus the process spawn.
pub fn thread_worker_factory(
    bench: Arc<Bench>,
    plans: Vec<FaultPlan>,
) -> impl FnMut(usize, u64, u64, Sender<(u64, WireEvent)>) -> SpawnResult {
    move |slot, start_ordinal, token, tx| {
        let (coord_w, worker_r) = pipe();
        let (worker_w, coord_r) = pipe();
        let kill_flag = Arc::new(AtomicBool::new(false));
        let opts = WorkerOpts {
            faults: plans.get(slot).cloned().unwrap_or_default(),
            start_ordinal,
            lease: None,
        };
        let bench = Arc::clone(&bench);
        let kf = Arc::clone(&kill_flag);
        let worker = std::thread::spawn(move || {
            let _ = worker_loop(&bench, BufReader::new(worker_r), worker_w, &opts, &kf);
        });
        let reader = std::thread::spawn(move || {
            let mut r = BufReader::new(coord_r);
            loop {
                match read_line_capped(&mut r, MAX_LINE_BYTES) {
                    Ok(LineRead::Eof) => {
                        let _ = tx.send((token, WireEvent::Eof));
                        break;
                    }
                    Ok(LineRead::Line(l)) => {
                        if tx.send((token, WireEvent::Line(l))).is_err() {
                            break;
                        }
                    }
                    Ok(LineRead::Overlong { discarded }) => {
                        if tx.send((token, WireEvent::Overlong(discarded))).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send((token, WireEvent::Io(e.to_string())));
                        break;
                    }
                }
            }
        });
        Ok(Box::new(ThreadLink {
            writer: Some(coord_w),
            kill_flag,
            worker: Some(worker),
            reader: Some(reader),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::{ControllerKind, VariantSpec};
    use crate::agent::ModelTier;
    use crate::exec::eval_variants;
    use crate::mantis::MantisConfig;

    fn fast_cfg(workers: usize) -> FleetConfig {
        FleetConfig {
            workers,
            deadline: Duration::from_secs(20),
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(20),
            ..FleetConfig::default()
        }
    }

    fn mini_work() -> SuiteWork {
        let bench_problems = crate::kernelbench::suite().len();
        SuiteWork::single(
            VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
            None,
            11,
            bench_problems,
        )
    }

    /// Work with both independent per-problem tasks and one
    /// sequentially-coupled whole-variant task (orchestrated + xmem).
    fn mixed_work() -> SuiteWork {
        let problems = crate::kernelbench::suite().len();
        SuiteWork {
            seed: 11,
            problems,
            work: vec![
                (VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini), None),
                (
                    VariantSpec::new(ControllerKind::OrchestratedSol, false, ModelTier::Mini),
                    Some(MantisConfig::default()),
                ),
            ],
        }
    }

    fn golden(bench: &Bench, work: &SuiteWork) -> String {
        let logs = eval_variants(bench, &work.work, work.seed, 1);
        Json::from(logs.iter().map(|l| l.to_json()).collect::<Vec<_>>()).to_string()
    }

    fn fleet_json(out: &FleetOutcome) -> String {
        Json::from(out.logs.iter().map(|l| l.to_json()).collect::<Vec<_>>()).to_string()
    }

    fn run_threads(
        work: &SuiteWork,
        cfg: &FleetConfig,
        plans: Vec<FaultPlan>,
    ) -> (Result<FleetOutcome, FleetError>, EventLog) {
        let bench = Arc::new(Bench::new());
        let events = EventLog::new();
        let out = run_fleet(
            &bench,
            work,
            cfg,
            thread_worker_factory(Arc::clone(&bench), plans),
            &events,
        );
        (out, events)
    }

    #[test]
    fn faultless_fleet_matches_eval_variants_byte_for_byte() {
        let work = mini_work();
        let cfg = fast_cfg(3);
        let (out, events) = run_threads(&work, &cfg, vec![FaultPlan::none(); 3]);
        let out = out.expect("faultless fleet converges");
        let bench = Bench::new();
        assert_eq!(fleet_json(&out), golden(&bench, &work));
        assert_eq!(out.stats.retries, 0);
        assert_eq!(out.stats.quarantines, 0);
        assert_eq!(events.count("merge"), out.stats.shards);
    }

    #[test]
    fn mixed_work_with_whole_variant_task_is_golden() {
        let work = mixed_work();
        let cfg = fast_cfg(2);
        let (out, _) = run_threads(&work, &cfg, vec![FaultPlan::none(); 2]);
        let out = out.expect("fleet converges");
        let bench = Bench::new();
        assert_eq!(
            fleet_json(&out),
            golden(&bench, &work),
            "sequentially-coupled variants must survive the fleet unchanged"
        );
    }

    #[test]
    fn every_scripted_fault_kind_converges_to_golden_output() {
        use crate::fleet::faults::Fault;
        let work = mini_work();
        let bench = Bench::new();
        let want = golden(&bench, &work);
        for fault in [
            Fault::CrashBeforeReply,
            Fault::TruncatedLine,
            Fault::GarbageLine,
            Fault::WrongVersion,
            Fault::DuplicateReply,
        ] {
            let plans =
                vec![FaultPlan::none().with(0, fault).with(2, fault), FaultPlan::none()];
            let cfg = fast_cfg(2);
            let (out, events) = run_threads(&work, &cfg, plans);
            let out =
                out.unwrap_or_else(|e| panic!("fleet must converge under {fault:?}: {e}"));
            assert_eq!(fleet_json(&out), want, "golden output under {fault:?}");
            if fault == Fault::CrashBeforeReply {
                assert!(events.count("respawn") >= 1, "crashes must respawn");
            }
            if fault == Fault::DuplicateReply {
                assert!(out.stats.duplicates >= 2, "duplicates must be discarded, not merged");
            }
        }
    }

    #[test]
    fn scripted_mixed_fault_storm_converges() {
        // a deterministic multi-kind schedule across both workers, plus a
        // seeded random plan on top (rate kept under the retry budget)
        let work = mini_work();
        let bench = Bench::new();
        let want = golden(&bench, &work);
        let plans = vec![
            FaultPlan::parse("0:crash,3:garbage,5:truncate,9:duplicate").unwrap(),
            FaultPlan::parse("1:wrong-version,4:crash,8:duplicate").unwrap(),
        ];
        let cfg = fast_cfg(2);
        let (out, _) = run_threads(&work, &cfg, plans);
        assert_eq!(fleet_json(&out.expect("storm converges")), want);
    }

    #[test]
    fn hanging_worker_is_reissued_and_killed() {
        let work = mini_work();
        let bench = Bench::new();
        let want = golden(&bench, &work);
        let plans = vec![FaultPlan::none().with(0, crate::fleet::faults::Fault::HangPastDeadline), FaultPlan::none()];
        let cfg = FleetConfig {
            deadline: Duration::from_millis(300),
            ..fast_cfg(2)
        };
        let (out, events) = run_threads(&work, &cfg, plans);
        let out = out.expect("hang must not wedge the fleet");
        assert_eq!(fleet_json(&out), want);
        assert!(out.stats.timeouts >= 1, "the hang must time out");
        assert!(
            events.count("straggler-kill") >= 1,
            "a never-delivering straggler must be killed"
        );
    }

    #[test]
    fn all_workers_dead_is_an_in_band_error() {
        let work = mini_work();
        // one worker whose replacement crashes too, forever — with
        // quarantine_after=2 the slot dies after two crashes
        let horizon = 64;
        let mut plan = FaultPlan::none();
        for i in 0..horizon {
            plan = plan.with(i, crate::fleet::faults::Fault::CrashBeforeReply);
        }
        let cfg = FleetConfig { quarantine_after: 2, ..fast_cfg(1) };
        let (out, events) = run_threads(&work, &cfg, vec![plan]);
        match out {
            Err(FleetError::AllWorkersDead { completed, total }) => {
                assert_eq!(completed, 0);
                assert!(total > 0);
            }
            other => panic!("expected AllWorkersDead, got {:?}", other.map(|o| o.stats)),
        }
        assert_eq!(events.count("quarantine"), 1);
    }

    #[test]
    fn retries_exhausted_is_an_in_band_error() {
        let work = mini_work();
        // worker 0 garbages every single assignment; retries=0 means the
        // first failure of any shard aborts the run
        let mut plan = FaultPlan::none();
        for i in 0..64 {
            plan = plan.with(i, crate::fleet::faults::Fault::GarbageLine);
        }
        let cfg = FleetConfig { retries: 0, quarantine_after: 100, ..fast_cfg(1) };
        let (out, _) = run_threads(&work, &cfg, vec![plan]);
        match out {
            Err(FleetError::RetriesExhausted { failures, .. }) => assert_eq!(failures, 1),
            other => panic!("expected RetriesExhausted, got {:?}", other.map(|o| o.stats)),
        }
    }

    #[test]
    fn quarantine_degrades_gracefully_to_the_healthy_worker() {
        let work = mini_work();
        let bench = Bench::new();
        let want = golden(&bench, &work);
        // slot 0 garbage-replies its first 3 assignments (with respawn not
        // triggered — garbage is protocol noise from a live worker), so it
        // hits quarantine_after=3 and the healthy slot 1 finishes the job
        let plan0 = FaultPlan::parse("0:garbage,1:garbage,2:garbage").unwrap();
        let cfg = FleetConfig { quarantine_after: 3, retries: 5, ..fast_cfg(2) };
        let (out, events) = run_threads(&work, &cfg, vec![plan0, FaultPlan::none()]);
        let out = out.expect("healthy worker carries the fleet");
        assert_eq!(fleet_json(&out), want);
        assert_eq!(out.stats.quarantines, 1);
        assert_eq!(events.count("quarantine"), 1);
    }

    #[test]
    fn admission_order_is_a_sol_sorted_permutation() {
        let bench = Bench::new();
        let work = mini_work();
        let of = suite_tasks(&work.work, work.problems).len();
        let policy = Policy { epsilon: 1.0, window: 0 };
        let order = admission_order(&bench, &work, of, &policy);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..of).collect::<Vec<_>>(), "a permutation of all shards");

        // headroom must be non-increasing along the order
        let ev = bench.evaluator();
        let head: Vec<u64> = (0..bench.problems.len())
            .map(|p| {
                let t_ref = ev.eval(&EvalRequest::baseline(p)).value;
                u64::from(!StopRule::sol_band(&policy, t_ref, bench.sols[p].t_sol_fp16_ms))
            })
            .collect();
        let hs: Vec<u64> = order.iter().map(|&s| head[s]).collect();
        assert!(hs.windows(2).all(|w| w[0] >= w[1]), "headroom-descending: {hs:?}");
        // ε=off deprioritizes nothing: pure index order
        let fixed = admission_order(&bench, &work, of, &Policy::fixed());
        assert_eq!(fixed, (0..of).collect::<Vec<_>>());
    }

    #[test]
    fn journaled_run_is_golden_and_a_done_resume_spawns_no_workers() {
        use crate::journal::RunJournal;
        let p = std::env::temp_dir()
            .join(format!("ucutlass_coord_{}_done.journal", std::process::id()));
        let _ = std::fs::remove_file(&p);
        let work = mini_work();
        let cfg = fast_cfg(2);
        let bench = Arc::new(Bench::new());
        let want = golden(&bench, &work);

        let journal = RunJournal::create(&p).unwrap();
        let events = EventLog::new();
        let out = run_fleet_journaled(
            &bench,
            &work,
            &cfg,
            thread_worker_factory(Arc::clone(&bench), vec![FaultPlan::none(); 2]),
            &events,
            Some(&journal),
        )
        .expect("journaled fleet converges");
        assert_eq!(fleet_json(&out), want, "journaling must not change the output");
        assert_eq!(out.stats.recovered, 0);
        drop(journal);

        // resuming a *done* journal must reassemble the output without
        // spawning a single worker or assigning a single shard
        let journal = RunJournal::resume(&p).unwrap();
        assert!(journal.done());
        let events = EventLog::new();
        let out = run_fleet_journaled(
            &bench,
            &work,
            &cfg,
            |_, _, _, _| -> SpawnResult {
                panic!("a done journal must not spawn workers")
            },
            &events,
            Some(&journal),
        )
        .expect("done resume reassembles");
        assert_eq!(fleet_json(&out), want);
        assert_eq!(out.stats.recovered, out.stats.shards);
        assert_eq!(out.stats.assigns, 0);
        assert_eq!(events.count("assign"), 0);
        assert_eq!(events.count("recovered"), out.stats.shards);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn journal_bound_to_a_different_work_spec_is_refused_in_band() {
        use crate::journal::RunJournal;
        let p = std::env::temp_dir()
            .join(format!("ucutlass_coord_{}_ident.journal", std::process::id()));
        let _ = std::fs::remove_file(&p);
        let bench = Arc::new(Bench::new());
        let cfg = fast_cfg(2);
        {
            let journal = RunJournal::create(&p).unwrap();
            let events = EventLog::new();
            run_fleet_journaled(
                &bench,
                &mini_work(),
                &cfg,
                thread_worker_factory(Arc::clone(&bench), vec![FaultPlan::none(); 2]),
                &events,
                Some(&journal),
            )
            .expect("fleet converges");
        }
        // same journal, different work: the seed differs, so the job
        // hash differs, and bind must refuse before spawning anything
        let journal = RunJournal::resume(&p).unwrap();
        let mut other = mini_work();
        other.seed = 12;
        let events = EventLog::new();
        let err = run_fleet_journaled(
            &bench,
            &other,
            &cfg,
            |_, _, _, _| -> SpawnResult { panic!("must refuse before spawning") },
            &events,
            Some(&journal),
        );
        match err {
            Err(FleetError::Internal(e)) => {
                assert!(e.contains("different run"), "got: {e}")
            }
            other => panic!("expected Internal, got {:?}", other.map(|o| o.stats)),
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_work_short_circuits() {
        let bench = Arc::new(Bench::new());
        let work = SuiteWork { seed: 1, problems: bench.problems.len(), work: Vec::new() };
        let events = EventLog::new();
        let out = run_fleet(
            &bench,
            &work,
            &fast_cfg(2),
            thread_worker_factory(Arc::clone(&bench), Vec::new()),
            &events,
        )
        .expect("empty work is trivially complete");
        assert!(out.logs.is_empty());
        assert_eq!(out.stats.shards, 0);
    }
}
