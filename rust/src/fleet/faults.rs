//! Deterministic fault injection for the fleet (ADR-007).
//!
//! A [`FaultPlan`] scripts, per worker *slot*, which of its assignment
//! ordinals misbehave and how. Plans are data: written by hand in tests,
//! parsed from a `--faults` spec on the CLI, or derived from the seeded
//! RNG streams of ADR-002 (`Pcg32::derive(seed, &[stream::FAULT, slot])`),
//! so a fault schedule is exactly reproducible across runs and across the
//! in-process and subprocess worker harnesses.
//!
//! Ordinals count assignments **per slot across respawns**: when the
//! coordinator respawns a crashed worker it passes the number of
//! assignments already issued to that slot (`--fault-offset`), so the
//! replacement resumes the plan where its predecessor died instead of
//! replaying the same fault forever. A plan with F faults therefore
//! injects exactly F faults, which is what makes convergence under a
//! scripted plan a provable property rather than a probabilistic one.

use crate::util::rng::{stream, Pcg32};
use std::collections::BTreeMap;

/// One scripted misbehavior, applied to a single assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Exit before replying (subprocess: process exits; in-process: the
    /// worker loop returns). The coordinator sees EOF.
    CrashBeforeReply,
    /// Never reply, ignoring the deadline; the coordinator must re-issue
    /// the shard and eventually kill this worker.
    HangPastDeadline,
    /// Reply with the real result line cut off mid-JSON.
    TruncatedLine,
    /// Reply with non-UTF-8 line noise.
    GarbageLine,
    /// Reply with a correct result wrapped in the wrong protocol version.
    WrongVersion,
    /// Reply correctly, twice (first-completion-wins must discard one).
    DuplicateReply,
}

pub const ALL_FAULTS: [Fault; 6] = [
    Fault::CrashBeforeReply,
    Fault::HangPastDeadline,
    Fault::TruncatedLine,
    Fault::GarbageLine,
    Fault::WrongVersion,
    Fault::DuplicateReply,
];

impl Fault {
    pub fn name(&self) -> &'static str {
        match self {
            Fault::CrashBeforeReply => "crash",
            Fault::HangPastDeadline => "hang",
            Fault::TruncatedLine => "truncate",
            Fault::GarbageLine => "garbage",
            Fault::WrongVersion => "wrong-version",
            Fault::DuplicateReply => "duplicate",
        }
    }

    pub fn parse(s: &str) -> Result<Fault, String> {
        ALL_FAULTS
            .iter()
            .find(|f| f.name() == s)
            .copied()
            .ok_or_else(|| format!("unknown fault `{s}` (crash|hang|truncate|garbage|wrong-version|duplicate)"))
    }
}

/// Which assignment ordinals of one worker slot misbehave, and how.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: BTreeMap<u64, Fault>,
}

impl FaultPlan {
    /// The well-behaved plan.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Builder: fault the assignment with this ordinal.
    pub fn with(mut self, ordinal: u64, fault: Fault) -> FaultPlan {
        self.faults.insert(ordinal, fault);
        self
    }

    /// The scripted fault for one assignment ordinal, if any.
    pub fn fault_at(&self, ordinal: u64) -> Option<Fault> {
        self.faults.get(&ordinal).copied()
    }

    /// Parse a spec like `"0:crash,3:garbage"` (ordinal:fault pairs).
    /// The empty string is the well-behaved plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::none();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (ord, name) = part
                .trim()
                .split_once(':')
                .ok_or_else(|| format!("fault spec `{part}`: expected ORDINAL:FAULT"))?;
            let ordinal: u64 =
                ord.parse().map_err(|_| format!("fault spec `{part}`: bad ordinal `{ord}`"))?;
            if plan.faults.insert(ordinal, Fault::parse(name)?).is_some() {
                return Err(format!("fault spec: duplicate ordinal {ordinal}"));
            }
        }
        Ok(plan)
    }

    /// Inverse of [`parse`]: `"0:crash,3:garbage"`.
    pub fn spec(&self) -> String {
        self.faults
            .iter()
            .map(|(o, f)| format!("{o}:{}", f.name()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Script a plan from the fleet's dedicated RNG stream: each of the
    /// first `horizon` ordinals faults with probability `rate`, the fault
    /// kind drawn uniformly. Same `(seed, slot)` → same plan, different
    /// slots → independent streams (ADR-002 derivation discipline).
    pub fn scripted(seed: u64, slot: u64, horizon: u64, rate: f64) -> FaultPlan {
        let mut rng = Pcg32::derive(seed, &[stream::FAULT, slot]);
        let mut plan = FaultPlan::none();
        for ordinal in 0..horizon {
            if rng.f64() < rate {
                plan.faults.insert(ordinal, *rng.choice(&ALL_FAULTS));
            }
        }
        plan
    }

    /// Parse a per-slot fleet spec: `"0=0:crash;1=2:garbage"` assigns a
    /// plan to slots 0 and 1; unnamed slots get the well-behaved plan.
    pub fn parse_fleet(spec: &str, workers: usize) -> Result<Vec<FaultPlan>, String> {
        let mut plans = vec![FaultPlan::none(); workers];
        for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
            let (slot, plan) = part
                .trim()
                .split_once('=')
                .ok_or_else(|| format!("fleet fault spec `{part}`: expected SLOT=PLAN"))?;
            let slot: usize =
                slot.parse().map_err(|_| format!("fleet fault spec: bad slot `{slot}`"))?;
            if slot >= workers {
                return Err(format!("fleet fault spec: slot {slot} >= --workers {workers}"));
            }
            if !plans[slot].is_empty() {
                return Err(format!("fleet fault spec: duplicate slot {slot}"));
            }
            plans[slot] = FaultPlan::parse(plan)?;
        }
        Ok(plans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip() {
        let plan = FaultPlan::none()
            .with(0, Fault::CrashBeforeReply)
            .with(3, Fault::GarbageLine)
            .with(7, Fault::WrongVersion);
        assert_eq!(plan.spec(), "0:crash,3:garbage,7:wrong-version");
        assert_eq!(FaultPlan::parse(&plan.spec()).unwrap(), plan);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none());
        assert_eq!(FaultPlan::none().spec(), "");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("0").is_err());
        assert!(FaultPlan::parse("x:crash").is_err());
        assert!(FaultPlan::parse("0:explode").is_err());
        assert!(FaultPlan::parse("0:crash,0:hang").is_err(), "duplicate ordinal");
    }

    #[test]
    fn fleet_spec_assigns_per_slot() {
        let plans = FaultPlan::parse_fleet("0=0:crash;2=1:hang,2:duplicate", 3).unwrap();
        assert_eq!(plans[0].fault_at(0), Some(Fault::CrashBeforeReply));
        assert!(plans[1].is_empty());
        assert_eq!(plans[2].fault_at(1), Some(Fault::HangPastDeadline));
        assert_eq!(plans[2].fault_at(2), Some(Fault::DuplicateReply));
        assert!(FaultPlan::parse_fleet("5=0:crash", 2).is_err(), "slot out of range");
        assert!(FaultPlan::parse_fleet("0=0:crash;0=1:hang", 2).is_err(), "duplicate slot");
        assert_eq!(FaultPlan::parse_fleet("", 2).unwrap(), vec![FaultPlan::none(); 2]);
    }

    #[test]
    fn scripted_plans_are_deterministic_and_slot_independent() {
        let a = FaultPlan::scripted(42, 0, 64, 0.3);
        let b = FaultPlan::scripted(42, 0, 64, 0.3);
        assert_eq!(a, b, "same (seed, slot) must script the same plan");
        let c = FaultPlan::scripted(42, 1, 64, 0.3);
        assert_ne!(a, c, "slots draw from independent streams");
        assert!(!a.is_empty(), "rate 0.3 over 64 ordinals faults some");
        assert!(a.len() < 40, "…but nowhere near all");
        // and plans survive the CLI spec round-trip
        assert_eq!(FaultPlan::parse(&a.spec()).unwrap(), a);
    }
}
