//! Fault-tolerant fleet coordination (ADR-007): a long-running
//! coordinator drives N `repro worker` subprocesses over a length-checked,
//! version-gated, line-delimited JSON protocol, assigning
//! [`crate::eval::manifest::SuiteShard`]s with per-shard deadlines,
//! bounded exponential-backoff retries, straggler re-issue
//! (first completion wins), and per-worker quarantine — merging
//! incrementally as shards land. The merged output is field-for-field
//! identical to single-process `exec::eval_variants`, inherited from the
//! ADR-003 shard/merge golden property by construction.
//!
//! Layers, bottom up:
//! - [`pipe`] — std-only in-memory byte pipe, so in-process test workers
//!   speak the same byte streams as subprocesses;
//! - [`protocol`] — the wire messages, version gate, and capped line
//!   reader;
//! - [`faults`] — deterministic fault-injection plans (scripted by hand,
//!   by CLI spec, or from the ADR-002 seeded RNG streams);
//! - [`worker`] — the worker loop both `repro worker` and the in-process
//!   harness run;
//! - [`events`] — the machine-readable coordinator event log;
//! - [`coordinator`] — assignment, deadlines/retries/quarantine,
//!   SOL-aware admission ordering, and incremental merge.

pub mod coordinator;
pub mod events;
pub mod faults;
pub mod pipe;
pub mod protocol;
pub mod worker;

pub use coordinator::{
    admission_order, run_fleet, run_fleet_journaled, subprocess_worker_factory,
    thread_worker_factory, FleetConfig, FleetError, FleetOutcome, FleetStats, WireEvent,
    WorkerLink,
};
pub use events::{parse_events_jsonl, EventLog};
pub use faults::{Fault, FaultPlan};
pub use protocol::{Message, ParseError, FLEET_PROTOCOL_VERSION, MAX_LINE_BYTES};
pub use worker::{worker_loop, WorkerOpts};
