//! `CachedEvaluator`: the ADR-003 `Evaluator` face of the binary store.
//!
//! Three layers, consulted in order for every request:
//!
//! 1. **memory** — a per-process map memoizing everything this process
//!    has seen (store hits and fresh live answers alike);
//! 2. **store** — the persistent binary store ([`EvalStore`]), one
//!    `pread` + checksum per first touch of a landed key;
//! 3. **live** — the real backend, consulted only for keys neither
//!    layer holds; in [`CacheMode::WriteThrough`] its answers are
//!    appended to the store so no one ever pays for them again.
//!
//! Like the JSONL `RecordingEvaluator`/`TraceEvaluator` pair it is
//! *transparent*: the response a caller sees is exactly what the live
//! backend produced (or what the store replays bit-for-bit, floats as
//! `f64::to_bits`), so a cached run's RunLogs are byte-identical to an
//! uncached run's — the golden property `tests/cache.rs` pins down at
//! `--jobs 1`, `--jobs 4`, and through `repro serve`.
//!
//! Counter semantics mirror `TraceMonitor`: a request answered live in
//! the fall-through modes is counted as `live`, not a *miss* — `misses`
//! is reserved for [`CacheMode::Offline`], where there is no backend and
//! a missing key is answered with an in-band error response and fails
//! [`StoreMonitor::check`] after the run. Error responses are cached and
//! written through too (`pass == false` is a real, deterministic answer
//! under ADR-003, and skipping them would break byte-identity).

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::eval::{DynEvaluator, EvalKey, EvalRequest, EvalResponse, Evaluator, OwnedAnalytic};

use super::format::{EvalStore, StoreWriter};

// ===========================================================================
// Monitor
// ===========================================================================

#[derive(Default)]
struct MonitorState {
    path: String,
    offline: bool,
    hits_mem: u64,
    hits_store: u64,
    live: u64,
    misses: u64,
    writes: u64,
    first_miss: Option<String>,
    io_error: Option<String>,
}

/// Shared counters for one cache session — the store-layer analogue of
/// `TraceMonitor`. Clone it before boxing the evaluator; every clone
/// sees the same state.
#[derive(Clone, Default)]
pub struct StoreMonitor(Arc<Mutex<MonitorState>>);

impl StoreMonitor {
    fn new(path: &Path, offline: bool) -> StoreMonitor {
        StoreMonitor(Arc::new(Mutex::new(MonitorState {
            path: path.display().to_string(),
            offline,
            ..MonitorState::default()
        })))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MonitorState> {
        self.0.lock().expect("store monitor lock")
    }

    fn record_io_error(&self, e: String) {
        let mut s = self.lock();
        if s.io_error.is_none() {
            s.io_error = Some(e);
        }
    }

    /// Requests served from the memory or store layer.
    pub fn hits(&self) -> u64 {
        let s = self.lock();
        s.hits_mem + s.hits_store
    }

    /// Hits served by the per-process memory layer.
    pub fn hits_mem(&self) -> u64 {
        self.lock().hits_mem
    }

    /// Hits that cost a store `pread` (first touch of a landed key).
    pub fn hits_store(&self) -> u64 {
        self.lock().hits_store
    }

    /// Requests answered by the live backend (fall-through modes only).
    pub fn live(&self) -> u64 {
        self.lock().live
    }

    /// Requests the cache could not answer at all (offline mode only);
    /// each produced an in-band error response.
    pub fn misses(&self) -> u64 {
        self.lock().misses
    }

    /// Records appended to the store this session.
    pub fn writes(&self) -> u64 {
        self.lock().writes
    }

    /// Human key of the first unanswerable request, if any.
    pub fn first_miss(&self) -> Option<String> {
        self.lock().first_miss.clone()
    }

    /// First cache I/O failure, if any (a failed `pread`, checksum
    /// mismatch, or write-through append).
    pub fn io_error(&self) -> Option<String> {
        self.lock().io_error.clone()
    }

    /// One-line session summary for the CLI.
    pub fn summary(&self) -> String {
        let s = self.lock();
        format!(
            "cache {}: {} served ({} memory, {} store), {} live, {} written, {} miss(es)",
            s.path,
            s.hits_mem + s.hits_store,
            s.hits_mem,
            s.hits_store,
            s.live,
            s.writes,
            s.misses
        )
    }

    /// In-band session verdict: `Err` on any cache I/O failure, and on
    /// offline misses (an offline run that was not fully served is not a
    /// reproduction — same discipline as strict trace replay).
    pub fn check(&self) -> Result<(), String> {
        let s = self.lock();
        if let Some(e) = &s.io_error {
            return Err(format!("cache {}: io error: {e}", s.path));
        }
        if s.offline && s.misses > 0 {
            let first = s.first_miss.as_deref().unwrap_or("?");
            return Err(format!(
                "cache {}: {} request(s) not in the store (first: {first}); the store \
                 does not cover this run — re-record it with --cache (write-through) \
                 or drop --offline to fall through to the live backend",
                s.path, s.misses
            ));
        }
        Ok(())
    }
}

// ===========================================================================
// CachedEvaluator
// ===========================================================================

/// What sits below the store layer.
pub enum CacheMode {
    /// No live backend: a key the store does not hold is answered with an
    /// in-band error response and counted as a miss.
    Offline,
    /// Fall through to a live backend but never write the store — the
    /// fleet-worker mode (many processes may read one store; only the
    /// recording run writes it).
    ReadThrough(Box<DynEvaluator>),
    /// Fall through and append every fresh answer to the store
    /// (create-or-extend) — the recording mode.
    WriteThrough(Box<DynEvaluator>),
}

/// The layered evaluator. Construct with [`CachedEvaluator::open`] or
/// the CLI-shaped [`cache_session`].
pub struct CachedEvaluator {
    memory: Mutex<HashMap<EvalKey, EvalResponse>>,
    store: EvalStore,
    writer: Option<Mutex<StoreWriter>>,
    live: Option<Box<DynEvaluator>>,
    monitor: StoreMonitor,
    /// Keys served this session, in service order — flushed to the
    /// `<store>.lru` recency sidecar at finish/drop so `repro cache gc`
    /// can rank keys least-recently-served (ADR-010).
    touched: Mutex<Vec<EvalKey>>,
}

impl CachedEvaluator {
    pub fn open(path: impl AsRef<Path>, mode: CacheMode) -> Result<CachedEvaluator, String> {
        let path = path.as_ref();
        let (store, writer, live, offline) = match mode {
            CacheMode::Offline => (EvalStore::open(path)?, None, None, true),
            CacheMode::ReadThrough(b) => (EvalStore::open(path)?, None, Some(b), false),
            CacheMode::WriteThrough(b) => {
                if path.exists() {
                    let (store, writer) = StoreWriter::extend(path)?;
                    (store, Some(Mutex::new(writer)), Some(b), false)
                } else {
                    let writer = StoreWriter::create(path)?;
                    let store = EvalStore::attach_empty(path)?;
                    (store, Some(Mutex::new(writer)), Some(b), false)
                }
            }
        };
        let monitor = StoreMonitor::new(path, offline);
        Ok(CachedEvaluator {
            memory: Mutex::new(HashMap::new()),
            store,
            writer,
            live,
            monitor,
            touched: Mutex::new(Vec::new()),
        })
    }

    /// A handle onto this session's counters.
    pub fn monitor(&self) -> StoreMonitor {
        self.monitor.clone()
    }

    /// Keys the persistent layer held at open (fresh answers live in the
    /// memory layer until the writer finishes).
    pub fn store_len(&self) -> usize {
        self.store.len()
    }

    /// Write the index + trailer now instead of at drop, surfacing the
    /// error to the caller. Also flushes the recency sidecar.
    pub fn finish(&self) -> Result<(), String> {
        self.flush_lru();
        match &self.writer {
            None => Ok(()),
            Some(w) => w.lock().expect("store writer lock").finish(),
        }
    }

    /// Append this session's served keys to `<store>.lru`, oldest→newest,
    /// deduped to each key's *last* service. Best-effort and advisory: a
    /// failed write costs GC eviction quality, never correctness, so it
    /// does not fail the session (unlike store I/O).
    fn flush_lru(&self) {
        use std::io::Write;
        let keys = std::mem::take(&mut *self.touched.lock().expect("cache lru lock"));
        if keys.is_empty() {
            return;
        }
        let mut seen: HashSet<EvalKey> = HashSet::new();
        let mut newest_first: Vec<EvalKey> = Vec::new();
        for k in keys.iter().rev() {
            if seen.insert(*k) {
                newest_first.push(*k);
            }
        }
        let mut text = String::with_capacity(newest_first.len() * 33);
        for k in newest_first.iter().rev() {
            text.push_str(&format!("{:032x}\n", k.0));
        }
        let path = super::lru_sidecar_path(self.store.path());
        if let Ok(mut f) =
            std::fs::OpenOptions::new().append(true).create(true).open(&path)
        {
            let _ = f.write_all(text.as_bytes());
        }
    }
}

impl Evaluator for CachedEvaluator {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        let keys: Vec<EvalKey> = reqs.iter().map(|r| r.eval_key()).collect();
        let mut out: Vec<Option<EvalResponse>> = vec![None; reqs.len()];
        let mut hits_mem = 0u64;
        let mut hits_store = 0u64;

        // layer 1: memory
        {
            let mem = self.memory.lock().expect("cache memory lock");
            for (i, key) in keys.iter().enumerate() {
                if let Some(r) = mem.get(key) {
                    out[i] = Some(r.clone());
                    hits_mem += 1;
                }
            }
        }

        // layer 2: store (memoize hits so later touches are layer-1)
        let mut landed: Vec<(EvalKey, EvalResponse)> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            match self.store.get(*key) {
                Ok(Some(r)) => {
                    hits_store += 1;
                    landed.push((*key, r.clone()));
                    out[i] = Some(r);
                }
                Ok(None) => {}
                // corruption on the hit path: record it once, then treat
                // the key as absent — offline turns it into an in-band
                // error response, fall-through re-measures it live
                Err(e) => self.monitor.record_io_error(e),
            }
        }
        if !landed.is_empty() {
            let mut mem = self.memory.lock().expect("cache memory lock");
            mem.extend(landed);
        }
        {
            let mut s = self.monitor.lock();
            s.hits_mem += hits_mem;
            s.hits_store += hits_store;
        }

        let missing: Vec<usize> = (0..reqs.len()).filter(|&i| out[i].is_none()).collect();
        if !missing.is_empty() {
            match &self.live {
                None => {
                    let mut s = self.monitor.lock();
                    s.misses += missing.len() as u64;
                    if s.first_miss.is_none() {
                        s.first_miss = Some(reqs[missing[0]].key());
                    }
                    drop(s);
                    for &i in &missing {
                        out[i] = Some(EvalResponse::error(
                            keys[i],
                            format!("cache miss: {}", reqs[i].key()),
                        ));
                    }
                }
                Some(live) => {
                    let sub: Vec<EvalRequest> =
                        missing.iter().map(|&i| reqs[i].clone()).collect();
                    let answers = live.eval_batch(&sub);
                    debug_assert_eq!(answers.len(), sub.len());
                    self.monitor.lock().live += missing.len() as u64;
                    let mut fresh: Vec<usize> = Vec::new();
                    {
                        let mut mem = self.memory.lock().expect("cache memory lock");
                        for (&i, resp) in missing.iter().zip(&answers) {
                            // first insert wins; a key repeated within
                            // this batch is only written through once
                            if mem.insert(keys[i], resp.clone()).is_none() {
                                fresh.push(i);
                            }
                            out[i] = Some(resp.clone());
                        }
                    }
                    if let Some(w) = &self.writer {
                        let mut w = w.lock().expect("store writer lock");
                        let mut wrote = 0u64;
                        for (&i, resp) in missing.iter().zip(&answers) {
                            if !fresh.contains(&i) {
                                continue;
                            }
                            match w.append(&reqs[i], resp) {
                                Ok(true) => wrote += 1,
                                Ok(false) => {}
                                Err(e) => self.monitor.record_io_error(e),
                            }
                        }
                        drop(w);
                        self.monitor.lock().writes += wrote;
                    }
                }
            }
        }

        // every request was answered by some layer, so the whole batch
        // counts as served for recency purposes
        self.touched.lock().expect("cache lru lock").extend(keys.iter().copied());

        out.into_iter()
            .map(|r| r.expect("every request answered by some layer"))
            .collect()
    }
}

impl Drop for CachedEvaluator {
    fn drop(&mut self) {
        self.flush_lru();
        if let Some(w) = &self.writer {
            if let Ok(mut w) = w.lock() {
                if let Err(e) = w.finish() {
                    self.monitor.record_io_error(e);
                }
            }
        }
    }
}

// ===========================================================================
// CLI-shaped constructor
// ===========================================================================

/// How the CLI wants the cache layered — the live backend (the owned
/// analytic model, same construction as `Bench::new()`) is supplied
/// here so `main.rs` never builds one.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CacheSessionMode {
    /// `--cache PATH`: record — serve hits, measure misses live, append
    /// them to the store (create-or-extend).
    WriteThrough,
    /// serve/worker `--cache PATH`: serve hits, measure misses live,
    /// never write (single-writer discipline: fleets read, runs record).
    ReadThrough,
    /// `--cache PATH --offline`: no live backend; a miss is an in-band
    /// error and fails the session check.
    Offline,
}

/// Build the boxed oracle + monitor for one CLI cache session,
/// mirroring `trace_session`. `PathBuf` keeps call sites uniform with
/// the trace plumbing in `main.rs`.
pub fn cache_session(
    mode: CacheSessionMode,
    path: PathBuf,
) -> Result<(Box<DynEvaluator>, StoreMonitor), String> {
    let mode = match mode {
        CacheSessionMode::Offline => CacheMode::Offline,
        CacheSessionMode::ReadThrough => CacheMode::ReadThrough(Box::new(OwnedAnalytic::new())),
        CacheSessionMode::WriteThrough => CacheMode::WriteThrough(Box::new(OwnedAnalytic::new())),
    };
    let cached = CachedEvaluator::open(&path, mode)?;
    let monitor = cached.monitor();
    Ok((Box::new(cached), monitor))
}
