//! Binary eval-store format v1 (ADR-008): the persistent,
//! content-addressed measurement store behind `repro … --cache PATH`.
//!
//! A store is one append-only file:
//!
//! ```text
//! header   16 B   magic "UCEVSTOR" · u32 version (=1) · u32 flags (=0)
//! records  n ×    u32 payload_len · u64 fnv64(payload) · payload
//!   payload:      u128 EvalKey · u64 f64-bits value · u8 pass ·
//!                 u8 detail_tag [· u32 len · detail bytes] ·
//!                 u32 len · canonical EvalRequest JSON bytes
//! index    n ×    u128 EvalKey · u64 record offset · u32 payload_len
//! trailer  40 B   magic "UCEVIDX1" · u32 version · u32 reserved ·
//!                 u64 count · u64 index_offset · u64 fnv64(index)
//! ```
//!
//! All integers are little-endian; floats travel as `f64::to_bits`, so a
//! served value is bit-identical to the recorded one. Each record carries
//! the full request's canonical JSON after the response fields: lookups
//! never parse it (the hit path decodes key + response and stops), but it
//! makes every record self-describing — `repro cache export` bridges to
//! the JSONL v2 diagnostic/interchange format losslessly, and `repro
//! cache stats` can aggregate by kind/problem without a side table.
//!
//! Opening a million-measurement store costs one index read (28 B per
//! record) and zero JSON parses; every hit is then one `pread` of its
//! record. The layout is mmap-friendly by construction: fixed header,
//! densely tiled length-prefixed records, and a fixed-size trailer that
//! locates the index from the end of the file.
//!
//! Integrity is checked where it is cheap enough to always do: the index
//! checksum and the record-tiling invariant (records must exactly tile
//! `[header, index)`, every offset reachable from the index) at open, the
//! per-record checksum on each record read. Every failure is an in-band
//! `Err(String)` naming the file — never a panic — mirroring the JSONL
//! trace parser's discipline (ADR-004) and the shard-artifact negative
//! suite (ADR-003).
//!
//! Crash story: records are flushed on a cadence, the index + trailer
//! only on [`StoreWriter::finish`] (or drop). A store torn by a crash
//! fails `open` in-band; `repro cache repair` (ADR-010) recovers the
//! valid record prefix and rebuilds the index footer — exactly the
//! records whose payload checksums landed, never a corrupt one.

use std::collections::{HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::eval::manifest::MAX_ARTIFACT_BYTES;
use crate::eval::{EvalKey, EvalRequest, EvalResponse};
use crate::util::fnv64;
use crate::util::json::Json;

/// File magic: the first 8 bytes of every eval store.
pub const STORE_MAGIC: [u8; 8] = *b"UCEVSTOR";
/// Trailer magic: the 8 bytes starting 40 from the end of the file.
pub const INDEX_MAGIC: [u8; 8] = *b"UCEVIDX1";
/// Binary store format version. Bump on any layout change; readers
/// reject other versions in-band (the v1→v2 gate of the JSONL trace).
pub const STORE_VERSION: u32 = 1;

pub const HEADER_BYTES: u64 = 16;
pub const TRAILER_BYTES: u64 = 40;
pub const INDEX_ENTRY_BYTES: u64 = 28;
/// Per-record header: u32 payload length + u64 payload checksum.
pub const RECORD_HEADER_BYTES: u64 = 12;

/// Per-record payload cap — the shard-artifact limit (ADR-003), shared so
/// "too big for the parser" and "too big for the store" are one bound.
pub const MAX_RECORD_BYTES: usize = MAX_ARTIFACT_BYTES;

/// Flush cadence for the record stream, matching the JSONL recorder's
/// crash-loss bound (`trace::FLUSH_EVERY_LINES`).
const FLUSH_EVERY_RECORDS: u32 = 512;

// ===========================================================================
// Record encoding
// ===========================================================================

/// Encode one `(request, response)` pair as a record payload. In-band
/// errors on oversized payloads and on a response that does not answer
/// the request (a mismatched pair must never become unreachable-but-
/// served state on disk).
pub(crate) fn encode_payload(req: &EvalRequest, resp: &EvalResponse) -> Result<Vec<u8>, String> {
    if resp.key != req.eval_key() {
        return Err(format!(
            "response key `{}` does not match its request key `{}` ({})",
            resp.key,
            req.eval_key(),
            req.key()
        ));
    }
    let mut buf = Vec::with_capacity(96);
    buf.extend_from_slice(&resp.key.0.to_le_bytes());
    buf.extend_from_slice(&resp.value.to_bits().to_le_bytes());
    buf.push(resp.pass as u8);
    match &resp.detail {
        None => buf.push(0),
        Some(d) => {
            buf.push(1);
            buf.extend_from_slice(&(d.len() as u32).to_le_bytes());
            buf.extend_from_slice(d.as_bytes());
        }
    }
    let rj = req.to_json().to_string();
    buf.extend_from_slice(&(rj.len() as u32).to_le_bytes());
    buf.extend_from_slice(rj.as_bytes());
    if buf.len() > MAX_RECORD_BYTES {
        return Err(format!(
            "record for key {} is {} bytes, over the {MAX_RECORD_BYTES}-byte limit",
            resp.key,
            buf.len()
        ));
    }
    Ok(buf)
}

/// Bounds-checked little-endian cursor over a record payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let s = self.b.get(self.i..self.i.checked_add(n)?)?;
        self.i += n;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().expect("8 bytes")))
    }

    fn u128(&mut self) -> Option<u128> {
        self.take(16).map(|s| u128::from_le_bytes(s.try_into().expect("16 bytes")))
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

/// Decode the response half of a payload — the hit path. Validates the
/// full structural frame (every length in bounds, nothing left over after
/// the request JSON) but never parses the request JSON itself.
fn decode_response(payload: &[u8]) -> Result<EvalResponse, String> {
    let bad = || "malformed record payload".to_string();
    let mut c = Cur { b: payload, i: 0 };
    let key = EvalKey(c.u128().ok_or_else(bad)?);
    let value = f64::from_bits(c.u64().ok_or_else(bad)?);
    let pass = match c.take(1).ok_or_else(bad)?[0] {
        0 => false,
        1 => true,
        _ => return Err(bad()),
    };
    let detail = match c.take(1).ok_or_else(bad)?[0] {
        0 => None,
        1 => {
            let n = c.u32().ok_or_else(bad)? as usize;
            let bytes = c.take(n).ok_or_else(bad)?;
            Some(std::str::from_utf8(bytes).map_err(|_| bad())?.into())
        }
        _ => return Err(bad()),
    };
    let rlen = c.u32().ok_or_else(bad)? as usize;
    if c.remaining() != rlen {
        return Err(bad());
    }
    Ok(EvalResponse { key, value, pass, detail })
}

/// Decode the full `(request, response)` pair — export/stats/merge, and
/// the record-by-record scan of `repair_store` (ADR-010). Also
/// re-derives the request's key and checks it against the stored one, so
/// a record can never serve under an identity its request does not have.
pub(crate) fn decode_pair(payload: &[u8]) -> Result<(EvalRequest, EvalResponse), String> {
    let resp = decode_response(payload)?;
    // re-walk the fixed fields (already validated above) to reach the
    // request JSON: key(16) + value(8) + pass(1), then the detail frame
    let bad = || "malformed record payload".to_string();
    let mut c = Cur { b: payload, i: 16 + 8 + 1 };
    if c.take(1).ok_or_else(bad)?[0] == 1 {
        let n = c.u32().ok_or_else(bad)? as usize;
        c.take(n).ok_or_else(bad)?;
    }
    let rlen = c.u32().ok_or_else(bad)? as usize;
    let rj = c.take(rlen).ok_or_else(bad)?;
    let text = std::str::from_utf8(rj).map_err(|_| "request JSON is not UTF-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("corrupt request JSON ({e})"))?;
    let req = EvalRequest::from_json(&j).ok_or("malformed request JSON")?;
    if req.eval_key() != resp.key {
        return Err(format!(
            "stored request `{}` does not hash to the record key {}",
            req.key(),
            resp.key
        ));
    }
    Ok((req, resp))
}

// ===========================================================================
// Read face
// ===========================================================================

/// Positioned read shared by every store reader. Unix uses `pread` (no
/// seek, safe under concurrent readers of one handle); elsewhere we fall
/// back to seek + read on the mutex-guarded handle.
fn read_exact_at(file: &mut File, buf: &mut [u8], off: u64) -> Result<(), String> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        return file.read_exact_at(buf, off).map_err(|e| e.to_string());
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        file.seek(SeekFrom::Start(off)).map_err(|e| e.to_string())?;
        file.read_exact(buf).map_err(|e| e.to_string())
    }
}

#[derive(Clone, Copy)]
struct Entry {
    offset: u64,
    len: u32,
}

/// The read face of a binary store: open validates the header, trailer,
/// index checksum, and record-tiling invariant; lookups are one `pread`
/// plus a checksum, with no JSON in sight.
pub struct EvalStore {
    file: Mutex<File>,
    path: PathBuf,
    index: HashMap<EvalKey, Entry>,
    /// Keys in record (append) order — export, compact, and merge walk
    /// this so rewritten stores are deterministic byte-for-byte.
    order: Vec<EvalKey>,
    data_end: u64,
    file_bytes: u64,
}

impl EvalStore {
    pub fn open(path: impl AsRef<Path>) -> Result<EvalStore, String> {
        let path = path.as_ref();
        let ctx = |e: String| format!("store {}: {e}", path.display());
        let mut file = File::open(path).map_err(|e| ctx(e.to_string()))?;
        let file_bytes = file.metadata().map_err(|e| ctx(e.to_string()))?.len();
        if file_bytes < HEADER_BYTES + TRAILER_BYTES {
            return Err(ctx(format!(
                "truncated: {file_bytes} bytes is smaller than an empty store \
                 ({} header + {} trailer)",
                HEADER_BYTES, TRAILER_BYTES
            )));
        }

        let mut hdr = [0u8; HEADER_BYTES as usize];
        read_exact_at(&mut file, &mut hdr, 0).map_err(&ctx)?;
        if hdr[..8] != STORE_MAGIC {
            return Err(ctx("bad magic (not an eval store)".into()));
        }
        let version = u32::from_le_bytes(hdr[8..12].try_into().expect("4 bytes"));
        if version != STORE_VERSION {
            return Err(ctx(format!(
                "unsupported store version {version} (this build reads version {STORE_VERSION})"
            )));
        }
        // flags are reserved-zero in v1; rejecting nonzero keeps every
        // header byte load-bearing (the byte-flip suite relies on it) and
        // the bits free for a compatible future use
        let flags = u32::from_le_bytes(hdr[12..16].try_into().expect("4 bytes"));
        if flags != 0 {
            return Err(ctx(format!("unsupported store flags {flags:#x} (v1 defines none)")));
        }

        let mut tr = [0u8; TRAILER_BYTES as usize];
        read_exact_at(&mut file, &mut tr, file_bytes - TRAILER_BYTES).map_err(&ctx)?;
        if tr[..8] != INDEX_MAGIC {
            return Err(ctx(
                "bad or truncated index trailer (crashed before finish? re-record, or \
                 rebuild from a JSONL export with `repro cache import`)"
                    .into(),
            ));
        }
        let tversion = u32::from_le_bytes(tr[8..12].try_into().expect("4 bytes"));
        if tversion != version {
            return Err(ctx(format!(
                "trailer version {tversion} disagrees with header version {version}"
            )));
        }
        let reserved = u32::from_le_bytes(tr[12..16].try_into().expect("4 bytes"));
        if reserved != 0 {
            return Err(ctx(format!("corrupt trailer (reserved field is {reserved:#x})")));
        }
        let count = u64::from_le_bytes(tr[16..24].try_into().expect("8 bytes"));
        let index_offset = u64::from_le_bytes(tr[24..32].try_into().expect("8 bytes"));
        let index_checksum = u64::from_le_bytes(tr[32..40].try_into().expect("8 bytes"));
        let index_bytes = count
            .checked_mul(INDEX_ENTRY_BYTES)
            .ok_or_else(|| ctx(format!("absurd record count {count}")))?;
        if index_offset < HEADER_BYTES
            || index_offset.checked_add(index_bytes) != Some(file_bytes - TRAILER_BYTES)
        {
            return Err(ctx(format!(
                "index ({count} records at offset {index_offset}) does not tile the file \
                 ({file_bytes} bytes)"
            )));
        }

        let mut ib = vec![0u8; index_bytes as usize];
        read_exact_at(&mut file, &mut ib, index_offset).map_err(&ctx)?;
        if fnv64(&ib) != index_checksum {
            return Err(ctx("index checksum mismatch (corrupt or partially-written store)".into()));
        }

        let mut index = HashMap::with_capacity(count as usize);
        let mut order = Vec::with_capacity(count as usize);
        let mut extents: Vec<Entry> = Vec::with_capacity(count as usize);
        for e in ib.chunks_exact(INDEX_ENTRY_BYTES as usize) {
            let key = EvalKey(u128::from_le_bytes(e[..16].try_into().expect("16 bytes")));
            let offset = u64::from_le_bytes(e[16..24].try_into().expect("8 bytes"));
            let len = u32::from_le_bytes(e[24..28].try_into().expect("4 bytes"));
            if len as usize > MAX_RECORD_BYTES {
                return Err(ctx(format!(
                    "record for key {key} is {len} bytes, over the {MAX_RECORD_BYTES}-byte limit"
                )));
            }
            if index.insert(key, Entry { offset, len }).is_some() {
                return Err(ctx(format!("duplicate key {key} in index")));
            }
            order.push(key);
            extents.push(Entry { offset, len });
        }

        // Tiling invariant: sorted by offset, the records must cover
        // [header, index) exactly — every byte of the data region is
        // reachable from the index, and no two records overlap. This is
        // what lets the byte-flip negative suite promise that any
        // corruption is caught by open or by the lookup that reads it.
        extents.sort_by_key(|e| e.offset);
        let mut pos = HEADER_BYTES;
        for e in &extents {
            if e.offset != pos {
                return Err(ctx(format!(
                    "records do not tile the data region (gap or overlap at offset {pos})"
                )));
            }
            pos += RECORD_HEADER_BYTES + e.len as u64;
        }
        if pos != index_offset {
            return Err(ctx(format!(
                "records end at {pos} but the index starts at {index_offset}"
            )));
        }

        Ok(EvalStore {
            file: Mutex::new(file),
            path: path.to_path_buf(),
            index,
            order,
            data_end: index_offset,
            file_bytes,
        })
    }

    /// Read face over a file the caller just created (header written and
    /// flushed, no records yet) — the fresh write-through case.
    pub(crate) fn attach_empty(path: &Path) -> Result<EvalStore, String> {
        let file =
            File::open(path).map_err(|e| format!("store {}: {e}", path.display()))?;
        Ok(EvalStore {
            file: Mutex::new(file),
            path: path.to_path_buf(),
            index: HashMap::new(),
            order: Vec::new(),
            data_end: HEADER_BYTES,
            file_bytes: HEADER_BYTES,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Distinct keys this store serves.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, key: EvalKey) -> bool {
        self.index.contains_key(&key)
    }

    /// Keys in record (append) order.
    pub fn keys(&self) -> impl Iterator<Item = EvalKey> + '_ {
        self.order.iter().copied()
    }

    /// Total file size at open.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// Bytes the open path actually read and validated (header + index +
    /// trailer) — what "opens without parsing" costs.
    pub fn open_bytes(&self) -> u64 {
        HEADER_BYTES + self.order.len() as u64 * INDEX_ENTRY_BYTES + TRAILER_BYTES
    }

    fn read_record(&self, key: EvalKey, e: Entry) -> Result<Vec<u8>, String> {
        let ctx = |msg: String| format!("store {}: key {key}: {msg}", self.path.display());
        let mut f = self.file.lock().expect("store file lock");
        let mut hdr = [0u8; RECORD_HEADER_BYTES as usize];
        read_exact_at(&mut f, &mut hdr, e.offset).map_err(&ctx)?;
        let len = u32::from_le_bytes(hdr[..4].try_into().expect("4 bytes"));
        let checksum = u64::from_le_bytes(hdr[4..12].try_into().expect("8 bytes"));
        if len != e.len {
            return Err(ctx(format!(
                "record length {len} disagrees with the index ({})",
                e.len
            )));
        }
        let mut payload = vec![0u8; len as usize];
        read_exact_at(&mut f, &mut payload, e.offset + RECORD_HEADER_BYTES).map_err(&ctx)?;
        drop(f);
        if fnv64(&payload) != checksum {
            return Err(ctx(format!(
                "record checksum mismatch at offset {} (corrupt store)",
                e.offset
            )));
        }
        Ok(payload)
    }

    /// Serve one key: `Ok(None)` when absent, `Err` on any corruption.
    pub fn get(&self, key: EvalKey) -> Result<Option<EvalResponse>, String> {
        let Some(e) = self.index.get(&key).copied() else { return Ok(None) };
        let payload = self.read_record(key, e)?;
        let resp = decode_response(&payload)
            .map_err(|m| format!("store {}: key {key}: {m}", self.path.display()))?;
        if resp.key != key {
            return Err(format!(
                "store {}: index key {key} points at a record for {}",
                self.path.display(),
                resp.key
            ));
        }
        Ok(Some(resp))
    }

    /// Serve the full pair (export / stats / merge — parses the stored
    /// request JSON, which the hit path never does).
    pub fn get_pair(&self, key: EvalKey) -> Result<Option<(EvalRequest, EvalResponse)>, String> {
        let Some(e) = self.index.get(&key).copied() else { return Ok(None) };
        let payload = self.read_record(key, e)?;
        let (req, resp) = decode_pair(&payload)
            .map_err(|m| format!("store {}: key {key}: {m}", self.path.display()))?;
        if resp.key != key {
            return Err(format!(
                "store {}: index key {key} points at a record for {}",
                self.path.display(),
                resp.key
            ));
        }
        Ok(Some((req, resp)))
    }

    /// Payload checksum of a key's record, without decoding it — the
    /// cheap equality witness `merge_stores` compares duplicates by
    /// (payload encoding is canonical: equal pairs ⇔ equal payloads).
    pub(crate) fn record_checksum(&self, key: EvalKey) -> Result<Option<u64>, String> {
        let Some(e) = self.index.get(&key).copied() else { return Ok(None) };
        Ok(Some(fnv64(&self.read_record(key, e)?)))
    }

    /// Payload length of a key's record, from the index alone — the GC
    /// size model prices each key without reading its record.
    pub(crate) fn record_len(&self, key: EvalKey) -> Option<u32> {
        self.index.get(&key).map(|e| e.len)
    }
}

// ===========================================================================
// Write face
// ===========================================================================

/// Append-only writer. `create` starts a fresh store; `extend` reopens an
/// existing one, seeding its dedup set and entry list **from the offset
/// index alone** — no record payload is re-read and no JSON is re-parsed
/// on open, unlike the JSONL `Fallthrough` path, which re-parses the
/// whole trace (the fix ISSUE 8 satellite 3 asks for).
///
/// Records are flushed on a cadence; the index + trailer are written by
/// [`StoreWriter::finish`] (called by `Drop` as a best effort — callers
/// that care about the error, like `CachedEvaluator`, call it
/// explicitly and route failures to their monitor).
pub struct StoreWriter {
    out: BufWriter<File>,
    path: PathBuf,
    entries: Vec<(EvalKey, Entry)>,
    seen: HashSet<EvalKey>,
    pos: u64,
    unflushed: u32,
    finished: bool,
}

impl StoreWriter {
    /// Create (truncating) a fresh store and write its header.
    pub fn create(path: impl AsRef<Path>) -> Result<StoreWriter, String> {
        let path = path.as_ref();
        let ctx = |e: String| format!("store {}: {e}", path.display());
        let file = File::create(path).map_err(|e| ctx(format!("cannot create: {e}")))?;
        let mut out = BufWriter::new(file);
        let mut hdr = [0u8; HEADER_BYTES as usize];
        hdr[..8].copy_from_slice(&STORE_MAGIC);
        hdr[8..12].copy_from_slice(&STORE_VERSION.to_le_bytes());
        out.write_all(&hdr).map_err(|e| ctx(e.to_string()))?;
        // flush now so a concurrently attached read face sees a real file
        out.flush().map_err(|e| ctx(e.to_string()))?;
        Ok(StoreWriter {
            out,
            path: path.to_path_buf(),
            entries: Vec::new(),
            seen: HashSet::new(),
            pos: HEADER_BYTES,
            unflushed: 0,
            finished: false,
        })
    }

    /// Reopen an existing store for append: validate it, truncate the old
    /// index + trailer, and seed the writer's state from the index. The
    /// returned [`EvalStore`] keeps serving every landed record (its
    /// offsets are untouched by the truncation).
    pub fn extend(path: impl AsRef<Path>) -> Result<(EvalStore, StoreWriter), String> {
        let path = path.as_ref();
        let store = EvalStore::open(path)?;
        let ctx = |e: String| format!("store {}: {e}", path.display());
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| ctx(format!("cannot reopen for append: {e}")))?;
        file.set_len(store.data_end).map_err(|e| ctx(e.to_string()))?;
        let mut out = BufWriter::new(file);
        use std::io::{Seek, SeekFrom};
        out.seek(SeekFrom::Start(store.data_end)).map_err(|e| ctx(e.to_string()))?;
        let entries: Vec<(EvalKey, Entry)> =
            store.order.iter().map(|k| (*k, store.index[k])).collect();
        let seen = store.order.iter().copied().collect();
        let writer = StoreWriter {
            out,
            path: path.to_path_buf(),
            entries,
            seen,
            pos: store.data_end,
            unflushed: 0,
            finished: false,
        };
        Ok((store, writer))
    }

    /// Distinct keys the finished store will serve.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Append one pair. `Ok(false)` = already present (first write wins,
    /// like the JSONL recorder's dedup); `Err` on oversized payloads,
    /// mismatched pairs, I/O failures, or an already-finished writer.
    pub fn append(&mut self, req: &EvalRequest, resp: &EvalResponse) -> Result<bool, String> {
        let ctx = |e: String| format!("store {}: {e}", self.path.display());
        if self.finished {
            return Err(ctx("append after finish".into()));
        }
        let key = req.eval_key();
        if self.seen.contains(&key) {
            return Ok(false);
        }
        // mark the key seen only after the record is fully written: a
        // rejected append (oversized, mismatched pair) must not block a
        // later valid record for the same key
        let payload = encode_payload(req, resp).map_err(&ctx)?;
        let len = payload.len() as u32;
        self.out.write_all(&len.to_le_bytes()).map_err(|e| ctx(e.to_string()))?;
        self.out.write_all(&fnv64(&payload).to_le_bytes()).map_err(|e| ctx(e.to_string()))?;
        self.out.write_all(&payload).map_err(|e| ctx(e.to_string()))?;
        self.seen.insert(key);
        self.entries.push((key, Entry { offset: self.pos, len }));
        self.pos += RECORD_HEADER_BYTES + payload.len() as u64;
        self.unflushed += 1;
        if self.unflushed >= FLUSH_EVERY_RECORDS {
            self.unflushed = 0;
            self.out.flush().map_err(|e| ctx(e.to_string()))?;
        }
        Ok(true)
    }

    /// Write the index + trailer and flush. Idempotent; after the first
    /// call (even a failed one) the writer refuses further appends.
    pub fn finish(&mut self) -> Result<(), String> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        let ctx = |e: String| format!("store {}: {e}", self.path.display());
        let mut ib = Vec::with_capacity(self.entries.len() * INDEX_ENTRY_BYTES as usize);
        for (key, e) in &self.entries {
            ib.extend_from_slice(&key.0.to_le_bytes());
            ib.extend_from_slice(&e.offset.to_le_bytes());
            ib.extend_from_slice(&e.len.to_le_bytes());
        }
        let mut tr = [0u8; TRAILER_BYTES as usize];
        tr[..8].copy_from_slice(&INDEX_MAGIC);
        tr[8..12].copy_from_slice(&STORE_VERSION.to_le_bytes());
        tr[16..24].copy_from_slice(&(self.entries.len() as u64).to_le_bytes());
        tr[24..32].copy_from_slice(&self.pos.to_le_bytes());
        tr[32..40].copy_from_slice(&fnv64(&ib).to_le_bytes());
        self.out.write_all(&ib).map_err(|e| ctx(e.to_string()))?;
        self.out.write_all(&tr).map_err(|e| ctx(e.to_string()))?;
        self.out.flush().map_err(|e| ctx(e.to_string()))
    }
}

impl Drop for StoreWriter {
    fn drop(&mut self) {
        // best effort: an unfinished store is unopenable, so always try;
        // callers that must see the error call finish() themselves first
        let _ = self.finish();
    }
}
