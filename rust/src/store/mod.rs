//! Persistent content-addressed eval store (ADR-008).
//!
//! At production scale no `(arch, problem, config, seed)` measurement
//! should ever be paid for twice — across runs, users, or fleet nodes.
//! `EvalKey` (ADR-005) is already a process-stable 128-bit content hash
//! and the JSONL trace (ADR-004) already keys every measurement; this
//! module adds the missing storage layer:
//!
//! - [`format`] — binary trace format v1: append-only length-prefixed
//!   records under a magic + version header, with a key→offset index
//!   footer. A million-measurement store opens by reading its index
//!   (28 bytes/record, no JSON) and serves each hit with one `pread`.
//! - [`cached`] — [`CachedEvaluator`], the ADR-003 `Evaluator` that
//!   layers in-memory map → binary store → live backend with
//!   write-through, plus [`StoreMonitor`] counters and the
//!   [`cache_session`] CLI constructor.
//! - this file — bridges and maintenance: lossless export/import to the
//!   JSONL v2 trace (which stays the diagnostic/interchange format),
//!   `EvalKey::shard`-based partitioning, conflict-checked merge,
//!   compaction, crash repair ([`repair_store`] recovers the valid
//!   record prefix of a store torn mid-append/mid-finish and rebuilds
//!   its index footer), and budgeted eviction ([`gc_store`] drops
//!   least-recently-served keys, ranked by the `<store>.lru` sidecar)
//!   — the ADR-010 store-hardening pair behind `repro cache repair|gc`.
//!
//! Single-writer discipline: exactly one process may hold a store's
//! [`StoreWriter`] (recording runs); any number may read. `repro serve`
//! therefore opens caches read-through/offline on the coordinator and
//! its workers — fleets consume stores, recording runs produce them.

pub mod cached;
pub mod format;

pub use cached::{cache_session, CacheMode, CacheSessionMode, CachedEvaluator, StoreMonitor};
pub use format::{EvalStore, StoreWriter, MAX_RECORD_BYTES, STORE_VERSION};

use std::io::Write;
use std::path::Path;

use crate::eval::trace::{header_line, pair_to_line, parse_trace_pairs};
use crate::eval::EvalKey;

/// Export a binary store to a JSONL v2 trace, in record order, emitting
/// exactly the bytes a `RecordingEvaluator` would have written for the
/// same pairs — so the export replays under `TraceEvaluator` and
/// re-imports losslessly (floats travel as shortest-roundtrip decimals
/// that reparse bit-identically). Returns the number of records.
pub fn export_jsonl(store: &EvalStore, dst: impl AsRef<Path>) -> Result<u64, String> {
    let dst = dst.as_ref();
    let ctx = |e: String| format!("trace {}: {e}", dst.display());
    let file = std::fs::File::create(dst).map_err(|e| ctx(format!("cannot create: {e}")))?;
    let mut out = std::io::BufWriter::new(file);
    writeln!(out, "{}", header_line()).map_err(|e| ctx(e.to_string()))?;
    let mut wrote = 0u64;
    for key in store.keys() {
        let (req, resp) = store
            .get_pair(key)?
            .expect("indexed key has a record");
        writeln!(out, "{}", pair_to_line(&req, &resp)).map_err(|e| ctx(e.to_string()))?;
        wrote += 1;
    }
    out.flush().map_err(|e| ctx(e.to_string()))?;
    Ok(wrote)
}

/// Import a JSONL v2 trace into a fresh binary store at `dst`
/// (truncating), with the trace parser's full validation (version gate,
/// key match, conflicting-duplicate rejection). Line order becomes
/// record order. Returns the number of records.
pub fn import_jsonl(src: impl AsRef<Path>, dst: impl AsRef<Path>) -> Result<u64, String> {
    let src = src.as_ref();
    let text = std::fs::read_to_string(src)
        .map_err(|e| format!("trace {}: {e}", src.display()))?;
    let pairs = parse_trace_pairs(&text, &src.display().to_string())?;
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for (req, resp) in &pairs {
        if w.append(req, resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Copy the records whose [`EvalKey::shard`] lands on `index` (of `of`)
/// into a fresh store at `dst`, preserving record order — the store-level
/// analogue of `repro shard --index I --of N` (ADR-003). Returns the
/// number of records copied.
pub fn shard_store(
    store: &EvalStore,
    index: usize,
    of: usize,
    dst: impl AsRef<Path>,
) -> Result<u64, String> {
    if of == 0 || index >= of {
        return Err(format!("bad shard spec: index {index} of {of}"));
    }
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for key in store.keys() {
        if key.shard(of) != index {
            continue;
        }
        let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
        if w.append(&req, &resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Merge stores into a fresh store at `dst`: first occurrence of a key
/// wins its record order; a key present in several sources must carry an
/// identical record everywhere (compared by canonical payload checksum),
/// otherwise the merge fails in-band — the same conflicting-duplicate
/// discipline as the trace parser and the PR 3 shard merge. Returns the
/// number of records written.
pub fn merge_stores(stores: &[&EvalStore], dst: impl AsRef<Path>) -> Result<u64, String> {
    let mut first_sum: std::collections::HashMap<EvalKey, u64> = std::collections::HashMap::new();
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for store in stores {
        for key in store.keys() {
            let sum = store
                .record_checksum(key)?
                .expect("indexed key has a record");
            match first_sum.get(&key) {
                Some(prev) if *prev != sum => {
                    return Err(format!(
                        "merge: conflicting records for key {key} \
                         (sources disagree; refusing to pick one)"
                    ));
                }
                Some(_) => continue,
                None => {
                    first_sum.insert(key, sum);
                }
            }
            let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
            if w.append(&req, &resp)? {
                wrote += 1;
            }
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Rewrite a store densely at `dst` (record order preserved), verifying
/// every record on the way through. Returns `(records, bytes_in,
/// bytes_out)`. Today's writers already produce dense stores, so this is
/// mainly a verify-and-rewrite pass; it exists so a store recovered from
/// forensic tooling or a future in-place format can be normalized.
pub fn compact_store(
    store: &EvalStore,
    dst: impl AsRef<Path>,
) -> Result<(u64, u64, u64), String> {
    let dst = dst.as_ref();
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for key in store.keys() {
        let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
        if w.append(&req, &resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    let bytes_out = std::fs::metadata(dst)
        .map_err(|e| format!("store {}: {e}", dst.display()))?
        .len();
    Ok((wrote, store.file_bytes(), bytes_out))
}

/// What [`repair_store`] recovered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepairReport {
    /// Records carried into the rebuilt store.
    pub records: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Trailing source bytes not decodable as records. For a store torn
    /// mid-append this is the torn tail; for a *finished* store it is
    /// the old index + trailer (rebuilt fresh at `dst`, so nothing is
    /// actually lost).
    pub dropped_bytes: u64,
    /// Why the record scan stopped before the end of the file, if it did.
    pub stopped: Option<String>,
}

/// Recover the valid record prefix of a store torn by a crash —
/// mid-append, mid-index, or mid-trailer — into a fresh, fully indexed
/// store at `dst` (ADR-010). The scan walks records from the header
/// forward and keeps exactly those whose payload checksum and decode
/// land; the first implausible length, checksum mismatch, or undecodable
/// payload ends the prefix (on a finished store that point is the old
/// index, so repair degenerates to [`compact_store`] and keeps every
/// record). The source is never modified.
pub fn repair_store(src: impl AsRef<Path>, dst: impl AsRef<Path>) -> Result<RepairReport, String> {
    use format::{HEADER_BYTES, RECORD_HEADER_BYTES, STORE_MAGIC, STORE_VERSION as V};
    let src = src.as_ref();
    let dst = dst.as_ref();
    let ctx = |e: String| format!("store {}: {e}", src.display());
    let bytes = std::fs::read(src).map_err(|e| ctx(e.to_string()))?;
    if bytes.len() < HEADER_BYTES as usize {
        return Err(ctx(format!(
            "truncated: {} bytes is smaller than a store header ({HEADER_BYTES})",
            bytes.len()
        )));
    }
    if bytes[..8] != STORE_MAGIC {
        return Err(ctx("bad magic (not an eval store)".into()));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    if version != V {
        return Err(ctx(format!(
            "unsupported store version {version} (this build reads version {V})"
        )));
    }
    let flags = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
    if flags != 0 {
        return Err(ctx(format!("unsupported store flags {flags:#x} (v1 defines none)")));
    }

    let mut w = StoreWriter::create(dst)?;
    let mut pos = HEADER_BYTES as usize;
    let mut records = 0u64;
    let mut stopped = None;
    while pos < bytes.len() {
        let remaining = bytes.len() - pos;
        if remaining < RECORD_HEADER_BYTES as usize {
            stopped = Some(format!("incomplete record header at offset {pos}"));
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().expect("4 bytes")) as usize;
        if len > MAX_RECORD_BYTES {
            stopped = Some(format!("implausible record length {len} at offset {pos}"));
            break;
        }
        let body = pos + RECORD_HEADER_BYTES as usize;
        if remaining < RECORD_HEADER_BYTES as usize + len {
            stopped = Some(format!("incomplete record at offset {pos}"));
            break;
        }
        let checksum =
            u64::from_le_bytes(bytes[pos + 4..pos + 12].try_into().expect("8 bytes"));
        let payload = &bytes[body..body + len];
        if crate::util::fnv64(payload) != checksum {
            stopped = Some(format!("record checksum mismatch at offset {pos}"));
            break;
        }
        let (req, resp) = match format::decode_pair(payload) {
            Ok(pair) => pair,
            Err(e) => {
                stopped = Some(format!("undecodable record at offset {pos}: {e}"));
                break;
            }
        };
        w.append(&req, &resp)?;
        records = w.len() as u64; // dedup-aware: first write wins
        pos += RECORD_HEADER_BYTES as usize + len;
    }
    w.finish()?;
    let bytes_out =
        std::fs::metadata(dst).map_err(|e| format!("store {}: {e}", dst.display()))?.len();
    Ok(RepairReport {
        records,
        bytes_in: bytes.len() as u64,
        bytes_out,
        dropped_bytes: (bytes.len() - pos) as u64,
        stopped,
    })
}

/// What [`gc_store`] kept and evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcReport {
    pub kept: u64,
    pub evicted: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Evict least-recently-served keys until the rewritten store fits
/// `max_bytes` (ADR-010). `recency` lists served keys oldest→newest
/// (the `<store>.lru` sidecar [`cached::CachedEvaluator`] appends);
/// keys never served rank coldest, ties break by append order. `pinned`
/// keys are never evicted. A store already under budget is rewritten
/// identically (same records, same order — byte-for-byte the
/// [`compact_store`] output). If even the pinned-only store would bust
/// the budget the call fails in-band rather than evict a pinned key.
pub fn gc_store(
    store: &EvalStore,
    max_bytes: u64,
    dst: impl AsRef<Path>,
    recency: &[EvalKey],
    pinned: &std::collections::HashSet<EvalKey>,
) -> Result<GcReport, String> {
    use format::{HEADER_BYTES, INDEX_ENTRY_BYTES, RECORD_HEADER_BYTES, TRAILER_BYTES};
    let cost = |key: EvalKey| -> u64 {
        let len = store.record_len(key).expect("key from store.keys()") as u64;
        RECORD_HEADER_BYTES + len + INDEX_ENTRY_BYTES
    };
    let mut total = HEADER_BYTES + TRAILER_BYTES;
    for key in store.keys() {
        total += cost(key);
    }

    // coldness order: never-served keys first (append order), then by
    // last service, oldest first
    let mut last_served: std::collections::HashMap<EvalKey, usize> =
        std::collections::HashMap::new();
    for (i, k) in recency.iter().enumerate() {
        last_served.insert(*k, i);
    }
    let mut by_cold: Vec<EvalKey> = store.keys().collect();
    by_cold.sort_by_key(|k| last_served.get(k).copied().map_or(0, |r| r as u64 + 1));

    let mut evict: std::collections::HashSet<EvalKey> = std::collections::HashSet::new();
    let mut candidates = by_cold.iter().filter(|k| !pinned.contains(k));
    while total > max_bytes {
        match candidates.next() {
            Some(k) => {
                total -= cost(*k);
                evict.insert(*k);
            }
            None => {
                return Err(format!(
                    "gc: cannot fit {} under {max_bytes} bytes without evicting a \
                     pinned key (pinned floor is {total} bytes)",
                    store.path().display()
                ));
            }
        }
    }

    let mut w = StoreWriter::create(dst)?;
    let mut kept = 0u64;
    for key in store.keys() {
        if evict.contains(&key) {
            continue;
        }
        let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
        if w.append(&req, &resp)? {
            kept += 1;
        }
    }
    w.finish()?;
    let dst = dst.as_ref();
    let bytes_out =
        std::fs::metadata(dst).map_err(|e| format!("store {}: {e}", dst.display()))?.len();
    Ok(GcReport {
        kept,
        evicted: evict.len() as u64,
        bytes_in: store.file_bytes(),
        bytes_out,
    })
}

/// Read a `<store>.lru` recency sidecar: one lowercase-hex [`EvalKey`]
/// per line, appended oldest→newest by [`cached::CachedEvaluator`] as
/// keys are served. A torn final line (crash mid-append) is skipped; so
/// is anything unparseable — the sidecar is advisory (losing it only
/// costs eviction quality, never correctness).
pub fn read_lru_sidecar(path: impl AsRef<Path>) -> Vec<EvalKey> {
    let Ok(text) = std::fs::read_to_string(path) else { return Vec::new() };
    text.lines()
        .filter_map(|l| u128::from_str_radix(l.trim(), 16).ok().map(EvalKey))
        .collect()
}

/// The conventional recency-sidecar path for a store: `<store>.lru`.
pub fn lru_sidecar_path(store: &Path) -> std::path::PathBuf {
    let mut os = store.as_os_str().to_os_string();
    os.push(".lru");
    std::path::PathBuf::from(os)
}

/// Full structural self-check used by `repro cache stats` and the
/// byte-flip negative suite: read and decode every record (per-record
/// checksum, key match, request JSON). The open-time checks already
/// guarantee the index tiles the data region exactly, so open +
/// `verify_store` together validate every byte of the file — which is
/// what lets the fuzz suite assert that *any* single-byte corruption is
/// caught in-band.
pub fn verify_store(store: &EvalStore) -> Result<(), String> {
    for key in store.keys() {
        let _ = store.get_pair(key)?;
    }
    Ok(())
}
