//! Persistent content-addressed eval store (ADR-008).
//!
//! At production scale no `(arch, problem, config, seed)` measurement
//! should ever be paid for twice — across runs, users, or fleet nodes.
//! `EvalKey` (ADR-005) is already a process-stable 128-bit content hash
//! and the JSONL trace (ADR-004) already keys every measurement; this
//! module adds the missing storage layer:
//!
//! - [`format`] — binary trace format v1: append-only length-prefixed
//!   records under a magic + version header, with a key→offset index
//!   footer. A million-measurement store opens by reading its index
//!   (28 bytes/record, no JSON) and serves each hit with one `pread`.
//! - [`cached`] — [`CachedEvaluator`], the ADR-003 `Evaluator` that
//!   layers in-memory map → binary store → live backend with
//!   write-through, plus [`StoreMonitor`] counters and the
//!   [`cache_session`] CLI constructor.
//! - this file — bridges and maintenance: lossless export/import to the
//!   JSONL v2 trace (which stays the diagnostic/interchange format),
//!   `EvalKey::shard`-based partitioning, conflict-checked merge, and
//!   compaction.
//!
//! Single-writer discipline: exactly one process may hold a store's
//! [`StoreWriter`] (recording runs); any number may read. `repro serve`
//! therefore opens caches read-through/offline on the coordinator and
//! its workers — fleets consume stores, recording runs produce them.

pub mod cached;
pub mod format;

pub use cached::{cache_session, CacheMode, CacheSessionMode, CachedEvaluator, StoreMonitor};
pub use format::{EvalStore, StoreWriter, MAX_RECORD_BYTES, STORE_VERSION};

use std::io::Write;
use std::path::Path;

use crate::eval::trace::{header_line, pair_to_line, parse_trace_pairs};
use crate::eval::EvalKey;

/// Export a binary store to a JSONL v2 trace, in record order, emitting
/// exactly the bytes a `RecordingEvaluator` would have written for the
/// same pairs — so the export replays under `TraceEvaluator` and
/// re-imports losslessly (floats travel as shortest-roundtrip decimals
/// that reparse bit-identically). Returns the number of records.
pub fn export_jsonl(store: &EvalStore, dst: impl AsRef<Path>) -> Result<u64, String> {
    let dst = dst.as_ref();
    let ctx = |e: String| format!("trace {}: {e}", dst.display());
    let file = std::fs::File::create(dst).map_err(|e| ctx(format!("cannot create: {e}")))?;
    let mut out = std::io::BufWriter::new(file);
    writeln!(out, "{}", header_line()).map_err(|e| ctx(e.to_string()))?;
    let mut wrote = 0u64;
    for key in store.keys() {
        let (req, resp) = store
            .get_pair(key)?
            .expect("indexed key has a record");
        writeln!(out, "{}", pair_to_line(&req, &resp)).map_err(|e| ctx(e.to_string()))?;
        wrote += 1;
    }
    out.flush().map_err(|e| ctx(e.to_string()))?;
    Ok(wrote)
}

/// Import a JSONL v2 trace into a fresh binary store at `dst`
/// (truncating), with the trace parser's full validation (version gate,
/// key match, conflicting-duplicate rejection). Line order becomes
/// record order. Returns the number of records.
pub fn import_jsonl(src: impl AsRef<Path>, dst: impl AsRef<Path>) -> Result<u64, String> {
    let src = src.as_ref();
    let text = std::fs::read_to_string(src)
        .map_err(|e| format!("trace {}: {e}", src.display()))?;
    let pairs = parse_trace_pairs(&text, &src.display().to_string())?;
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for (req, resp) in &pairs {
        if w.append(req, resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Copy the records whose [`EvalKey::shard`] lands on `index` (of `of`)
/// into a fresh store at `dst`, preserving record order — the store-level
/// analogue of `repro shard --index I --of N` (ADR-003). Returns the
/// number of records copied.
pub fn shard_store(
    store: &EvalStore,
    index: usize,
    of: usize,
    dst: impl AsRef<Path>,
) -> Result<u64, String> {
    if of == 0 || index >= of {
        return Err(format!("bad shard spec: index {index} of {of}"));
    }
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for key in store.keys() {
        if key.shard(of) != index {
            continue;
        }
        let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
        if w.append(&req, &resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Merge stores into a fresh store at `dst`: first occurrence of a key
/// wins its record order; a key present in several sources must carry an
/// identical record everywhere (compared by canonical payload checksum),
/// otherwise the merge fails in-band — the same conflicting-duplicate
/// discipline as the trace parser and the PR 3 shard merge. Returns the
/// number of records written.
pub fn merge_stores(stores: &[&EvalStore], dst: impl AsRef<Path>) -> Result<u64, String> {
    let mut first_sum: std::collections::HashMap<EvalKey, u64> = std::collections::HashMap::new();
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for store in stores {
        for key in store.keys() {
            let sum = store
                .record_checksum(key)?
                .expect("indexed key has a record");
            match first_sum.get(&key) {
                Some(prev) if *prev != sum => {
                    return Err(format!(
                        "merge: conflicting records for key {key} \
                         (sources disagree; refusing to pick one)"
                    ));
                }
                Some(_) => continue,
                None => {
                    first_sum.insert(key, sum);
                }
            }
            let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
            if w.append(&req, &resp)? {
                wrote += 1;
            }
        }
    }
    w.finish()?;
    Ok(wrote)
}

/// Rewrite a store densely at `dst` (record order preserved), verifying
/// every record on the way through. Returns `(records, bytes_in,
/// bytes_out)`. Today's writers already produce dense stores, so this is
/// mainly a verify-and-rewrite pass; it exists so a store recovered from
/// forensic tooling or a future in-place format can be normalized.
pub fn compact_store(
    store: &EvalStore,
    dst: impl AsRef<Path>,
) -> Result<(u64, u64, u64), String> {
    let dst = dst.as_ref();
    let mut w = StoreWriter::create(dst)?;
    let mut wrote = 0u64;
    for key in store.keys() {
        let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
        if w.append(&req, &resp)? {
            wrote += 1;
        }
    }
    w.finish()?;
    let bytes_out = std::fs::metadata(dst)
        .map_err(|e| format!("store {}: {e}", dst.display()))?
        .len();
    Ok((wrote, store.file_bytes(), bytes_out))
}

/// Full structural self-check used by `repro cache stats` and the
/// byte-flip negative suite: read and decode every record (per-record
/// checksum, key match, request JSON). The open-time checks already
/// guarantee the index tiles the data region exactly, so open +
/// `verify_store` together validate every byte of the file — which is
/// what lets the fuzz suite assert that *any* single-byte corruption is
/// caught in-band.
pub fn verify_store(store: &EvalStore) -> Result<(), String> {
    for key in store.keys() {
        let _ = store.get_pair(key)?;
    }
    Ok(())
}
