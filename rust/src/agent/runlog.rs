//! Run logs: everything downstream analysis needs. The scheduler replays
//! them offline (paper §5.7), the integrity pipeline labels them (§5.8),
//! and the metrics module turns them into Fast-p curves (§5.6).

use crate::util::json::Json;

use super::attempt::{AttemptOutcome, AttemptRecord};

/// All attempts for one problem under one variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ProblemRun {
    pub problem_idx: usize,
    /// Measured PyTorch baseline (ms).
    pub t_ref_ms: f64,
    /// TF32 SOL bound (ms).
    pub t_sol_ms: f64,
    /// FP16-augmented SOL bound (ms) — scheduling/integrity ceiling.
    pub t_sol_fp16_ms: f64,
    pub attempts: Vec<AttemptRecord>,
}

impl ProblemRun {
    /// Best measured time over all correct attempts (any solution kind —
    /// integrity filtering is applied offline, as in the paper).
    pub fn best_time_ms(&self) -> Option<f64> {
        self.attempts
            .iter()
            .filter_map(|a| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Best time over genuine custom kernels only (excludes gaming and
    /// PyTorch-only) — what integrity filtering converges to when detectors
    /// are perfect.
    pub fn best_honest_time_ms(&self) -> Option<f64> {
        self.attempts
            .iter()
            .filter(|a| {
                matches!(
                    a.kind,
                    super::attempt::SolutionKind::DslKernel
                        | super::attempt::SolutionKind::RawCuda
                )
            })
            .filter_map(|a| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Best-so-far time after the first `n` attempts.
    pub fn best_time_after(&self, n: usize) -> Option<f64> {
        self.attempts
            .iter()
            .take(n)
            .filter_map(|a| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Speedup over PyTorch (unfiltered); None when never solved.
    pub fn speedup(&self) -> Option<f64> {
        self.best_time_ms().map(|t| self.t_ref_ms / t)
    }

    /// Total LLM tokens spent on this problem.
    pub fn total_tokens(&self) -> u64 {
        self.attempts.iter().map(|a| a.tokens).sum()
    }

    /// Total tool-action time (s).
    pub fn total_tool_time_s(&self) -> f64 {
        self.attempts.iter().map(|a| a.tool_time_s).sum()
    }

    /// Number of attempts that reached the toolchain (non-DslRejected and
    /// non-Pruned — the two static short-circuits that save a trial).
    pub fn tool_actions(&self) -> usize {
        self.attempts
            .iter()
            .filter(|a| {
                !matches!(
                    a.outcome,
                    AttemptOutcome::DslRejected | AttemptOutcome::Pruned { .. }
                )
            })
            .count()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("problem_idx", self.problem_idx)
            .set("t_ref_ms", self.t_ref_ms)
            .set("t_sol_ms", self.t_sol_ms)
            .set("t_sol_fp16_ms", self.t_sol_fp16_ms)
            .set(
                "attempts",
                Json::Arr(self.attempts.iter().map(|a| a.to_json()).collect()),
            );
        o
    }

    /// Inverse of [`Self::to_json`] — exact round-trip, including the
    /// attempts' compiled plans (reconstructed through `plans`). This is
    /// what lets `repro merge` reassemble shard output field-for-field
    /// identical to a single-process run (floats survive: the JSON writer
    /// emits shortest-roundtrip representations).
    pub fn from_json(
        j: &Json,
        plans: &mut crate::dsl::PlanCache,
    ) -> Result<ProblemRun, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("run: missing {k}"));
        Ok(ProblemRun {
            problem_idx: field("problem_idx")?
                .as_u64()
                .ok_or("run: bad problem_idx")? as usize,
            t_ref_ms: field("t_ref_ms")?.as_f64().ok_or("run: bad t_ref_ms")?,
            t_sol_ms: field("t_sol_ms")?.as_f64().ok_or("run: bad t_sol_ms")?,
            t_sol_fp16_ms: field("t_sol_fp16_ms")?
                .as_f64()
                .ok_or("run: bad t_sol_fp16_ms")?,
            attempts: field("attempts")?
                .as_arr()
                .ok_or("run: attempts not an array")?
                .iter()
                .map(|a| AttemptRecord::from_json(a, plans))
                .collect::<Result<Vec<_>, String>>()?,
        })
    }
}

/// A complete run: one variant over the whole suite.
#[derive(Debug, Clone, PartialEq)]
pub struct RunLog {
    /// Variant label, e.g. "µCUTLASS + SOL [gpt-5]".
    pub variant: String,
    pub tier_name: String,
    pub price_per_mtok: f64,
    pub runs: Vec<ProblemRun>,
}

impl RunLog {
    /// Unfiltered speedups (1.0 fallback for unsolved — the PyTorch seed
    /// remains in cuda_model.cu).
    pub fn speedups(&self) -> Vec<f64> {
        self.runs.iter().map(|r| r.speedup().unwrap_or(1.0)).collect()
    }

    pub fn total_tokens(&self) -> u64 {
        self.runs.iter().map(|r| r.total_tokens()).sum()
    }

    /// Total dollar cost at this tier's input-token price.
    pub fn dollar_cost(&self) -> f64 {
        self.total_tokens() as f64 / 1e6 * self.price_per_mtok
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("variant", self.variant.clone())
            .set("tier", self.tier_name.clone())
            .set("price_per_mtok", self.price_per_mtok)
            .set("runs", Json::Arr(self.runs.iter().map(|r| r.to_json()).collect()));
        o
    }

    /// Inverse of [`Self::to_json`] (see [`ProblemRun::from_json`]).
    pub fn from_json(j: &Json, plans: &mut crate::dsl::PlanCache) -> Result<RunLog, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("log: missing {k}"));
        Ok(RunLog {
            variant: field("variant")?
                .as_str()
                .ok_or("log: variant not a string")?
                .to_string(),
            tier_name: field("tier")?.as_str().ok_or("log: tier not a string")?.to_string(),
            price_per_mtok: field("price_per_mtok")?
                .as_f64()
                .ok_or("log: bad price_per_mtok")?,
            runs: field("runs")?
                .as_arr()
                .ok_or("log: runs not an array")?
                .iter()
                .map(|r| ProblemRun::from_json(r, plans))
                .collect::<Result<Vec<_>, String>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::attempt::{AttemptOutcome, AttemptRecord, SolutionKind};

    fn rec(attempt: u32, outcome: AttemptOutcome, kind: SolutionKind) -> AttemptRecord {
        AttemptRecord {
            problem_idx: 0,
            attempt,
            outcome,
            kind,
            minor_issue: None,
            inherited: false,
            tokens: 1000,
            tool_time_s: 60.0,
            config: None,
            kernel_names: vec![],
            dsl_source: None,
            dsl_plan: None,
        }
    }

    #[test]
    fn best_time_tracks_minimum() {
        let run = ProblemRun {
            problem_idx: 0,
            t_ref_ms: 10.0,
            t_sol_ms: 1.0,
            t_sol_fp16_ms: 0.5,
            attempts: vec![
                rec(0, AttemptOutcome::Incorrect, SolutionKind::RawCuda),
                rec(1, AttemptOutcome::Correct { time_ms: 5.0 }, SolutionKind::RawCuda),
                rec(2, AttemptOutcome::Correct { time_ms: 3.0 }, SolutionKind::DslKernel),
            ],
        };
        assert_eq!(run.best_time_ms(), Some(3.0));
        assert_eq!(run.best_time_after(2), Some(5.0));
        assert_eq!(run.best_time_after(1), None);
        assert!((run.speedup().unwrap() - 10.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn honest_best_excludes_gaming() {
        let run = ProblemRun {
            problem_idx: 0,
            t_ref_ms: 10.0,
            t_sol_ms: 1.0,
            t_sol_fp16_ms: 0.5,
            attempts: vec![
                rec(0, AttemptOutcome::Correct { time_ms: 0.1 },
                    SolutionKind::Gaming(super::super::attempt::GamingType::ConstantOutput)),
                rec(1, AttemptOutcome::Correct { time_ms: 4.0 }, SolutionKind::RawCuda),
            ],
        };
        assert_eq!(run.best_time_ms(), Some(0.1));
        assert_eq!(run.best_honest_time_ms(), Some(4.0));
    }

    #[test]
    fn tool_actions_exclude_dsl_rejections() {
        let run = ProblemRun {
            problem_idx: 0,
            t_ref_ms: 10.0,
            t_sol_ms: 1.0,
            t_sol_fp16_ms: 0.5,
            attempts: vec![
                rec(0, AttemptOutcome::DslRejected, SolutionKind::DslKernel),
                rec(1, AttemptOutcome::CompileError, SolutionKind::RawCuda),
            ],
        };
        assert_eq!(run.tool_actions(), 1);
    }
}
