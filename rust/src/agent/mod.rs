//! SimLLM agent policies and controllers (paper §5.4–§5.5).
//!
//! The paper's agents are GPT-5-mini / GPT-5 / GPT-5.2 driving an
//! OpenHands runtime on an H100. Neither is available here, so the agent
//! is a seeded stochastic *policy model* ([`tiers::TierParams`]) whose
//! behaviour distributions are calibrated from the paper's own reported
//! per-tier statistics (solve rates, gaming counts, quality of raw CUDA).
//! Everything downstream — the DSL compiler, SOL analysis, MANTIS
//! phases, budget scheduling, integrity checking — is the *real* system
//! under test acting on those behaviours (DESIGN.md §2).
//!
//! Key fidelity point: when a DSL-enabled agent emits a candidate it emits
//! an actual µCUTLASS source string which goes through the real
//! [`crate::dsl`] compiler; statically-invalid programs are caught by the
//! real validator at near-zero cost, exactly the mechanism the paper
//! credits for the DSL's iteration-efficiency gains.

pub mod attempt;
pub mod controller;
pub mod policy;
pub mod runlog;
pub mod session;
pub mod tiers;

pub use attempt::{AttemptOutcome, AttemptRecord, GamingType, MinorIssueType, SolutionKind};
pub use controller::{run_problem, ControllerKind, VariantSpec};
pub use runlog::{ProblemRun, RunLog};
pub use session::{FlatSession, ProblemSession, StepResult};
pub use tiers::{ModelTier, TierParams};
