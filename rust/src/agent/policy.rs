//! Optimization-move machinery and the µCUTLASS source generator.
//!
//! Agents search the same configuration landscape the performance model
//! costs: tiles, compute precision, fusion, schedulers, pipeline depth,
//! residual code quality. A *move* mutates the current best config; move
//! *selection* is where model capability and SOL steering act — steering
//! shrinks the noise on the agent's own impact estimates and filters moves
//! to the ones targeting the analyzed bottleneck (paper §4.2).

use std::cell::RefCell;

use crate::dsl;
use crate::eval::{EvalRequest, Evaluator, Oracle};
use crate::kernelbench::{Op, Problem};
use crate::perfmodel::{CandidateConfig, ConfigBatch, SchedulerKind};
use crate::sol::{Bottleneck, SolAnalysis};
use crate::util::rng::Pcg32;

use super::tiers::TierParams;

thread_local! {
    /// Reusable scratch for the direct (no-override) estimation path of
    /// [`select_move`]: the move pool is lowered straight into a
    /// struct-of-arrays batch, so a selection round performs no
    /// per-candidate allocation once the columns are warm (ADR-006).
    static SCRATCH: RefCell<(ConfigBatch, Vec<f64>)> =
        RefCell::new((ConfigBatch::new(), Vec::new()));
}

/// The tile menu agents choose from (MXU/WGMMA-shaped).
pub const TILES: &[(u64, u64, u64)] = &[
    (64, 64, 32),
    (64, 64, 64),
    (128, 64, 32),
    (128, 64, 64),
    (128, 128, 32),
    (128, 128, 64),
    (256, 128, 32),
    (256, 128, 64),
    (64, 128, 64),
    (128, 256, 32),
];

/// One optimization move (also the MANTIS hypothesis vocabulary, §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptMove {
    /// Switch to tile `TILES[i]`.
    Tile(usize),
    /// Cast to FP16 tensor-core math on-chip (I/O stays FP32).
    UseFp16,
    /// Cast to BF16.
    UseBf16,
    /// Fuse the full op graph (epilogues + neighbours) into one kernel.
    FuseAll,
    /// Persistent tile scheduler.
    SchedulerPersistent,
    /// Stream-K scheduler.
    SchedulerStreamK,
    /// Deepen the async pipeline.
    MoreStages,
    /// Rewrite for code quality (raw path: vectorization, smem use, …).
    ImproveCode,
}

pub const ALL_MOVE_KINDS: usize = 8;

/// Enumerate the plausible moves from a config.
pub fn moves_from(cfg: &CandidateConfig) -> Vec<OptMove> {
    let mut v = Vec::with_capacity(16);
    for i in 0..TILES.len() {
        if TILES[i] != cfg.tile {
            v.push(OptMove::Tile(i));
        }
    }
    if cfg.compute_dtype != dsl::DType::Fp16 {
        v.push(OptMove::UseFp16);
    }
    if cfg.compute_dtype != dsl::DType::Bf16 {
        v.push(OptMove::UseBf16);
    }
    if cfg.fusion_coverage < 1.0 || !cfg.fused_epilogue {
        v.push(OptMove::FuseAll);
    }
    if cfg.scheduler != SchedulerKind::Persistent {
        v.push(OptMove::SchedulerPersistent);
    }
    if cfg.scheduler != SchedulerKind::StreamK {
        v.push(OptMove::SchedulerStreamK);
    }
    if cfg.stages < 4 {
        v.push(OptMove::MoreStages);
    }
    if cfg.quality < 0.95 {
        v.push(OptMove::ImproveCode);
    }
    v
}

/// Apply a move to a config. `quality_gain` is how much an ImproveCode
/// rewrite recovers (tier-dependent).
pub fn apply_move(cfg: &CandidateConfig, mv: OptMove, quality_gain: f64) -> CandidateConfig {
    let mut c = cfg.clone();
    match mv {
        OptMove::Tile(i) => c.tile = TILES[i],
        OptMove::UseFp16 => c.compute_dtype = dsl::DType::Fp16,
        OptMove::UseBf16 => c.compute_dtype = dsl::DType::Bf16,
        OptMove::FuseAll => {
            c.fusion_coverage = 1.0;
            c.fused_epilogue = true;
        }
        OptMove::SchedulerPersistent => c.scheduler = SchedulerKind::Persistent,
        OptMove::SchedulerStreamK => c.scheduler = SchedulerKind::StreamK,
        OptMove::MoreStages => c.stages = (c.stages + 1).min(4),
        OptMove::ImproveCode => c.quality = (c.quality + quality_gain).min(0.95),
    }
    c
}

/// Is a move relevant to the analyzed bottleneck? SOL steering filters the
/// nomination pool with this (paper: "nominate hypotheses that target the
/// dominant performance gaps").
pub fn targets_bottleneck(mv: OptMove, b: Bottleneck) -> bool {
    match b {
        Bottleneck::Compute => matches!(
            mv,
            OptMove::UseFp16
                | OptMove::UseBf16
                | OptMove::Tile(_)
                | OptMove::MoreStages
                | OptMove::ImproveCode
                | OptMove::FuseAll
                | OptMove::SchedulerPersistent
                | OptMove::SchedulerStreamK
        ),
        Bottleneck::Memory => matches!(
            mv,
            OptMove::FuseAll | OptMove::Tile(_) | OptMove::ImproveCode | OptMove::MoreStages
        ),
    }
}

/// Select a move. `steering` carries the SOL analysis when the controller
/// is SOL-guided; it (a) filters moves to the bottleneck and (b) shrinks
/// estimate noise, modelling the structured Analyze→Nominate phases.
/// Candidate estimation is batched: with no backend override the pool is
/// lowered into a reusable [`ConfigBatch`] and priced by the problem's
/// pre-compiled evaluator (ADR-006); with an override (record/replay) one
/// `eval_batch` of requests covers the current config plus every move in
/// the pool so the backend observes each of them (ADR-004). The two paths
/// produce bitwise-identical estimates.
pub fn select_move(
    ev: &Oracle,
    pidx: usize,
    cfg: &CandidateConfig,
    tier: &TierParams,
    steering: Option<&SolAnalysis>,
    quality_gain: f64,
    rng: &mut Pcg32,
) -> Option<(OptMove, f64)> {
    let mut pool = moves_from(cfg);
    if pool.is_empty() {
        return None;
    }
    if let Some(sol) = steering {
        let filtered: Vec<OptMove> = pool
            .iter()
            .copied()
            .filter(|m| targets_bottleneck(*m, sol.bottleneck))
            .collect();
        if !filtered.is_empty() {
            pool = filtered;
        }
    }
    let sigma = tier.estimate_sigma * if steering.is_some() { 0.4 } else { 1.5 };
    // The model sometimes doesn't reason at all and picks randomly.
    let reasoned = rng.chance(tier.move_quality + if steering.is_some() { 0.25 } else { 0.0 });
    if !reasoned {
        let mv = *rng.choice(&pool);
        let est = 1.0;
        return Some((mv, est));
    }
    // est[0] is the current config, est[1..] the pool in order; the RNG
    // draw sequence is the same on both estimation paths below.
    let pick = |est: &[f64], rng: &mut Pcg32| {
        let t_now = est[0];
        let mut best: Option<(OptMove, f64)> = None; // (move, noisy estimate)
        for (&mv, &t_new) in pool.iter().zip(&est[1..]) {
            let true_speedup = t_now / t_new;
            let bias = match mv {
                OptMove::UseFp16 | OptMove::UseBf16 => tier.fp16_move_bias,
                _ => 1.0,
            };
            let noisy = true_speedup * rng.lognormal_noise(sigma) * bias;
            if best.as_ref().map(|(_, b)| noisy > *b).unwrap_or(true) {
                best = Some((mv, noisy));
            }
        }
        best
    };
    match ev.direct() {
        // No backend override: lower the pool into the reusable
        // struct-of-arrays scratch and price it with the problem's
        // compiled evaluator — no `EvalRequest`s, no allocation (ADR-006).
        Some(analytic) => SCRATCH.with(|s| {
            let (batch, out) = &mut *s.borrow_mut();
            batch.clear();
            batch.reserve(pool.len() + 1);
            batch.push(cfg);
            for &mv in &pool {
                batch.push(&apply_move(cfg, mv, quality_gain));
            }
            out.clear();
            analytic.candidate_batch_into(pidx, batch, out);
            pick(out, rng)
        }),
        // Override installed (record/replay, ADR-004): the backend must
        // observe every request, so build the batched request path. The
        // values are bitwise equal to the direct path, so the RNG draws
        // and everything downstream are identical.
        None => {
            let reqs: Vec<EvalRequest> = std::iter::once(cfg.clone())
                .chain(pool.iter().map(|&mv| apply_move(cfg, mv, quality_gain)))
                .map(|c| EvalRequest::candidate(pidx, c))
                .collect();
            let est: Vec<f64> = ev.eval_batch(&reqs).iter().map(|r| r.value).collect();
            pick(&est, rng)
        }
    }
}

// ---------------------------------------------------------------------------
// µCUTLASS source generation (with tier-dependent validity mistakes)
// ---------------------------------------------------------------------------

/// Validity mistakes weaker models make; each is caught by the *real*
/// validator, exercising the paper's static-rejection path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DslMistake {
    /// `sm_90` instead of `sm_90a` (SM90 constraint 1).
    Sm90NotA,
    /// `.with_tile()` on SM90+ (constraint 2).
    WithTileOnSm90,
    /// fp16 with alignment 4 — TMA violation (constraint 3).
    BadAlignment,
    /// tma_cooperative without explicit stages (constraint 6).
    CoopNoStages,
    /// Tile not MMA-atom aligned.
    MisalignedTile,
}

pub const DSL_MISTAKES: [DslMistake; 5] = [
    DslMistake::Sm90NotA,
    DslMistake::WithTileOnSm90,
    DslMistake::BadAlignment,
    DslMistake::CoopNoStages,
    DslMistake::MisalignedTile,
];

/// Epilogue chain the DSL program should carry for this problem, derived
/// from the trailing elementwise structure of the op graph.
fn epilogue_for(problem: &Problem) -> &'static str {
    let n = problem.name;
    if n.contains("bias_relu") {
        " >> bias() >> relu()"
    } else if n.contains("gelu") {
        " >> scale(0.5) >> gelu()"
    } else if n.contains("silu") || n.contains("swish") || n.contains("swiglu") {
        " >> silu() >> scale(1.5)"
    } else if n.contains("sigmoid") {
        " >> sigmoid()"
    } else if n.contains("mish") {
        " >> mish()"
    } else if n.contains("clamp") {
        " >> silu() >> clamp(lo=0.0, hi=6.0)"
    } else if matches!(problem.ops.last(), Some(Op::Elementwise { .. })) {
        " >> relu()"
    } else {
        ""
    }
}

/// Generate µCUTLASS source realizing `cfg` for `problem`, optionally with
/// an injected validity mistake.
pub fn dsl_source(
    problem: &Problem,
    cfg: &CandidateConfig,
    mistake: Option<DslMistake>,
) -> String {
    let (tm, tn, tk) = cfg.tile;
    let (tm, tn) = match mistake {
        Some(DslMistake::MisalignedTile) => (tm + 4, tn),
        _ => (tm, tn),
    };
    let dt = match cfg.compute_dtype {
        dsl::DType::Fp16 => "fp16",
        dsl::DType::Bf16 => "bf16",
        _ => "fp32",
    };
    let out_dt = "fp32"; // I/O stays FP32 per KernelBench
    let arch = match mistake {
        Some(DslMistake::Sm90NotA) => "sm_90",
        _ => "sm_90a",
    };
    let align = match (cfg.compute_dtype, mistake) {
        (_, Some(DslMistake::BadAlignment)) => 4,
        (dsl::DType::Fp16 | dsl::DType::Bf16, _) => 8,
        _ => 4,
    };
    // fp16 in / fp32 out: C alignment must still satisfy TMA for fp32 (>=4)
    let c_align = 4;
    let tile_call = match mistake {
        Some(DslMistake::WithTileOnSm90) => "with_tile",
        _ => "with_threadblockshape",
    };
    let sched = match cfg.scheduler {
        SchedulerKind::Persistent => "tile=persistent, kernel=tma, epilogue=auto",
        SchedulerKind::StreamK => "tile=stream_k, kernel=tma, epilogue=auto",
        SchedulerKind::Default => "kernel=tma_cooperative, epilogue=auto",
    };
    let stages = match mistake {
        Some(DslMistake::CoopNoStages) if cfg.scheduler == SchedulerKind::Default => String::new(),
        _ => format!(".with_stages({})", cfg.stages.clamp(2, 4)),
    };
    let epi = if cfg.fused_epilogue { epilogue_for(problem) } else { "" };

    let op_call = match problem.dominant_op() {
        Op::BatchedGemm { .. } | Op::Attention { .. } => "batched_gemm()",
        Op::Conv1d { kw, groups, .. } => {
            return format!(
                "conv1d_fprop(kernel_w={kw}).with_dtype(input={dt}, acc=fp32, output={out_dt})\n\
                 .with_arch(sm_89).with_tile(m={tm}, n={tn}, k={tk})\n\
                 .with_alignment(A={align}, B={align}, C={c_align}).with_stages({}){}",
                cfg.stages.clamp(2, 4),
                if *groups > 1 { "\n# depthwise variant routed via group lowering" } else { "" },
            );
        }
        _ => "gemm()",
    };
    format!(
        "{op_call}.with_dtype(input={dt}, acc=fp32, output={out_dt})\n\
         .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch({arch})\n\
         .{tile_call}(m={tm}, n={tn}, k={tk})\n\
         .with_alignment(A={align}, B={align}, C={c_align}){stages}\n\
         .with_scheduler({sched}){epi}"
    )
}

/// Run the generate→validate→repair loop for one DSL attempt. Returns the
/// accepted source together with its already-lowered, validated IR (so
/// the caller never re-runs the front-end) and the try count, or None if
/// the model failed to produce a valid program within `max_tries`
/// (→ DslRejected; still no tool action spent).
pub fn generate_valid_dsl(
    problem: &Problem,
    cfg: &CandidateConfig,
    tier: &TierParams,
    rng: &mut Pcg32,
    max_tries: u32,
) -> (Option<(String, dsl::ProgramIr)>, u32) {
    let mut tries = 0;
    loop {
        tries += 1;
        let mistake = if rng.chance(tier.dsl_invalid_rate / tries as f64) {
            Some(*rng.choice(&DSL_MISTAKES))
        } else {
            None
        };
        let src = dsl_source(problem, cfg, mistake);
        // codegen-free validation: the repair loop only needs the verdict
        match dsl::validate_source(&src) {
            Ok(ir) => return (Some((src, ir)), tries),
            Err(_) if tries < max_tries => continue, // repair from the hint
            Err(_) => return (None, tries),
        }
    }
}

/// Is µCUTLASS applicable to this problem? The DSL covers GEMM/conv
/// families (paper Table 1a); pure elementwise/softmax/scan problems fall
/// back to raw CUDA in every variant.
pub fn dsl_applicable(problem: &Problem) -> bool {
    problem.is_matmul_like()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelbench::{find, suite};
    use crate::sol::{analyze, H100_SXM};

    #[test]
    fn clean_dsl_source_compiles() {
        let s = suite();
        for key in ["L1-1", "L2-76", "L2-86", "L1-3", "L1-67", "L3-43"] {
            let p = &s[find(&s, key).unwrap()];
            let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp16);
            let src = dsl_source(p, &cfg, None);
            dsl::compile(&src).unwrap_or_else(|e| panic!("{key}: {e}\n{src}"));
        }
    }

    #[test]
    fn every_mistake_is_caught_statically() {
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp16);
        for m in DSL_MISTAKES {
            let src = dsl_source(p, &cfg, Some(m));
            let err = dsl::compile(&src).expect_err(&format!("{m:?} should be rejected"));
            assert!(err.is_static(), "{m:?} must be a static rejection");
        }
    }

    #[test]
    fn generate_valid_dsl_repairs() {
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp16);
        let mut rng = Pcg32::new(5, 1);
        let mut accepted = 0;
        for _ in 0..100 {
            let (src, _tries) = generate_valid_dsl(p, &cfg, &crate::agent::tiers::MINI, &mut rng, 3);
            if let Some((src, ir)) = src {
                let compiled = dsl::compile(&src).unwrap();
                assert_eq!(compiled.ir, ir, "returned IR matches a fresh front-end run");
                accepted += 1;
            }
        }
        assert!(accepted >= 95, "repair loop should almost always converge, got {accepted}");
    }

    #[test]
    fn moves_enumerate_and_apply() {
        let cfg = CandidateConfig::library((64, 64, 32), dsl::DType::Fp32);
        let pool = moves_from(&cfg);
        assert!(pool.contains(&OptMove::UseFp16));
        let c2 = apply_move(&cfg, OptMove::UseFp16, 0.1);
        assert_eq!(c2.compute_dtype, dsl::DType::Fp16);
        let c3 = apply_move(&cfg, OptMove::Tile(5), 0.1);
        assert_eq!(c3.tile, TILES[5]);
    }

    #[test]
    fn steered_selection_finds_fp16_on_compute_bound() {
        let s = suite();
        let pidx = find(&s, "L1-1").unwrap(); // compute-bound GEMM
        let sols: Vec<SolAnalysis> = s.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let model = crate::perfmodel::PerfModel::new(H100_SXM.clone());
        let compiled = crate::perfmodel::CompiledCostModel::compile(&model, &s);
        let ev = crate::eval::Oracle::analytic(crate::eval::AnalyticEvaluator::new(
            &model, &s, &sols, &compiled,
        ));
        let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp32);
        let mut hits = 0;
        let mut rng = Pcg32::new(11, 1);
        for _ in 0..50 {
            if let Some((mv, _)) = select_move(
                &ev, pidx, &cfg, &crate::agent::tiers::MID, Some(&sols[pidx]), 0.1, &mut rng,
            ) {
                if matches!(mv, OptMove::UseFp16 | OptMove::UseBf16) {
                    hits += 1;
                }
            }
        }
        assert!(hits > 30, "steered mid-tier should usually pick reduced precision, got {hits}/50");
    }

    #[test]
    fn unsteered_mini_is_noisier() {
        let s = suite();
        let pidx = find(&s, "L1-1").unwrap();
        let sols: Vec<SolAnalysis> = s.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let model = crate::perfmodel::PerfModel::new(H100_SXM.clone());
        let compiled = crate::perfmodel::CompiledCostModel::compile(&model, &s);
        let ev = crate::eval::Oracle::analytic(crate::eval::AnalyticEvaluator::new(
            &model, &s, &sols, &compiled,
        ));
        let cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp32);
        let mut hits = 0;
        let mut rng = Pcg32::new(13, 1);
        for _ in 0..60 {
            if let Some((mv, _)) =
                select_move(&ev, pidx, &cfg, &crate::agent::tiers::MINI, None, 0.1, &mut rng)
            {
                if matches!(mv, OptMove::UseFp16 | OptMove::UseBf16) {
                    hits += 1;
                }
            }
        }
        assert!(hits < 45, "unsteered mini should miss the best move often, got {hits}/60");
    }

    #[test]
    fn direct_and_overridden_estimation_paths_select_identically() {
        // the direct compiled-scratch path and the EvalRequest path must
        // produce the same estimates bit-for-bit, hence — from the same
        // RNG state — the same selected move and noisy estimate
        let s = suite();
        let sols: Vec<SolAnalysis> = s.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let model = crate::perfmodel::PerfModel::new(H100_SXM.clone());
        let compiled = crate::perfmodel::CompiledCostModel::compile(&model, &s);
        let analytic = crate::eval::AnalyticEvaluator::new(&model, &s, &sols, &compiled);
        let direct = crate::eval::Oracle::analytic(analytic);
        let owned = crate::eval::OwnedAnalytic::new();
        let via_backend = crate::eval::Oracle::with_backend(analytic, Some(&owned));
        assert!(direct.direct().is_some());
        assert!(via_backend.direct().is_none());
        let mut cfg = CandidateConfig::library((128, 128, 64), dsl::DType::Fp32);
        cfg.quality = 0.8;
        for pidx in [find(&s, "L1-1").unwrap(), find(&s, "L1-23").unwrap()] {
            for seed in 0..20u64 {
                let tier = &crate::agent::tiers::MID;
                let mut r1 = Pcg32::new(seed, 3);
                let mut r2 = Pcg32::new(seed, 3);
                let a = select_move(&direct, pidx, &cfg, tier, Some(&sols[pidx]), 0.1, &mut r1);
                let b =
                    select_move(&via_backend, pidx, &cfg, tier, Some(&sols[pidx]), 0.1, &mut r2);
                match (a, b) {
                    (Some((ma, ea)), Some((mb, eb))) => {
                        assert_eq!(ma, mb, "seed {seed}");
                        assert_eq!(ea.to_bits(), eb.to_bits(), "seed {seed}");
                    }
                    (a, b) => assert!(a.is_none() && b.is_none(), "seed {seed}"),
                }
            }
        }
    }

    #[test]
    fn dsl_applicability() {
        let s = suite();
        assert!(dsl_applicable(&s[find(&s, "L1-1").unwrap()]));
        assert!(!dsl_applicable(&s[find(&s, "L1-23").unwrap()])); // softmax
        assert!(!dsl_applicable(&s[find(&s, "L1-89").unwrap()])); // cumsum
    }
}
