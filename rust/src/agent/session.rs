//! Resumable per-problem agent sessions (ADR-002).
//!
//! A [`ProblemSession`] is one (variant, problem, seed) task turned into a
//! state machine: every `step()` executes exactly one Generate–Compile–
//! Test–Profile attempt and returns its observable outcome. Driving a
//! session to exhaustion reproduces the classic fixed-budget loops
//! ([`controller::run_problem`] / [`crate::mantis::run_orchestrated`])
//! bit-for-bit; stopping earlier yields exactly the corresponding prefix
//! of that run, because each attempt consumes the session's RNG stream in
//! the same order regardless of when (or on which thread) the session is
//! resumed. That prefix property is what lets the online scheduler
//! (`scheduler::online`) realize SOL-headroom and no-progress savings
//! *during* execution while offline `replay()` provably agrees.
//!
//! Sessions own all mutable state (RNG, agent state, plan cache, attempt
//! log) and hold the shared environment by value ([`Env`] is `Copy`), so
//! they are `Send` and can be fanned across the `exec` thread pool.

use crate::dsl;
use crate::eval::EvalRequest;
use crate::sol::SolAnalysis;
use crate::util::rng::{stream, MeasureSeq, Pcg32, StreamPath};

use super::attempt::AttemptRecord;
use super::controller::{modifiers, run_attempt, AgentState, Env, Modifiers, VariantSpec};
use super::runlog::ProblemRun;

/// The scheduler-visible outcome of one `step()`: enough to drive stopping
/// rules and cost accounting without borrowing the session's attempt log.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepResult {
    /// Attempt ordinal just executed (0-based).
    pub attempt: u32,
    /// Measured time when the attempt passed correctness.
    pub time_ms: Option<f64>,
    /// LLM tokens the attempt consumed.
    pub tokens: u64,
}

/// Resumable flat-controller session (MI / in-prompt SOL): the 40-iteration
/// loop of `run_problem`, one attempt per `step()`.
pub struct FlatSession<'a> {
    env: Env<'a>,
    spec: VariantSpec,
    mods: Modifiers,
    pidx: usize,
    rng: Pcg32,
    state: AgentState,
    plans: dsl::PlanCache,
    attempts: Vec<AttemptRecord>,
    t_ref_ms: f64,
}

impl<'a> FlatSession<'a> {
    pub fn new(env: Env<'a>, spec: &VariantSpec, pidx: usize, seed: u64) -> Self {
        let rng =
            Pcg32::derive(seed, &[stream::FLAT_CONTROLLER, spec.stream_id(), pidx as u64]);
        let mods = modifiers(spec);
        // Measurement noise lives on its own derived streams, one per
        // measurement (ADR-003): the baseline takes stream 0, attempt
        // measurements continue the sequence. Replaying a serialized
        // request therefore cannot drift from the in-process order.
        let mut measure = MeasureSeq::new(StreamPath::new(
            seed,
            &[stream::MEASURE, stream::FLAT_CONTROLLER, spec.stream_id(), pidx as u64],
        ));
        // scalar fast path (ADR-005): no response struct, no key strings —
        // with an oracle override this still routes through the backend
        let t_ref_ms = env
            .evaluator()
            .value(&EvalRequest::measured_baseline(pidx, measure.next_stream()));
        let state = AgentState {
            best_time_ms: f64::INFINITY,
            t_ref_ms,
            best_cfg: None,
            gamed: None,
            consecutive_failures: 0,
            tokens: 0,
            measure,
            prune: crate::analyze::PruneGate::new(),
        };
        FlatSession {
            env,
            spec: *spec,
            mods,
            pidx,
            rng,
            state,
            // Per-problem plan cache: revisited candidate configurations
            // skip re-lowering/re-generation (ADR-001).
            plans: dsl::PlanCache::new(),
            attempts: Vec::with_capacity(spec.attempts as usize),
            t_ref_ms,
        }
    }

    /// Execute one attempt; `None` once the per-problem budget is spent.
    pub fn step(&mut self) -> Option<StepResult> {
        if self.attempts.len() >= self.spec.attempts as usize {
            return None;
        }
        let attempt_no = self.attempts.len() as u32;
        let steering: Option<&'a SolAnalysis> =
            if self.mods.steered { Some(&self.env.sols[self.pidx]) } else { None };
        let rec = run_attempt(
            &self.env,
            &self.spec,
            &self.mods,
            self.pidx,
            attempt_no,
            &mut self.state,
            steering,
            None,
            &mut self.plans,
            &mut self.rng,
        );
        let result =
            StepResult { attempt: attempt_no, time_ms: rec.outcome.time_ms(), tokens: rec.tokens };
        self.attempts.push(rec);
        Some(result)
    }

    pub fn attempts_done(&self) -> usize {
        self.attempts.len()
    }

    pub fn t_ref_ms(&self) -> f64 {
        self.t_ref_ms
    }

    pub fn finish(self) -> ProblemRun {
        ProblemRun {
            problem_idx: self.pidx,
            t_ref_ms: self.t_ref_ms,
            t_sol_ms: self.env.sols[self.pidx].t_sol_ms,
            t_sol_fp16_ms: self.env.sols[self.pidx].t_sol_fp16_ms,
            attempts: self.attempts,
        }
    }
}

/// Controller-agnostic resumable session: the unit the online scheduler
/// and the parallel engine operate on.
///
/// Orchestrated sessions own a per-session [`crate::mantis::CrossMemory`]
/// (fresh by default, matching `run_problem`'s semantics). The sequential
/// cross-problem memory chain of `experiments::runner::run_variant` is
/// inherently order-dependent and therefore not available through this
/// interface — see ADR-002 for the determinism boundary.
pub enum ProblemSession<'a> {
    Flat(FlatSession<'a>),
    Mantis(crate::mantis::MantisSession<'a>),
}

impl<'a> ProblemSession<'a> {
    pub fn new(env: Env<'a>, spec: &VariantSpec, pidx: usize, seed: u64) -> Self {
        use super::controller::ControllerKind;
        match spec.controller {
            ControllerKind::OrchestratedSol => {
                ProblemSession::Mantis(crate::mantis::MantisSession::new(
                    env,
                    spec,
                    pidx,
                    seed,
                    crate::mantis::MantisConfig::default(),
                    crate::mantis::CrossMemory::default(),
                ))
            }
            _ => ProblemSession::Flat(FlatSession::new(env, spec, pidx, seed)),
        }
    }

    /// Execute one attempt; `None` once the session's budget is exhausted.
    pub fn step(&mut self) -> Option<StepResult> {
        match self {
            ProblemSession::Flat(s) => s.step(),
            ProblemSession::Mantis(s) => s.step(),
        }
    }

    pub fn attempts_done(&self) -> usize {
        match self {
            ProblemSession::Flat(s) => s.attempts_done(),
            ProblemSession::Mantis(s) => s.attempts_done(),
        }
    }

    pub fn pidx(&self) -> usize {
        match self {
            ProblemSession::Flat(s) => s.pidx,
            ProblemSession::Mantis(s) => s.pidx(),
        }
    }

    /// Measured PyTorch baseline for this problem (ms).
    pub fn t_ref_ms(&self) -> f64 {
        match self {
            ProblemSession::Flat(s) => s.t_ref_ms(),
            ProblemSession::Mantis(s) => s.t_ref_ms(),
        }
    }

    /// FP16-augmented SOL bound (ms) — the online stopping ceiling.
    pub fn t_sol_fp16_ms(&self) -> f64 {
        let (env, pidx) = match self {
            ProblemSession::Flat(s) => (&s.env, s.pidx),
            ProblemSession::Mantis(s) => (s.env(), s.pidx()),
        };
        env.sols[pidx].t_sol_fp16_ms
    }

    pub fn finish(self) -> ProblemRun {
        match self {
            ProblemSession::Flat(s) => s.finish(),
            ProblemSession::Mantis(s) => s.finish().0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::{run_problem, ControllerKind};
    use crate::agent::ModelTier;
    use crate::kernelbench::suite;
    use crate::perfmodel::{CompiledCostModel, PerfModel};
    use crate::sol::{analyze, H100_SXM};

    struct Fixture {
        model: PerfModel,
        problems: Vec<crate::kernelbench::Problem>,
        sols: Vec<crate::sol::SolAnalysis>,
        compiled: CompiledCostModel,
    }

    impl Fixture {
        fn new() -> Self {
            let problems = suite();
            let sols = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
            let model = PerfModel::new(H100_SXM.clone());
            let compiled = CompiledCostModel::compile(&model, &problems);
            Fixture { model, problems, sols, compiled }
        }

        fn env(&self) -> Env<'_> {
            Env::new(&self.model, &self.problems, &self.sols, &self.compiled)
        }
    }

    #[test]
    fn session_determinism_stepping_equals_run_problem() {
        let fx = Fixture::new();
        let env = fx.env();
        for spec in [
            VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid),
            VariantSpec::new(ControllerKind::InPromptSol, false, ModelTier::Max),
            VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini),
        ] {
            let full = run_problem(&env, &spec, 2, 31);
            let mut s = ProblemSession::new(env, &spec, 2, 31);
            let mut steps = 0;
            while s.step().is_some() {
                steps += 1;
            }
            let stepped = s.finish();
            assert_eq!(steps, full.attempts.len(), "{}", spec.label());
            assert_eq!(stepped, full, "stepped session must equal the loop: {}", spec.label());
        }
    }

    #[test]
    fn session_truncation_is_a_prefix_of_the_full_run() {
        let fx = Fixture::new();
        let env = fx.env();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
        let full = run_problem(&env, &spec, 0, 9);
        for cut in [1usize, 7, 23] {
            let mut s = ProblemSession::new(env, &spec, 0, 9);
            for _ in 0..cut {
                assert!(s.step().is_some());
            }
            let run = s.finish();
            assert_eq!(run.attempts.len(), cut);
            assert_eq!(run.attempts[..], full.attempts[..cut], "cut={cut}");
            assert_eq!(run.t_ref_ms, full.t_ref_ms);
        }
    }

    #[test]
    fn step_result_mirrors_the_recorded_attempt() {
        let fx = Fixture::new();
        let env = fx.env();
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max);
        let mut s = ProblemSession::new(env, &spec, 1, 5);
        let mut results = Vec::new();
        while let Some(r) = s.step() {
            results.push(r);
        }
        let run = s.finish();
        assert_eq!(results.len(), run.attempts.len());
        for (r, a) in results.iter().zip(&run.attempts) {
            assert_eq!(r.attempt, a.attempt);
            assert_eq!(r.time_ms, a.outcome.time_ms());
            assert_eq!(r.tokens, a.tokens);
        }
    }

    #[test]
    fn budget_truncated_variant_shares_the_stream() {
        // spec.attempts is excluded from stream_id(): a 12-attempt variant
        // must produce exactly the first 12 attempts of the 40-attempt one
        let fx = Fixture::new();
        let env = fx.env();
        let full_spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid);
        let mut short_spec = full_spec;
        short_spec.attempts = 12;
        let full = run_problem(&env, &full_spec, 4, 77);
        let short = run_problem(&env, &short_spec, 4, 77);
        assert_eq!(short.attempts[..], full.attempts[..12]);
    }
}
