//! Attempt records: one Generate–Compile–Test–Profile cycle (paper §5.5).
//!
//! These are the unit the run log stores, the scheduler replays, and the
//! integrity pipeline labels.

use std::sync::Arc;

use crate::analyze::RuleId;
use crate::dsl::KernelPlan;
use crate::perfmodel::CandidateConfig;
use crate::util::json::Json;

/// How the candidate was produced (the integrity pipeline's ground truth;
/// detectors must *infer* these from runtime, profile, and code features).
#[derive(Debug, Clone, PartialEq)]
pub enum SolutionKind {
    /// µCUTLASS-generated kernel (DSL path).
    DslKernel,
    /// Hand-written CUDA/CUTLASS (raw path).
    RawCuda,
    /// Composition of PyTorch library calls, no custom kernel (§5.8).
    PyTorchOnly,
    /// Gaming: exploits a spec/correctness loophole (§4.4, §6.3).
    Gaming(GamingType),
}

/// Original-gaming subcategories (paper Figure 11, red shades).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GamingType {
    /// Linear/constant fit calibrated to the benchmark input shape.
    BenchmarkInputExploitation,
    /// Ignores input; returns a pre-computed/cached tensor.
    ConstantOutput,
    /// Omits a required pipeline stage (dropout, bias, clamp…).
    SkippedComputation,
    /// view/as_strided instead of a real data transpose.
    FakeTranspose,
    /// Computes a prefix/sub-sample, zero-fills the rest.
    IncompleteComputation,
}

impl GamingType {
    pub const ALL: [GamingType; 5] = [
        GamingType::BenchmarkInputExploitation,
        GamingType::ConstantOutput,
        GamingType::SkippedComputation,
        GamingType::FakeTranspose,
        GamingType::IncompleteComputation,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            GamingType::BenchmarkInputExploitation => "benchmark_input_exploitation",
            GamingType::ConstantOutput => "constant_output",
            GamingType::SkippedComputation => "skipped_computation",
            GamingType::FakeTranspose => "fake_transpose",
            GamingType::IncompleteComputation => "incomplete_computation",
        }
    }

    pub fn parse(s: &str) -> Option<GamingType> {
        GamingType::ALL.iter().copied().find(|g| g.name() == s)
    }
}

/// Minor-issue subcategories (paper Figure 11, green shades) — accepted by
/// the integrity pipeline since performance is unaffected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MinorIssueType {
    /// Subtle math/precision difference still within tolerance.
    MathApproximation,
    /// Caches weights keyed on shape/pointer rather than content.
    CachedParameter,
    /// Assumes contiguous layout (fails on strided views).
    ContiguityAssumption,
    /// Uses the default CUDA stream (latent race).
    DefaultStream,
}

impl MinorIssueType {
    pub const ALL: [MinorIssueType; 4] = [
        MinorIssueType::MathApproximation,
        MinorIssueType::CachedParameter,
        MinorIssueType::ContiguityAssumption,
        MinorIssueType::DefaultStream,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MinorIssueType::MathApproximation => "minor_math_approximation",
            MinorIssueType::CachedParameter => "cached_parameter",
            MinorIssueType::ContiguityAssumption => "contiguity_assumption",
            MinorIssueType::DefaultStream => "uses_default_stream",
        }
    }

    pub fn parse(s: &str) -> Option<MinorIssueType> {
        MinorIssueType::ALL.iter().copied().find(|m| m.name() == s)
    }
}

/// Outcome of one generate–compile–test–profile cycle.
#[derive(Debug, Clone, PartialEq)]
pub enum AttemptOutcome {
    /// µCUTLASS static validation rejected every repair try — no tool
    /// action was spent (the DSL's cost-saving path).
    DslRejected,
    /// nvcc/toolchain failure (raw path).
    CompileError,
    /// Crashed or timed out at runtime.
    RuntimeError,
    /// Ran but failed the correctness harness.
    Incorrect,
    /// Passed correctness; measured at `time_ms` by NCU.
    Correct { time_ms: f64 },
    /// The static analyzer proved the trial pointless before measurement
    /// (ADR-009): SOL-infeasible (A101) or duplicate config (A301). The
    /// candidate compiled but was never evaluated — no measurement exists.
    Pruned { rule: RuleId },
}

impl AttemptOutcome {
    pub fn time_ms(&self) -> Option<f64> {
        match self {
            AttemptOutcome::Correct { time_ms } => Some(*time_ms),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttemptOutcome::DslRejected => "dsl_rejected",
            AttemptOutcome::CompileError => "compile_error",
            AttemptOutcome::RuntimeError => "runtime_error",
            AttemptOutcome::Incorrect => "incorrect",
            AttemptOutcome::Correct { .. } => "correct",
            AttemptOutcome::Pruned { .. } => "pruned",
        }
    }

    /// The analyzer rule that pruned the attempt, if any.
    pub fn prune_rule(&self) -> Option<RuleId> {
        match self {
            AttemptOutcome::Pruned { rule } => Some(*rule),
            _ => None,
        }
    }

    /// Inverse of `name()` + the serialized `time_ms` / `prune_rule`
    /// fields (`prune_rule` is absent in pre-ADR-009 logs, which also
    /// never contain `"pruned"` outcomes).
    pub fn parse(
        name: &str,
        time_ms: Option<f64>,
        prune_rule: Option<RuleId>,
    ) -> Option<AttemptOutcome> {
        match name {
            "dsl_rejected" => Some(AttemptOutcome::DslRejected),
            "compile_error" => Some(AttemptOutcome::CompileError),
            "runtime_error" => Some(AttemptOutcome::RuntimeError),
            "incorrect" => Some(AttemptOutcome::Incorrect),
            "correct" => time_ms.map(|time_ms| AttemptOutcome::Correct { time_ms }),
            "pruned" => prune_rule.map(|rule| AttemptOutcome::Pruned { rule }),
            _ => None,
        }
    }
}

impl SolutionKind {
    pub fn name(&self) -> String {
        match self {
            SolutionKind::DslKernel => "dsl".to_string(),
            SolutionKind::RawCuda => "raw".to_string(),
            SolutionKind::PyTorchOnly => "pytorch_only".to_string(),
            SolutionKind::Gaming(g) => format!("gaming:{}", g.name()),
        }
    }

    pub fn parse(s: &str) -> Option<SolutionKind> {
        match s {
            "dsl" => Some(SolutionKind::DslKernel),
            "raw" => Some(SolutionKind::RawCuda),
            "pytorch_only" => Some(SolutionKind::PyTorchOnly),
            _ => s
                .strip_prefix("gaming:")
                .and_then(GamingType::parse)
                .map(SolutionKind::Gaming),
        }
    }
}

/// One attempt, as recorded in the run log. `PartialEq` compares every
/// field — the determinism tests assert the parallel engine and the online
/// scheduler reproduce serial logs exactly, not just summary statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptRecord {
    /// Index of the problem in the suite.
    pub problem_idx: usize,
    /// Attempt ordinal within the problem (0-based).
    pub attempt: u32,
    pub outcome: AttemptOutcome,
    pub kind: SolutionKind,
    /// Minor issue present (only meaningful for Correct attempts).
    pub minor_issue: Option<MinorIssueType>,
    /// True when a gaming exploit was carried over from an earlier attempt
    /// (paper: Inherited Gaming).
    pub inherited: bool,
    /// LLM tokens consumed by this attempt (generate + reasoning).
    pub tokens: u64,
    /// Compile/run/profile wall time (s) — the tool-action cost.
    pub tool_time_s: f64,
    /// The kernel-design descriptor, for correct genuine solutions.
    pub config: Option<CandidateConfig>,
    /// Kernel launch signatures from the NCU profile (PyTorch-only
    /// detector input).
    pub kernel_names: Vec<String>,
    /// µCUTLASS source, when the DSL path produced one (traceability).
    pub dsl_source: Option<String>,
    /// The compiled lowering artifact for DSL attempts (shared, from the
    /// controller's plan cache): downstream consumers — cost attribution,
    /// integrity's dtype-aware SOL ceiling, runtime variant mapping — read
    /// the same resolved numbers codegen emitted.
    pub dsl_plan: Option<Arc<KernelPlan>>,
}

impl AttemptRecord {
    /// Full-fidelity serialization: together with [`Self::from_json`] this
    /// round-trips every field (the shard/merge protocol's requirement —
    /// merged logs must be `PartialEq`-identical to single-process logs).
    /// The `dsl_plan` itself is not written: `config_hash` + `dsl_source`
    /// identify it, and `from_json` reconstructs it by recompiling the
    /// source (the compiler is deterministic; the hash is verified).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("problem_idx", self.problem_idx)
            .set("attempt", self.attempt as u64)
            .set("outcome", self.outcome.name())
            .set(
                "time_ms",
                self.outcome.time_ms().map(Json::Num).unwrap_or(Json::Null),
            )
            .set("kind", self.kind.name())
            .set(
                "minor_issue",
                self.minor_issue.map(|m| Json::Str(m.name().into())).unwrap_or(Json::Null),
            )
            .set("inherited", self.inherited)
            .set("tokens", self.tokens)
            .set("tool_time_s", self.tool_time_s)
            .set(
                "config",
                self.config.as_ref().map(|c| c.to_json()).unwrap_or(Json::Null),
            )
            .set(
                "kernel_names",
                Json::Arr(self.kernel_names.iter().map(|k| Json::Str(k.clone())).collect()),
            )
            .set(
                "dsl_source",
                self.dsl_source.as_ref().map(|s| Json::Str(s.clone())).unwrap_or(Json::Null),
            )
            .set(
                "config_hash",
                self.dsl_plan
                    .as_ref()
                    .map(|p| Json::Str(p.config_hash.clone()))
                    .unwrap_or(Json::Null),
            )
            .set(
                "prune_rule",
                self.outcome
                    .prune_rule()
                    .map(|r| Json::Str(r.code().into()))
                    .unwrap_or(Json::Null),
            );
        o
    }

    /// Inverse of [`Self::to_json`]. `plans` caches plan reconstruction
    /// across attempts (a revisited configuration costs one map lookup).
    pub fn from_json(
        j: &Json,
        plans: &mut crate::dsl::PlanCache,
    ) -> Result<AttemptRecord, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("attempt: missing {k}"));
        let time_ms = field("time_ms")?.as_f64();
        let outcome_name =
            field("outcome")?.as_str().ok_or("attempt: outcome not a string")?;
        // Absent in pre-ADR-009 logs (which also never say "pruned").
        let prune_rule =
            j.get("prune_rule").and_then(|v| v.as_str()).and_then(RuleId::parse_code);
        let outcome = AttemptOutcome::parse(outcome_name, time_ms, prune_rule)
            .ok_or_else(|| format!("attempt: bad outcome `{outcome_name}`"))?;
        let kind_name = field("kind")?.as_str().ok_or("attempt: kind not a string")?;
        let kind = SolutionKind::parse(kind_name)
            .ok_or_else(|| format!("attempt: bad kind `{kind_name}`"))?;
        let minor_issue = match field("minor_issue")? {
            Json::Null => None,
            m => Some(
                m.as_str()
                    .and_then(MinorIssueType::parse)
                    .ok_or_else(|| format!("attempt: bad minor_issue {m}"))?,
            ),
        };
        let config = match field("config")? {
            Json::Null => None,
            c => Some(
                CandidateConfig::from_json(c)
                    .ok_or_else(|| format!("attempt: bad config {c}"))?,
            ),
        };
        let dsl_source = match field("dsl_source")? {
            Json::Null => None,
            s => Some(s.as_str().ok_or("attempt: dsl_source not a string")?.to_string()),
        };
        let dsl_plan = match field("config_hash")? {
            Json::Null => None,
            h => {
                let hash = h.as_str().ok_or("attempt: config_hash not a string")?;
                let src = dsl_source
                    .as_deref()
                    .ok_or("attempt: config_hash without dsl_source")?;
                let compiled = crate::dsl::compile_cached(src, plans)
                    .map_err(|e| format!("attempt: recompiling dsl_source: {e}"))?;
                if compiled.plan.config_hash != hash {
                    return Err(format!(
                        "attempt: recompiled plan hash {} != recorded {hash}",
                        compiled.plan.config_hash
                    ));
                }
                Some(compiled.plan.clone())
            }
        };
        Ok(AttemptRecord {
            problem_idx: field("problem_idx")?
                .as_u64()
                .ok_or("attempt: bad problem_idx")? as usize,
            attempt: field("attempt")?.as_u64().ok_or("attempt: bad attempt")? as u32,
            outcome,
            kind,
            minor_issue,
            inherited: field("inherited")?.as_bool().ok_or("attempt: bad inherited")?,
            tokens: field("tokens")?.as_u64().ok_or("attempt: bad tokens")?,
            tool_time_s: field("tool_time_s")?.as_f64().ok_or("attempt: bad tool_time_s")?,
            config,
            kernel_names: field("kernel_names")?
                .as_arr()
                .ok_or("attempt: kernel_names not an array")?
                .iter()
                .map(|k| {
                    k.as_str()
                        .map(String::from)
                        .ok_or_else(|| "attempt: kernel name not a string".to_string())
                })
                .collect::<Result<Vec<_>, String>>()?,
            dsl_source,
            dsl_plan,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_time() {
        assert_eq!(AttemptOutcome::Correct { time_ms: 2.0 }.time_ms(), Some(2.0));
        assert_eq!(AttemptOutcome::Incorrect.time_ms(), None);
        // Pruned attempts were never measured: no time, ever.
        assert_eq!(AttemptOutcome::Pruned { rule: RuleId::SolInfeasible }.time_ms(), None);
    }

    #[test]
    fn pruned_record_roundtrips() {
        let mut r = rec(
            2,
            AttemptOutcome::Pruned { rule: RuleId::DuplicateConfig },
            SolutionKind::DslKernel,
        );
        r.tool_time_s = 1.0;
        let j = r.to_json();
        assert_eq!(j.get("outcome").unwrap().as_str(), Some("pruned"));
        assert_eq!(j.get("prune_rule").unwrap().as_str(), Some("A301"));
        assert!(matches!(j.get("time_ms").unwrap(), Json::Null));
        let mut plans = crate::dsl::PlanCache::new();
        let parsed =
            AttemptRecord::from_json(&Json::parse(&j.to_string()).unwrap(), &mut plans).unwrap();
        assert_eq!(parsed, r);
        // "pruned" without a rule code is malformed
        assert_eq!(AttemptOutcome::parse("pruned", None, None), None);
    }

    #[test]
    fn record_serializes() {
        let r = AttemptRecord {
            problem_idx: 3,
            attempt: 7,
            outcome: AttemptOutcome::Correct { time_ms: 1.5 },
            kind: SolutionKind::Gaming(GamingType::ConstantOutput),
            minor_issue: None,
            inherited: true,
            tokens: 9000,
            tool_time_s: 40.0,
            config: None,
            kernel_names: vec![],
            dsl_source: None,
            dsl_plan: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("gaming:constant_output"));
        assert_eq!(j.get("inherited").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn record_json_roundtrips_every_field() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
            .with_threadblockshape(m=128, n=64, k=64).with_stages(3) >> bias() >> relu()";
        let compiled = crate::dsl::compile(src).unwrap();
        let r = AttemptRecord {
            problem_idx: 5,
            attempt: 12,
            outcome: AttemptOutcome::Correct { time_ms: 0.123456789012345 },
            kind: SolutionKind::DslKernel,
            minor_issue: Some(MinorIssueType::ContiguityAssumption),
            inherited: false,
            tokens: 12345,
            tool_time_s: 87.65432109876,
            config: Some(CandidateConfig::library((128, 64, 64), crate::dsl::DType::Fp16)),
            kernel_names: vec!["ucutlass_kernel::gemm".into(), "helper".into()],
            dsl_source: Some(src.to_string()),
            dsl_plan: Some(compiled.plan.clone()),
        };
        let text = r.to_json().to_string();
        let mut plans = crate::dsl::PlanCache::new();
        let parsed = AttemptRecord::from_json(
            &crate::util::json::Json::parse(&text).unwrap(),
            &mut plans,
        )
        .unwrap();
        assert_eq!(parsed, r, "round-trip must be field-for-field identical");

        // non-plan record too
        let r2 = rec(3, AttemptOutcome::CompileError, SolutionKind::RawCuda);
        let parsed2 = AttemptRecord::from_json(
            &crate::util::json::Json::parse(&r2.to_json().to_string()).unwrap(),
            &mut plans,
        )
        .unwrap();
        assert_eq!(parsed2, r2);
    }

    fn rec(attempt: u32, outcome: AttemptOutcome, kind: SolutionKind) -> AttemptRecord {
        AttemptRecord {
            problem_idx: 0,
            attempt,
            outcome,
            kind,
            minor_issue: None,
            inherited: false,
            tokens: 1000,
            tool_time_s: 60.0,
            config: None,
            kernel_names: vec![],
            dsl_source: None,
            dsl_plan: None,
        }
    }
}
