//! Attempt records: one Generate–Compile–Test–Profile cycle (paper §5.5).
//!
//! These are the unit the run log stores, the scheduler replays, and the
//! integrity pipeline labels.

use std::sync::Arc;

use crate::dsl::KernelPlan;
use crate::perfmodel::CandidateConfig;
use crate::util::json::Json;

/// How the candidate was produced (the integrity pipeline's ground truth;
/// detectors must *infer* these from runtime, profile, and code features).
#[derive(Debug, Clone, PartialEq)]
pub enum SolutionKind {
    /// µCUTLASS-generated kernel (DSL path).
    DslKernel,
    /// Hand-written CUDA/CUTLASS (raw path).
    RawCuda,
    /// Composition of PyTorch library calls, no custom kernel (§5.8).
    PyTorchOnly,
    /// Gaming: exploits a spec/correctness loophole (§4.4, §6.3).
    Gaming(GamingType),
}

/// Original-gaming subcategories (paper Figure 11, red shades).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GamingType {
    /// Linear/constant fit calibrated to the benchmark input shape.
    BenchmarkInputExploitation,
    /// Ignores input; returns a pre-computed/cached tensor.
    ConstantOutput,
    /// Omits a required pipeline stage (dropout, bias, clamp…).
    SkippedComputation,
    /// view/as_strided instead of a real data transpose.
    FakeTranspose,
    /// Computes a prefix/sub-sample, zero-fills the rest.
    IncompleteComputation,
}

impl GamingType {
    pub const ALL: [GamingType; 5] = [
        GamingType::BenchmarkInputExploitation,
        GamingType::ConstantOutput,
        GamingType::SkippedComputation,
        GamingType::FakeTranspose,
        GamingType::IncompleteComputation,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            GamingType::BenchmarkInputExploitation => "benchmark_input_exploitation",
            GamingType::ConstantOutput => "constant_output",
            GamingType::SkippedComputation => "skipped_computation",
            GamingType::FakeTranspose => "fake_transpose",
            GamingType::IncompleteComputation => "incomplete_computation",
        }
    }
}

/// Minor-issue subcategories (paper Figure 11, green shades) — accepted by
/// the integrity pipeline since performance is unaffected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MinorIssueType {
    /// Subtle math/precision difference still within tolerance.
    MathApproximation,
    /// Caches weights keyed on shape/pointer rather than content.
    CachedParameter,
    /// Assumes contiguous layout (fails on strided views).
    ContiguityAssumption,
    /// Uses the default CUDA stream (latent race).
    DefaultStream,
}

impl MinorIssueType {
    pub const ALL: [MinorIssueType; 4] = [
        MinorIssueType::MathApproximation,
        MinorIssueType::CachedParameter,
        MinorIssueType::ContiguityAssumption,
        MinorIssueType::DefaultStream,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MinorIssueType::MathApproximation => "minor_math_approximation",
            MinorIssueType::CachedParameter => "cached_parameter",
            MinorIssueType::ContiguityAssumption => "contiguity_assumption",
            MinorIssueType::DefaultStream => "uses_default_stream",
        }
    }
}

/// Outcome of one generate–compile–test–profile cycle.
#[derive(Debug, Clone, PartialEq)]
pub enum AttemptOutcome {
    /// µCUTLASS static validation rejected every repair try — no tool
    /// action was spent (the DSL's cost-saving path).
    DslRejected,
    /// nvcc/toolchain failure (raw path).
    CompileError,
    /// Crashed or timed out at runtime.
    RuntimeError,
    /// Ran but failed the correctness harness.
    Incorrect,
    /// Passed correctness; measured at `time_ms` by NCU.
    Correct { time_ms: f64 },
}

impl AttemptOutcome {
    pub fn time_ms(&self) -> Option<f64> {
        match self {
            AttemptOutcome::Correct { time_ms } => Some(*time_ms),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttemptOutcome::DslRejected => "dsl_rejected",
            AttemptOutcome::CompileError => "compile_error",
            AttemptOutcome::RuntimeError => "runtime_error",
            AttemptOutcome::Incorrect => "incorrect",
            AttemptOutcome::Correct { .. } => "correct",
        }
    }
}

/// One attempt, as recorded in the run log. `PartialEq` compares every
/// field — the determinism tests assert the parallel engine and the online
/// scheduler reproduce serial logs exactly, not just summary statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptRecord {
    /// Index of the problem in the suite.
    pub problem_idx: usize,
    /// Attempt ordinal within the problem (0-based).
    pub attempt: u32,
    pub outcome: AttemptOutcome,
    pub kind: SolutionKind,
    /// Minor issue present (only meaningful for Correct attempts).
    pub minor_issue: Option<MinorIssueType>,
    /// True when a gaming exploit was carried over from an earlier attempt
    /// (paper: Inherited Gaming).
    pub inherited: bool,
    /// LLM tokens consumed by this attempt (generate + reasoning).
    pub tokens: u64,
    /// Compile/run/profile wall time (s) — the tool-action cost.
    pub tool_time_s: f64,
    /// The kernel-design descriptor, for correct genuine solutions.
    pub config: Option<CandidateConfig>,
    /// Kernel launch signatures from the NCU profile (PyTorch-only
    /// detector input).
    pub kernel_names: Vec<String>,
    /// µCUTLASS source, when the DSL path produced one (traceability).
    pub dsl_source: Option<String>,
    /// The compiled lowering artifact for DSL attempts (shared, from the
    /// controller's plan cache): downstream consumers — cost attribution,
    /// integrity's dtype-aware SOL ceiling, runtime variant mapping — read
    /// the same resolved numbers codegen emitted.
    pub dsl_plan: Option<Arc<KernelPlan>>,
}

impl AttemptRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("problem_idx", self.problem_idx)
            .set("attempt", self.attempt as u64)
            .set("outcome", self.outcome.name())
            .set(
                "time_ms",
                self.outcome.time_ms().map(Json::Num).unwrap_or(Json::Null),
            )
            .set(
                "kind",
                match &self.kind {
                    SolutionKind::DslKernel => "dsl".to_string(),
                    SolutionKind::RawCuda => "raw".to_string(),
                    SolutionKind::PyTorchOnly => "pytorch_only".to_string(),
                    SolutionKind::Gaming(g) => format!("gaming:{}", g.name()),
                },
            )
            .set(
                "minor_issue",
                self.minor_issue.map(|m| Json::Str(m.name().into())).unwrap_or(Json::Null),
            )
            .set("inherited", self.inherited)
            .set("tokens", self.tokens)
            .set("tool_time_s", self.tool_time_s)
            .set(
                "config_hash",
                self.dsl_plan
                    .as_ref()
                    .map(|p| Json::Str(p.config_hash.clone()))
                    .unwrap_or(Json::Null),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_time() {
        assert_eq!(AttemptOutcome::Correct { time_ms: 2.0 }.time_ms(), Some(2.0));
        assert_eq!(AttemptOutcome::Incorrect.time_ms(), None);
    }

    #[test]
    fn record_serializes() {
        let r = AttemptRecord {
            problem_idx: 3,
            attempt: 7,
            outcome: AttemptOutcome::Correct { time_ms: 1.5 },
            kind: SolutionKind::Gaming(GamingType::ConstantOutput),
            minor_issue: None,
            inherited: true,
            tokens: 9000,
            tool_time_s: 40.0,
            config: None,
            kernel_names: vec![],
            dsl_source: None,
            dsl_plan: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("gaming:constant_output"));
        assert_eq!(j.get("inherited").unwrap().as_bool(), Some(true));
    }
}
