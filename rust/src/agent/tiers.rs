//! Model-tier behaviour parameters (paper §5.2 "Models").
//!
//! The three tiers mirror GPT-5-mini / GPT-5 / GPT-5.2 and are calibrated
//! against the paper's own per-tier statistics: MI solve rates (52/57/59 of
//! 59), raw-CUDA quality (0.40× / 0.86× / 2.04× geomean), gaming and
//! PyTorch-only counts (Figures 10–11), and token pricing ($0.25 / $1.25 /
//! $1.75 per M input tokens). The parameters are behaviour *distributions*;
//! the system under test (DSL validation, SOL steering, scheduling,
//! integrity) acts on samples from them.

/// Capability tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelTier {
    /// GPT-5-mini analogue: cheap, weak raw-CUDA, benefits most from tooling.
    Mini,
    /// GPT-5 analogue: mid tier.
    Mid,
    /// GPT-5.2 analogue: strongest; can self-direct once given the DSL.
    Max,
}

impl ModelTier {
    pub const ALL: [ModelTier; 3] = [ModelTier::Mini, ModelTier::Mid, ModelTier::Max];

    pub fn name(&self) -> &'static str {
        match self {
            ModelTier::Mini => "gpt-5-mini",
            ModelTier::Mid => "gpt-5",
            ModelTier::Max => "gpt-5.2",
        }
    }

    /// Stable serialization token (shared with the CLI's `--tier`).
    pub fn token(&self) -> &'static str {
        match self {
            ModelTier::Mini => "mini",
            ModelTier::Mid => "mid",
            ModelTier::Max => "max",
        }
    }

    pub fn parse_token(s: &str) -> Option<ModelTier> {
        match s {
            "mini" => Some(ModelTier::Mini),
            "mid" => Some(ModelTier::Mid),
            "max" => Some(ModelTier::Max),
            _ => None,
        }
    }

    pub fn params(&self) -> &'static TierParams {
        match self {
            ModelTier::Mini => &MINI,
            ModelTier::Mid => &MID,
            ModelTier::Max => &MAX,
        }
    }
}

/// Behaviour distribution parameters for one tier.
#[derive(Debug, Clone)]
pub struct TierParams {
    pub name: &'static str,
    /// $ per million input tokens (paper §5.2).
    pub price_per_mtok: f64,

    // ---- raw CUDA/CUTLASS path -----------------------------------------
    /// P(candidate compiles) when emitting raw CUDA.
    pub raw_compile_rate: f64,
    /// P(passes correctness | compiles).
    pub raw_correct_rate: f64,
    /// Median implementation quality of a correct raw kernel, in (0, 1]
    /// (1.0 = library-grade). Sampled lognormally around this.
    pub raw_quality_median: f64,
    /// Lognormal sigma of raw quality.
    pub raw_quality_sigma: f64,
    /// P(a correct raw kernel exploits FP16/BF16 tensor cores).
    pub raw_fp16_rate: f64,
    /// P(a correct raw kernel fully fuses the op graph).
    pub raw_fuse_rate: f64,

    // ---- µCUTLASS path ---------------------------------------------------
    /// P(one DSL generation has a validity bug). Static validation catches
    /// it at near-zero cost and the model repairs from the error hint.
    pub dsl_invalid_rate: f64,
    /// P(the generated kernel is integrated correctly | valid DSL).
    pub dsl_integrate_rate: f64,

    // ---- optimization search ----------------------------------------------
    /// Probability the un-steered model picks a high-impact move (vs a
    /// random plausible one).
    pub move_quality: f64,
    /// Relative propensity to try reduced-precision math.
    pub fp16_move_bias: f64,
    /// Noise sigma on the model's own speedup estimates (drives Triage).
    pub estimate_sigma: f64,

    // ---- failure modes ---------------------------------------------------------
    /// Base per-attempt probability of discovering a gaming exploit.
    pub gaming_rate: f64,
    /// Per-attempt probability of falling back to PyTorch library
    /// composition after repeated custom-kernel failures.
    pub pytorch_fallback_rate: f64,
    /// P(a correct genuine kernel carries a minor issue).
    pub minor_issue_rate: f64,

    // ---- cost -------------------------------------------------------------------
    /// Mean LLM tokens per attempt.
    pub tokens_mean: f64,
    /// Lognormal sigma of tokens per attempt.
    pub tokens_sigma: f64,
}

/// GPT-5-mini analogue.
pub static MINI: TierParams = TierParams {
    name: "gpt-5-mini",
    price_per_mtok: 0.25,
    raw_compile_rate: 0.80,
    raw_correct_rate: 0.40,
    raw_quality_median: 0.22,
    raw_quality_sigma: 0.55,
    raw_fp16_rate: 0.04,
    raw_fuse_rate: 0.35,
    dsl_invalid_rate: 0.35,
    dsl_integrate_rate: 0.80,
    move_quality: 0.30,
    fp16_move_bias: 0.4,
    estimate_sigma: 0.8,
    gaming_rate: 0.010,
    pytorch_fallback_rate: 0.12,
    minor_issue_rate: 0.25,
    tokens_mean: 26_000.0,
    tokens_sigma: 0.35,
};

/// GPT-5 analogue.
pub static MID: TierParams = TierParams {
    name: "gpt-5",
    price_per_mtok: 1.25,
    raw_compile_rate: 0.90,
    raw_correct_rate: 0.55,
    raw_quality_median: 0.38,
    raw_quality_sigma: 0.45,
    raw_fp16_rate: 0.12,
    raw_fuse_rate: 0.55,
    dsl_invalid_rate: 0.18,
    dsl_integrate_rate: 0.92,
    move_quality: 0.50,
    fp16_move_bias: 0.8,
    estimate_sigma: 0.5,
    gaming_rate: 0.015,
    pytorch_fallback_rate: 0.07,
    minor_issue_rate: 0.20,
    tokens_mean: 34_000.0,
    tokens_sigma: 0.35,
};

/// GPT-5.2 analogue.
pub static MAX: TierParams = TierParams {
    name: "gpt-5.2",
    price_per_mtok: 1.75,
    raw_compile_rate: 0.96,
    raw_correct_rate: 0.75,
    raw_quality_median: 0.70,
    raw_quality_sigma: 0.35,
    raw_fp16_rate: 0.55,
    raw_fuse_rate: 0.85,
    dsl_invalid_rate: 0.08,
    dsl_integrate_rate: 0.97,
    move_quality: 0.75,
    fp16_move_bias: 1.2,
    estimate_sigma: 0.25,
    // the paper: "more capable models exhibit higher gaming rates"
    gaming_rate: 0.045,
    pytorch_fallback_rate: 0.04,
    minor_issue_rate: 0.15,
    tokens_mean: 42_000.0,
    tokens_sigma: 0.35,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_are_monotone_in_capability() {
        let (a, b, c) = (&MINI, &MID, &MAX);
        assert!(a.raw_quality_median < b.raw_quality_median);
        assert!(b.raw_quality_median < c.raw_quality_median);
        assert!(a.move_quality < b.move_quality && b.move_quality < c.move_quality);
        assert!(a.dsl_invalid_rate > b.dsl_invalid_rate);
        assert!(b.dsl_invalid_rate > c.dsl_invalid_rate);
        // and the paper's counter-intuitive one: stronger models game more
        assert!(c.gaming_rate > a.gaming_rate);
    }

    #[test]
    fn pricing_matches_paper() {
        assert_eq!(MINI.price_per_mtok, 0.25);
        assert_eq!(MID.price_per_mtok, 1.25);
        assert_eq!(MAX.price_per_mtok, 1.75);
        // "GPT-5 and GPT-5.2 approximately 5× and 7× more expensive"
        assert!((MID.price_per_mtok / MINI.price_per_mtok - 5.0).abs() < 1e-9);
        assert!((MAX.price_per_mtok / MINI.price_per_mtok - 7.0).abs() < 1e-9);
    }

    #[test]
    fn tier_lookup() {
        assert_eq!(ModelTier::Mini.params().name, "gpt-5-mini");
        assert_eq!(ModelTier::Max.name(), "gpt-5.2");
    }
}
