//! MI and in-prompt-SOL controllers: the flat Generate–Compile–Test–Profile
//! loop (paper §5.5). The orchestrated MANTIS controller lives in
//! [`crate::mantis`] and shares this module's attempt engine.
//!
//! DSL attempts compile through a per-problem [`dsl::PlanCache`]: repeated
//! candidate configurations (common when the search revisits a tile/dtype
//! point) skip re-lowering and re-generation, and the accepted attempt's
//! [`dsl::KernelPlan`] is threaded into the attempt record so the cost
//! model, SOL gap attribution and the integrity review all read the same
//! resolved numbers codegen emitted.

use crate::analyze::PruneGate;
use crate::dsl;
use crate::eval::{AnalyticEvaluator, DynEvaluator, EvalRequest, Oracle};
use crate::kernelbench::Problem;
use crate::perfmodel::{CandidateConfig, CompiledCostModel, ConfigBatch, PerfModel};
use crate::sol::SolAnalysis;
use crate::util::json::Json;
use crate::util::rng::{MeasureSeq, Pcg32};

use super::attempt::{AttemptOutcome, AttemptRecord, GamingType, MinorIssueType, SolutionKind};
use super::policy::{self, dsl_applicable, generate_valid_dsl, select_move, TILES};
use super::runlog::ProblemRun;
use super::tiers::{ModelTier, TierParams};

/// Which controller drives the loop (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControllerKind {
    /// Flat Measure–Implement loop.
    Mi,
    /// Flat loop whose prompt carries the SOL report (in-prompt steering).
    InPromptSol,
    /// Multi-phase orchestrated MANTIS (5 iters × 2 hypotheses × 4 attempts).
    OrchestratedSol,
}

impl ControllerKind {
    pub fn name(&self) -> &'static str {
        match self {
            ControllerKind::Mi => "MI",
            ControllerKind::InPromptSol => "in-prompt SOL",
            ControllerKind::OrchestratedSol => "orchestrated SOL",
        }
    }

    /// Stable serialization token (the display `name()` has spaces and is
    /// subject to wording changes).
    pub fn token(&self) -> &'static str {
        match self {
            ControllerKind::Mi => "mi",
            ControllerKind::InPromptSol => "in_prompt_sol",
            ControllerKind::OrchestratedSol => "orchestrated_sol",
        }
    }

    pub fn parse_token(s: &str) -> Option<ControllerKind> {
        match s {
            "mi" => Some(ControllerKind::Mi),
            "in_prompt_sol" => Some(ControllerKind::InPromptSol),
            "orchestrated_sol" => Some(ControllerKind::OrchestratedSol),
            _ => None,
        }
    }
}

/// A full experimental variant: controller × DSL × tier (paper Table 2).
#[derive(Debug, Clone, Copy)]
pub struct VariantSpec {
    pub controller: ControllerKind,
    pub dsl: bool,
    pub tier: ModelTier,
    /// Matched per-problem attempt budget (40 in the paper).
    pub attempts: u32,
    /// Prompt-level anti-gaming / anti-PyTorch-only guardrails (Table 4
    /// run 2).
    pub guardrails: bool,
    /// Online integrity feedback (the paper's §7 future-work item): the
    /// SOL-ceiling + LGD review runs inside the loop, so detected gaming is
    /// rejected immediately and the agent corrects instead of inheriting
    /// the exploit.
    pub online_integrity: bool,
    /// Static analyzer pruning (ADR-009): DSL candidates whose analytic
    /// lower bound provably cannot beat the session best are recorded as
    /// `Pruned` and never reach the evaluator. Deterministic and
    /// stream-aligned: a pruned run's RNG state matches its unpruned twin
    /// attempt-for-attempt, so accepted results are field-for-field
    /// identical (pinned by `tests/lint.rs`).
    pub prune: bool,
}

impl VariantSpec {
    pub fn new(controller: ControllerKind, dsl: bool, tier: ModelTier) -> Self {
        VariantSpec {
            controller,
            dsl,
            tier,
            attempts: 40,
            guardrails: false,
            online_integrity: false,
            prune: false,
        }
    }

    /// Enable online integrity feedback (§7 future work, `ext1`).
    pub fn with_online_integrity(mut self) -> Self {
        self.online_integrity = true;
        self
    }

    /// Enable static analyzer pruning (ADR-009).
    pub fn with_prune(mut self) -> Self {
        self.prune = true;
        self
    }

    /// Nominal per-problem attempt budget: flat controllers spend
    /// `attempts`; the orchestrated controller's budget is structural
    /// (Table 2: iterations × hypotheses × attempts) and ignores the
    /// `attempts` field. Savings accounting must use this, not `attempts`.
    pub fn total_budget(&self) -> u32 {
        match self.controller {
            ControllerKind::OrchestratedSol => {
                crate::mantis::ITERATIONS
                    * crate::mantis::HYPOTHESES_PER_ITER as u32
                    * crate::mantis::ATTEMPTS_PER_HYPOTHESIS
            }
            _ => self.attempts,
        }
    }

    /// Stable stream identifier for RNG derivation (`Pcg32::derive`).
    /// Encodes every behaviour-shaping field *except* the attempt budget:
    /// a budget-truncated variant draws the same stream as its full-budget
    /// twin, so a 20-attempt run is exactly the 20-attempt prefix of the
    /// 40-attempt run — the property the online scheduler's early stopping
    /// and the replay-agreement tests rely on.
    pub fn stream_id(&self) -> u64 {
        let c = match self.controller {
            ControllerKind::Mi => 0u64,
            ControllerKind::InPromptSol => 1,
            ControllerKind::OrchestratedSol => 2,
        };
        let t = match self.tier {
            ModelTier::Mini => 0u64,
            ModelTier::Mid => 1,
            ModelTier::Max => 2,
        };
        // `prune` is deliberately EXCLUDED: a pruned variant draws the
        // same stream as its unpruned twin, which is what makes the
        // accepted subsets field-for-field identical (ADR-009).
        (c << 8)
            | (t << 4)
            | ((self.dsl as u64) << 3)
            | ((self.guardrails as u64) << 2)
            | ((self.online_integrity as u64) << 1)
    }

    pub fn label(&self) -> String {
        let base = match (self.controller, self.dsl) {
            (ControllerKind::Mi, false) => "MI".to_string(),
            (ControllerKind::Mi, true) => "µCUTLASS + MI".to_string(),
            (c, false) => format!("{}", c.name()),
            (c, true) => format!("µCUTLASS + {}", c.name()),
        };
        let prune = if self.prune { " +prune" } else { "" };
        format!("{} [{}]{}", base, self.tier.name(), prune)
    }

    /// Serialize every behaviour-shaping field (the suite shard/merge
    /// protocol ships specs between processes).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("controller", self.controller.token())
            .set("tier", self.tier.token())
            .set("dsl", self.dsl)
            .set("attempts", self.attempts as u64)
            .set("guardrails", self.guardrails)
            .set("online_integrity", self.online_integrity)
            .set("prune", self.prune);
        o
    }

    pub fn from_json(j: &Json) -> Result<VariantSpec, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("spec: missing {k}"));
        let controller = field("controller")?
            .as_str()
            .and_then(ControllerKind::parse_token)
            .ok_or("spec: bad controller")?;
        let tier = field("tier")?
            .as_str()
            .and_then(ModelTier::parse_token)
            .ok_or("spec: bad tier")?;
        Ok(VariantSpec {
            controller,
            tier,
            dsl: field("dsl")?.as_bool().ok_or("spec: bad dsl")?,
            attempts: field("attempts")?.as_u64().ok_or("spec: bad attempts")? as u32,
            guardrails: field("guardrails")?.as_bool().ok_or("spec: bad guardrails")?,
            online_integrity: field("online_integrity")?
                .as_bool()
                .ok_or("spec: bad online_integrity")?,
            // absent in pre-ADR-009 logs: default off
            prune: j.get("prune").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

/// Shared evaluation environment. `Copy` (it is a handful of shared
/// references): resumable sessions hold it by value so they can be moved
/// freely across worker threads.
#[derive(Clone, Copy)]
pub struct Env<'a> {
    pub model: &'a PerfModel,
    pub problems: &'a [Problem],
    /// Per-problem SOL analyses (same order as `problems`).
    pub sols: &'a [SolAnalysis],
    /// Per-problem compiled cost models (same order as `problems`),
    /// lowered once by whoever owns the model/suite pair (ADR-006).
    pub compiled: &'a CompiledCostModel,
    /// Measurement-oracle override (record/replay, ADR-004): when set,
    /// every evaluation the agent loop makes routes through this backend
    /// instead of the analytic fast path. `Bench::env` threads it in from
    /// the bench's installed oracle.
    pub oracle: Option<&'a DynEvaluator>,
}

impl<'a> Env<'a> {
    pub fn new(
        model: &'a PerfModel,
        problems: &'a [Problem],
        sols: &'a [SolAnalysis],
        compiled: &'a CompiledCostModel,
    ) -> Env<'a> {
        Env { model, problems, sols, compiled, oracle: None }
    }

    /// Install (or clear) the measurement-oracle override.
    pub fn with_oracle(mut self, oracle: Option<&'a DynEvaluator>) -> Env<'a> {
        self.oracle = oracle;
        self
    }

    /// The measurement oracle over this environment (ADR-003/ADR-004).
    /// `Copy` over shared references — construct freely at call sites. All
    /// agent-loop measurement goes through this evaluator; nothing above
    /// the `eval` layer calls `PerfModel::candidate_ms` or `measure_ms`
    /// directly.
    pub fn evaluator(&self) -> Oracle<'a> {
        Oracle::with_backend(
            AnalyticEvaluator::new(self.model, self.problems, self.sols, self.compiled),
            self.oracle,
        )
    }
}

/// Mutable per-problem agent state threaded through attempts.
pub struct AgentState {
    /// Best *measured* time of any correct attempt so far (ms). Starts at
    /// the PyTorch-seed baseline (the bootstrap cuda_model.cu delegates to
    /// PyTorch).
    pub best_time_ms: f64,
    /// Measured PyTorch reference.
    pub t_ref_ms: f64,
    /// Best genuine (non-gamed) config, the mutation base.
    pub best_cfg: Option<CandidateConfig>,
    /// Active exploit once gaming was discovered (inherited thereafter).
    pub gamed: Option<(GamingType, f64)>,
    pub consecutive_failures: u32,
    /// Tokens spent on this problem so far.
    pub tokens: u64,
    /// Measurement-noise streams for this session: the k-th measurement
    /// draws from a derived stream named by (seed, session path, k), so a
    /// serialized `EvalRequest` replays the exact value out of process
    /// (ADR-003).
    pub measure: MeasureSeq,
    /// Analyzer pruning state (ADR-009): seen config hashes + the SOL
    /// margin. Only consulted when the variant's `prune` flag is on.
    pub prune: PruneGate,
}

/// Gaming runtime: what the exploit's kernel actually costs. The
/// write-only estimate is dtype-aware (out elements × the problem's
/// declared dtype width), matching the integrity pipeline's dtype-aware
/// SOL ceiling — a hardcoded 4 bytes/element would overestimate FP16
/// problems' exploit cost by 2×.
fn gaming_time_ms(
    model: &PerfModel,
    problem: &Problem,
    ty: GamingType,
    honest_best_ms: f64,
) -> f64 {
    let out_bytes =
        problem.ops.last().map(|o| o.out_elems()).unwrap_or(1) * problem.dtype.size();
    let write_only_ms = out_bytes as f64 / model.gpu.effective_bandwidth() * 1e3 + 0.003;
    match ty {
        GamingType::ConstantOutput | GamingType::BenchmarkInputExploitation => write_only_ms,
        GamingType::SkippedComputation => honest_best_ms * 0.55,
        GamingType::FakeTranspose => honest_best_ms * 0.80,
        GamingType::IncompleteComputation => honest_best_ms * 0.35,
    }
}

/// Sample a fresh raw-CUDA config (first genuine attempt on the raw path).
fn sample_raw_config(
    tier: &TierParams,
    mods: &Modifiers,
    problem: &Problem,
    rng: &mut Pcg32,
) -> CandidateConfig {
    let tile = *rng.choice(TILES);
    let quality = (mods.raw_quality(tier.raw_quality_median)
        * rng.lognormal_noise(tier.raw_quality_sigma))
    .clamp(0.03, 0.95);
    let fuse = mods.raw_fuse(tier.raw_fuse_rate);
    CandidateConfig {
        tile,
        compute_dtype: if rng.chance(mods.raw_fp16(tier.raw_fp16_rate)) {
            crate::dsl::DType::Fp16
        } else {
            crate::dsl::DType::Fp32
        },
        tensor_cores: problem.is_matmul_like() && rng.chance(0.8),
        fused_epilogue: rng.chance(fuse),
        fusion_coverage: if rng.chance(fuse) { 1.0 } else { 0.3 },
        scheduler: Default::default(),
        stages: 2,
        quality,
    }
}

/// Default first DSL config: the grammar's SM90+ template.
fn default_dsl_config(tier: &TierParams, rng: &mut Pcg32) -> CandidateConfig {
    let mut cfg = CandidateConfig::library((128, 128, 64), crate::dsl::DType::Fp32);
    if rng.chance(0.25 * tier.fp16_move_bias) {
        cfg.compute_dtype = crate::dsl::DType::Fp16;
    }
    cfg.quality = 0.97; // CUTLASS-backed codegen is library-grade
    cfg
}

/// Per-variant behaviour modifiers derived from the paper's findings.
pub struct Modifiers {
    pub gaming_mult: f64,
    pub fallback_mult: f64,
    pub tokens_mult: f64,
    pub steered: bool,
    /// Strength of SOL steering's effect on *what gets implemented*:
    /// 0 = none, 0.6 = in-prompt, 1.0 = orchestrated. SOL analysis tells
    /// the agent which precision/fusion/structure to target, which lifts
    /// raw-code quality and implementation success (paper §6.1: SOL alone
    /// improves GPT-5 MI from 0.86× to ~1.7×).
    pub steer_strength: f64,
}

impl Modifiers {
    /// Raw-quality median after steering (diminishing toward 0.9).
    pub fn raw_quality(&self, base: f64) -> f64 {
        base + (0.90 - base) * 0.30 * self.steer_strength
    }

    /// FP16 adoption rate after steering (SOL's FP16 augmentation makes
    /// the reduced-precision headroom explicit).
    pub fn raw_fp16(&self, base: f64) -> f64 {
        (base * (1.0 + 2.5 * self.steer_strength)).min(0.9)
    }

    /// Fusion adoption after steering.
    pub fn raw_fuse(&self, base: f64) -> f64 {
        (base * (1.0 + 0.8 * self.steer_strength)).min(0.95)
    }

    /// Correctness rates improve under structured implement phases.
    pub fn success_rate(&self, base: f64) -> f64 {
        1.0 - (1.0 - base) * (1.0 - 0.45 * self.steer_strength)
    }
}

pub fn modifiers(spec: &VariantSpec) -> Modifiers {
    let mut m = Modifiers {
        gaming_mult: 1.0,
        fallback_mult: 1.0,
        tokens_mult: 1.0,
        steered: false,
        steer_strength: 0.0,
    };
    if spec.dsl {
        // fake-transpose exploits open up; weak models also fall back to
        // torch more when the DSL doesn't cover the problem (§6.3)
        m.gaming_mult *= 1.6;
        m.fallback_mult *= match spec.tier {
            ModelTier::Mini => 2.6,
            ModelTier::Mid => 1.6,
            ModelTier::Max => 1.2,
        };
    }
    match spec.controller {
        ControllerKind::Mi => {}
        ControllerKind::InPromptSol => {
            m.steered = true;
            m.steer_strength = 0.6;
            m.gaming_mult *= 0.35; // structured steering discourages shortcuts
            m.tokens_mult *= 1.25; // SOL report + reasoning in prompt
        }
        ControllerKind::OrchestratedSol => {
            m.steered = true;
            m.steer_strength = 1.0;
            m.gaming_mult *= 0.20;
            m.tokens_mult *= 1.55; // per-phase artifacts
        }
    }
    if spec.guardrails {
        // Table 4: anti-PyTorch-only instruction works, anti-gaming doesn't
        m.fallback_mult *= 0.15;
    }
    m
}

/// Quality recovered per ImproveCode rewrite, by tier.
pub fn quality_gain(tier: ModelTier) -> f64 {
    match tier {
        ModelTier::Mini => 0.05,
        ModelTier::Mid => 0.10,
        ModelTier::Max => 0.18,
    }
}

/// Online integrity review (ext1): SOL-ceiling fires deterministically on
/// physically-implausible runtimes; otherwise the LGD catches the exploit
/// with its calibrated detection rate.
fn online_review_catches(
    env: &Env,
    _spec: &VariantSpec,
    pidx: usize,
    time_ms: f64,
    rng: &mut Pcg32,
) -> bool {
    if time_ms < 0.9 * env.sols[pidx].t_sol_fp16_ms {
        return true; // strict runtime bounds check
    }
    rng.chance(0.93) // LGD with the SOL report as specification augmentation
}

/// Execute ONE generate–compile–test–profile attempt and update state.
/// This is the shared engine used by MI, in-prompt, and MANTIS Implement.
/// `plans` is the per-problem plan cache: repeated candidate
/// configurations skip re-lowering/re-generation.
#[allow(clippy::too_many_arguments)]
pub fn run_attempt(
    env: &Env,
    spec: &VariantSpec,
    mods: &Modifiers,
    pidx: usize,
    attempt_no: u32,
    state: &mut AgentState,
    steering: Option<&SolAnalysis>,
    forced_move: Option<policy::OptMove>,
    plans: &mut dsl::PlanCache,
    rng: &mut Pcg32,
) -> AttemptRecord {
    let tier = spec.tier.params();
    let problem = &env.problems[pidx];
    let ev = env.evaluator();
    let tokens =
        (tier.tokens_mean * mods.tokens_mult * rng.lognormal_noise(tier.tokens_sigma)) as u64;
    state.tokens += tokens;
    let mut rec = AttemptRecord {
        problem_idx: pidx,
        attempt: attempt_no,
        outcome: AttemptOutcome::Incorrect,
        kind: SolutionKind::RawCuda,
        minor_issue: None,
        inherited: false,
        tokens,
        tool_time_s: 90.0 * rng.lognormal_noise(0.2),
        config: None,
        kernel_names: vec![],
        dsl_source: None,
        dsl_plan: None,
    };

    // -- inherited gaming: once an exploit wins, later attempts keep it ----
    // (unless online integrity feedback already rejected the exploit)
    if let Some((ty, t)) = state.gamed {
        if spec.online_integrity && online_review_catches(env, spec, pidx, t, rng) {
            // the harness rejects the inherited exploit; the agent corrects
            state.gamed = None;
            if state.best_time_ms <= t {
                state.best_time_ms = f64::INFINITY; // rebuild best from honest attempts
                if let Some(cfg) = &state.best_cfg {
                    state.best_time_ms = ev.value(&EvalRequest::candidate(pidx, cfg.clone()));
                }
            }
            let _ = ty;
        } else if rng.chance(0.80) {
            let t_j = t * rng.lognormal_noise(0.01);
            rec.outcome = AttemptOutcome::Correct { time_ms: t_j };
            rec.kind = SolutionKind::Gaming(ty);
            rec.inherited = true;
            rec.kernel_names = vec!["fast_kernel_v2".into()];
            if t_j < state.best_time_ms {
                state.best_time_ms = t_j;
            }
            return rec;
        }
    }

    // -- original gaming discovery -----------------------------------------
    let p_gaming = tier.gaming_rate * mods.gaming_mult;
    if rng.chance(p_gaming) {
        // type distribution: constant-output needs strong reasoning (Max);
        // fake transpose is DSL-associated (§6.3)
        let weights: Vec<f64> = GamingType::ALL
            .iter()
            .map(|ty| match ty {
                GamingType::ConstantOutput => {
                    if spec.tier == ModelTier::Max { 3.0 } else { 0.2 }
                }
                GamingType::FakeTranspose => if spec.dsl { 1.5 } else { 0.05 },
                GamingType::BenchmarkInputExploitation => 0.6,
                GamingType::SkippedComputation => 1.0,
                GamingType::IncompleteComputation => 0.5,
            })
            .collect();
        let ty = GamingType::ALL[rng.weighted_choice(&weights)];
        let honest = state
            .best_cfg
            .as_ref()
            .map(|c| ev.value(&EvalRequest::candidate(pidx, c.clone())))
            .unwrap_or(state.t_ref_ms);
        let t = gaming_time_ms(env.model, problem, ty, honest) * rng.lognormal_noise(0.01);
        if spec.online_integrity && online_review_catches(env, spec, pidx, t, rng) {
            // rejected in the loop: the attempt fails correctness review and
            // no exploit is inherited (paper §7: agents correct during search)
            rec.outcome = AttemptOutcome::Incorrect;
            rec.kind = SolutionKind::Gaming(ty);
            state.consecutive_failures += 1;
            return rec;
        }
        rec.outcome = AttemptOutcome::Correct { time_ms: t };
        rec.kind = SolutionKind::Gaming(ty);
        rec.kernel_names = vec!["optimized_kernel".into()];
        state.gamed = Some((ty, t));
        if t < state.best_time_ms {
            state.best_time_ms = t;
        }
        return rec;
    }

    // -- PyTorch-only fallback ------------------------------------------------
    let p_fb = tier.pytorch_fallback_rate
        * mods.fallback_mult
        * (1.0 + 0.4 * state.consecutive_failures as f64);
    if rng.chance(p_fb.min(0.85)) {
        // library-composed implementations (addmm/sdpa fusion) modestly beat
        // eager but write no custom kernel
        let t = state.t_ref_ms * rng.range_f64(0.55, 0.95);
        rec.outcome = AttemptOutcome::Correct { time_ms: t };
        rec.kind = SolutionKind::PyTorchOnly;
        rec.kernel_names = vec![
            "void at::native::vectorized_elementwise_kernel<4, ...>".into(),
            "ampere_sgemm_128x64_tn [cublas]".into(),
        ];
        state.consecutive_failures = 0;
        if t < state.best_time_ms {
            state.best_time_ms = t;
        }
        return rec;
    }

    // -- genuine path -----------------------------------------------------------
    let use_dsl = spec.dsl && dsl_applicable(problem);
    let qgain = quality_gain(spec.tier);
    let proposed: CandidateConfig = match (&state.best_cfg, forced_move) {
        (Some(base), Some(mv)) => policy::apply_move(base, mv, qgain),
        (Some(base), None) => {
            match select_move(&ev, pidx, base, tier, steering, qgain, rng) {
                Some((mv, _est)) => policy::apply_move(base, mv, qgain),
                None => base.clone(),
            }
        }
        (None, _) => {
            if use_dsl {
                default_dsl_config(tier, rng)
            } else {
                sample_raw_config(tier, mods, problem, rng)
            }
        }
    };

    if use_dsl {
        let (src, tries) = generate_valid_dsl(problem, &proposed, tier, rng, 3);
        // repairs cost extra tokens but no tool action
        let repair_tokens = (tries as u64 - 1) * 2_000;
        rec.tokens += repair_tokens;
        state.tokens += repair_tokens;
        match src {
            None => {
                rec.outcome = AttemptOutcome::DslRejected;
                rec.kind = SolutionKind::DslKernel;
                rec.tool_time_s = 1.0; // static rejection: no compile/run/profile
                state.consecutive_failures += 1;
                return rec;
            }
            Some((src, ir)) => {
                rec.dsl_source = Some(src.clone());
                rec.kind = SolutionKind::DslKernel;
                if !rng.chance(mods.success_rate(tier.dsl_integrate_rate)) {
                    // kernel is fine, integration into cuda_model.cu is not
                    rec.outcome = if rng.chance(0.5) {
                        AttemptOutcome::RuntimeError
                    } else {
                        AttemptOutcome::Incorrect
                    };
                    state.consecutive_failures += 1;
                    return rec;
                }
                // Plan + codegen through the per-problem cache, reusing the
                // IR the repair loop already lowered and validated: a
                // revisited configuration costs one map lookup.
                let compiled = dsl::compile_lowered(&src, &ir, plans);
                // The measured config reads the plan's resolved tile/dtype/
                // scheduler/stages — the same numbers codegen emitted.
                // Integration-level facts the DSL cannot express (fusion
                // coverage into cuda_model.cu, residual code quality) stay
                // with the proposal.
                let mut measured = CandidateConfig::from_plan(&compiled.plan, true);
                measured.tensor_cores = proposed.tensor_cores;
                measured.fused_epilogue = proposed.fused_epilogue;
                measured.fusion_coverage = proposed.fusion_coverage;
                measured.quality = proposed.quality;
                // -- static analyzer pruning (ADR-009) ---------------------
                if spec.prune {
                    // Analytic lower bound straight from the compiled cost
                    // model (ADR-006) — bitwise the noise-free base of what
                    // the evaluator would measure, at zero evaluator calls.
                    let mut batch = ConfigBatch::with_capacity(1);
                    batch.push(&measured);
                    let mut est = [0.0f64];
                    env.compiled.problem(pidx).eval_into(&batch, &mut est);
                    // Soundness gates beyond the margin (see analyze::prune
                    // docs): pruning must leave the unpruned twin's StopRule,
                    // move-selection, and integrity-review state unchanged.
                    // `best_cfg` present rules out the "first correct attempt
                    // seeds best_cfg" branch below; best ≥ 0.9×SOL rules out
                    // a rule-best / session-best split from a filtered
                    // sub-SOL gaming time; est×margin above the twin's
                    // dtype-aware integrity ceiling guarantees (to the same
                    // 6σ as the margin itself) that the twin's review never
                    // takes the SolCeiling early return, whose skipped RNG
                    // draw would desync every later label in the run.
                    let sols = &env.sols[pidx];
                    let ceiling = if compiled.plan.primary().reduced_precision() {
                        0.9 * sols.t_sol_fp16_ms
                    } else {
                        0.9 * sols.t_sol_ms.max(sols.t_sol_fp16_ms)
                    };
                    let hash = &compiled.plan.config_hash;
                    let rule = if state.best_cfg.is_some()
                        && state.best_time_ms >= 0.9 * sols.t_sol_fp16_ms
                        && est[0] * crate::analyze::PRUNE_MARGIN >= ceiling
                    {
                        state.prune.check(est[0], state.best_time_ms, hash)
                    } else {
                        None
                    };
                    state.prune.record(hash);
                    if let Some(rule) = rule {
                        // Consume exactly the draws the measured path would
                        // have, keeping the RNG streams of the pruned and
                        // unpruned twins bit-for-bit aligned.
                        let _ = state.measure.next_stream();
                        rec.dsl_plan = Some(compiled.plan.clone());
                        rec.outcome = AttemptOutcome::Pruned { rule };
                        if rng.chance(tier.minor_issue_rate) {
                            rec.minor_issue = Some(*rng.choice(&MinorIssueType::ALL));
                        }
                        rec.config = Some(measured);
                        rec.tool_time_s = 1.0; // static verdict: no trial
                        state.consecutive_failures = 0;
                        return rec;
                    }
                }
                let t = ev.value(
                    &EvalRequest::measured(
                        pidx,
                        measured.clone(),
                        state.measure.next_stream(),
                    )
                    .with_hash(compiled.plan.config_hash.clone()),
                );
                rec.dsl_plan = Some(compiled.plan.clone());
                rec.outcome = AttemptOutcome::Correct { time_ms: t };
                rec.kernel_names = vec![format!("ucutlass_kernel::{}", problem.name)];
                if rng.chance(tier.minor_issue_rate) {
                    rec.minor_issue = Some(*rng.choice(&MinorIssueType::ALL));
                }
                rec.config = Some(measured.clone());
                state.consecutive_failures = 0;
                if t < state.best_time_ms {
                    state.best_time_ms = t;
                    state.best_cfg = Some(measured);
                } else if state.best_cfg.is_none() {
                    state.best_cfg = Some(measured);
                }
                return rec;
            }
        }
    }

    // raw CUDA path
    rec.kind = SolutionKind::RawCuda;
    if !rng.chance(tier.raw_compile_rate) {
        rec.outcome = AttemptOutcome::CompileError;
        rec.tool_time_s = 35.0 * rng.lognormal_noise(0.2);
        state.consecutive_failures += 1;
        return rec;
    }
    if !rng.chance(mods.success_rate(tier.raw_correct_rate)) {
        rec.outcome = if rng.chance(0.3) {
            AttemptOutcome::RuntimeError
        } else {
            AttemptOutcome::Incorrect
        };
        state.consecutive_failures += 1;
        return rec;
    }
    let t = ev.value(&EvalRequest::measured(
        pidx,
        proposed.clone(),
        state.measure.next_stream(),
    ));
    rec.outcome = AttemptOutcome::Correct { time_ms: t };
    rec.kernel_names = vec![format!("{}_custom_kernel", problem.name)];
    if rng.chance(tier.minor_issue_rate) {
        rec.minor_issue = Some(*rng.choice(&MinorIssueType::ALL));
    }
    rec.config = Some(proposed.clone());
    state.consecutive_failures = 0;
    if t < state.best_time_ms {
        state.best_time_ms = t;
        state.best_cfg = Some(proposed);
    } else if state.best_cfg.is_none() {
        state.best_cfg = Some(proposed);
    }
    rec
}

/// Run one problem to its full budget. Flat controllers (MI / in-prompt
/// SOL) drive a [`super::session::FlatSession`] to exhaustion; orchestrated
/// MANTIS is dispatched to [`crate::mantis::run_orchestrated`]. The online
/// scheduler uses the same sessions but may stop stepping early — a run
/// produced here is always the full-budget extension of any truncated
/// session run (ADR-002).
pub fn run_problem(env: &Env, spec: &VariantSpec, pidx: usize, seed: u64) -> ProblemRun {
    if spec.controller == ControllerKind::OrchestratedSol {
        return crate::mantis::run_orchestrated(env, spec, pidx, seed, None);
    }
    let mut session = super::session::FlatSession::new(*env, spec, pidx, seed);
    while session.step().is_some() {}
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelbench::suite;
    use crate::perfmodel::PerfModel;
    use crate::sol::{analyze, H100_SXM};

    fn env_fixture() -> (PerfModel, Vec<Problem>, Vec<SolAnalysis>, CompiledCostModel) {
        let model = PerfModel::new(H100_SXM.clone());
        let problems = suite();
        let sols: Vec<SolAnalysis> = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let compiled = CompiledCostModel::compile(&model, &problems);
        (model, problems, sols, compiled)
    }

    #[test]
    fn variant_spec_json_roundtrips() {
        let mut spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Max)
            .with_online_integrity();
        spec.guardrails = true;
        spec.attempts = 12;
        let parsed = VariantSpec::from_json(
            &crate::util::json::Json::parse(&spec.to_json().to_string()).unwrap(),
        )
        .unwrap();
        // VariantSpec is not PartialEq (Copy config struct); compare the
        // serialized identity and the derived stream id
        assert_eq!(parsed.to_json().to_string(), spec.to_json().to_string());
        assert_eq!(parsed.stream_id(), spec.stream_id());
        assert_eq!(parsed.label(), spec.label());
    }

    #[test]
    fn run_problem_respects_budget() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini);
        let run = run_problem(&env, &spec, 0, 42);
        assert_eq!(run.attempts.len(), 40);
        assert!(run.t_ref_ms > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid);
        let a = run_problem(&env, &spec, 3, 7);
        let b = run_problem(&env, &spec, 3, 7);
        assert_eq!(a.best_time_ms(), b.best_time_ms());
        assert_eq!(a.total_tokens(), b.total_tokens());
    }

    #[test]
    fn dsl_variant_produces_dsl_kernels_on_gemm() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid);
        let run = run_problem(&env, &spec, 0, 11); // L1-1 gemm
        assert!(run
            .attempts
            .iter()
            .any(|a| matches!(a.kind, SolutionKind::DslKernel)));
        // DSL sources that were accepted must really compile
        for a in &run.attempts {
            if let Some(src) = &a.dsl_source {
                crate::dsl::compile(src).unwrap();
            }
        }
    }

    #[test]
    fn dsl_attempts_carry_plans_consistent_with_configs() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid);
        let run = run_problem(&env, &spec, 0, 11); // L1-1 gemm
        let mut with_plan = 0;
        for a in &run.attempts {
            if let Some(plan) = &a.dsl_plan {
                with_plan += 1;
                // the measured config mirrors the plan's resolved facts —
                // cost model and codegen read the same numbers
                let cfg = a.config.as_ref().expect("correct DSL attempts carry a config");
                let k = plan.primary();
                assert_eq!(cfg.tile, (k.tile.m, k.tile.n, k.tile.k));
                assert_eq!(cfg.compute_dtype, k.dtype_input);
                assert_eq!(cfg.stages, k.stages);
                assert_eq!(plan.config_hash.len(), 16);
            }
        }
        assert!(with_plan > 0, "expected plan-carrying DSL attempts");
    }

    #[test]
    fn mini_dsl_beats_mini_raw_on_gemm() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let mut wins = 0;
        for seed in 0..10u64 {
            let raw = run_problem(
                &env,
                &VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
                0,
                seed,
            );
            let dsl = run_problem(
                &env,
                &VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini),
                0,
                seed + 1000,
            );
            if dsl.best_honest_time_ms().unwrap_or(f64::INFINITY)
                < raw.best_honest_time_ms().unwrap_or(f64::INFINITY)
            {
                wins += 1;
            }
        }
        assert!(wins >= 8, "DSL should dominate raw for mini on GEMM, won {wins}/10");
    }

    #[test]
    fn online_integrity_breaks_gaming_chains() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let base = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max);
        let online = base.with_online_integrity();
        let gaming = |spec: VariantSpec| -> (usize, usize) {
            let mut orig = 0;
            let mut inherited = 0;
            for seed in 0..15u64 {
                for a in run_problem(&env, &spec, 0, seed).attempts {
                    if matches!(a.kind, SolutionKind::Gaming(_))
                        && a.outcome.time_ms().is_some()
                    {
                        if a.inherited {
                            inherited += 1;
                        } else {
                            orig += 1;
                        }
                    }
                }
            }
            (orig, inherited)
        };
        let (o1, i1) = gaming(base);
        let (o2, i2) = gaming(online);
        assert!(o2 + i2 < (o1 + i1) / 4, "online review should collapse gaming: {o1}+{i1} -> {o2}+{i2}");
        assert!(i2 <= i1, "inheritance chains must not grow");
    }

    #[test]
    fn steering_reduces_gaming() {
        let (model, problems, sols, compiled) = env_fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let count_gaming = |spec: VariantSpec| -> usize {
            (0..12u64)
                .flat_map(|seed| run_problem(&env, &spec, 0, seed).attempts)
                .filter(|a| matches!(a.kind, SolutionKind::Gaming(_)))
                .count()
        };
        let mi = count_gaming(VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max));
        let sol = count_gaming(VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Max));
        assert!(sol < mi, "SOL steering should reduce gaming: {sol} vs {mi}");
    }
}
