//! Artifact manifest: the JSON index `python/compile/aot.py` writes next
//! to the HLO-text artifacts.

use std::collections::BTreeMap;
use std::path::Path;

use crate::errmsg;
use crate::util::errors::{Result, ResultExt};
use crate::util::json::Json;

/// Input spec for one artifact operand.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One problem's artifact family: a reference plus candidate variants.
#[derive(Debug, Clone)]
pub struct ManifestProblem {
    pub kb_id: String,
    pub inputs: Vec<InputSpec>,
    pub reference: String,
    pub rtol: f64,
    pub atol: f64,
    /// variant name → artifact path (relative to the artifact dir).
    pub variants: BTreeMap<String, String>,
}

impl ManifestProblem {
    #[doc(hidden)]
    pub fn empty_for_test() -> Self {
        ManifestProblem {
            kb_id: String::new(),
            inputs: vec![],
            reference: String::new(),
            rtol: 1e-4,
            atol: 1e-4,
            variants: BTreeMap::new(),
        }
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub problems: BTreeMap<String, ManifestProblem>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| errmsg!("manifest: {e}"))?;
        let version = doc.get("version").and_then(|v| v.as_u64()).unwrap_or(1);
        let mut problems = BTreeMap::new();
        let probs = doc
            .get("problems")
            .and_then(|p| p.as_obj())
            .ok_or_else(|| errmsg!("manifest: missing problems object"))?;
        for (name, entry) in probs {
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| errmsg!("{name}: missing inputs"))?
                .iter()
                .map(|spec| {
                    let shape = spec
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| errmsg!("{name}: input without shape"))?
                        .iter()
                        .map(|d| d.as_u64().unwrap_or(0) as usize)
                        .collect();
                    let dtype = spec
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("f32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut variants = BTreeMap::new();
            if let Some(vs) = entry.get("variants").and_then(|v| v.as_obj()) {
                for (vname, v) in vs {
                    let path = v
                        .get("path")
                        .and_then(|p| p.as_str())
                        .ok_or_else(|| errmsg!("{name}/{vname}: missing path"))?;
                    variants.insert(vname.clone(), path.to_string());
                }
            }
            problems.insert(
                name.clone(),
                ManifestProblem {
                    kb_id: entry
                        .get("kb_id")
                        .and_then(|k| k.as_str())
                        .unwrap_or("")
                        .to_string(),
                    inputs,
                    reference: entry
                        .get("reference")
                        .and_then(|r| r.as_str())
                        .ok_or_else(|| errmsg!("{name}: missing reference"))?
                        .to_string(),
                    rtol: entry.get("rtol").and_then(|v| v.as_f64()).unwrap_or(1e-4),
                    atol: entry.get("atol").and_then(|v| v.as_f64()).unwrap_or(1e-4),
                    variants,
                },
            );
        }
        Ok(Manifest { version, problems })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2,
      "problems": {
        "gemm_square": {
          "kb_id": "L1-1",
          "inputs": [{"shape": [256, 256], "dtype": "f32"},
                     {"shape": [256, 256], "dtype": "f32"}],
          "reference": "gemm_square__ref.hlo.txt",
          "rtol": 1e-4, "atol": 1e-4,
          "variants": {
            "t64x64x64_fp32": {"path": "gemm_square__t64x64x64_fp32.hlo.txt"}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 2);
        let p = &m.problems["gemm_square"];
        assert_eq!(p.kb_id, "L1-1");
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[0].shape, vec![256, 256]);
        assert_eq!(p.variants.len(), 1);
    }

    #[test]
    fn rejects_missing_reference() {
        let bad = r#"{"problems": {"x": {"inputs": []}}}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
