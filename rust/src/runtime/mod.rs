//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and numerically validates candidate kernels
//! against their pure-jnp reference — the request-path correctness check.
//!
//! Wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`, with HLO
//! *text* as the interchange format (serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1 — see gen_hlo.py).
//!
//! The executor is gated behind the `pjrt` cargo feature (the `xla` crate
//! is vendored, not on crates.io). Without the feature `Runtime::open`
//! returns an explanatory error and every caller — integration tests, the
//! examples, `repro validate` — skips gracefully. Variant *selection* is
//! pure logic over the [`KernelPlan`] and works in every build.

pub mod manifest;

pub use manifest::{Manifest, ManifestProblem};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::dsl::{DType, KernelPlan};
use crate::errmsg;
use crate::util::errors::{Result, ResultExt};
use crate::util::rng::{stream, Pcg32};

/// Result of validating one candidate variant against its reference.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub problem: String,
    pub variant: String,
    pub max_abs_err: f64,
    pub max_rel_err: f64,
    pub elems: usize,
    pub pass: bool,
}

/// The PJRT executor with a compiled-executable cache (one compile per
/// artifact per process — Python never runs here).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    /// Artifact directory (holds `manifest.json` and the HLO text files).
    pub dir: PathBuf,
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Self::with_manifest(dir, manifest)
    }

    #[cfg(feature = "pjrt")]
    fn with_manifest(dir: PathBuf, manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| errmsg!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    #[cfg(not(feature = "pjrt"))]
    fn with_manifest(_dir: PathBuf, _manifest: Manifest) -> Result<Self> {
        Err(errmsg!(
            "PJRT executor unavailable: built without the `pjrt` feature \
             (needs the vendored xla crate wired in as a path dependency — \
             see the [features] note in rust/Cargo.toml)"
        ))
    }

    /// Compile (or fetch from cache) the executable for an artifact path.
    #[cfg(feature = "pjrt")]
    fn executable(&mut self, rel_path: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(rel_path) {
            let full = self.dir.join(rel_path);
            let proto = xla::HloModuleProto::from_text_file(
                full.to_str().ok_or_else(|| errmsg!("non-utf8 path"))?,
            )
            .map_err(|e| errmsg!("parsing {rel_path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| errmsg!("compiling {rel_path}: {e:?}"))?;
            self.cache.insert(rel_path.to_string(), exe);
        }
        Ok(self.cache.get(rel_path).unwrap())
    }

    /// Number of compiled executables held in the cache.
    #[cfg(feature = "pjrt")]
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Non-pjrt stub: no executor, nothing cached.
    #[cfg(not(feature = "pjrt"))]
    pub fn cached(&self) -> usize {
        0
    }

    /// Deterministic standard-normal inputs for a problem (seeded).
    pub fn gen_inputs(prob: &ManifestProblem, seed: u64) -> Vec<(Vec<f32>, Vec<i64>)> {
        let mut rng = Pcg32::derive(seed, &[stream::RUNTIME_INPUTS]);
        prob.inputs
            .iter()
            .map(|spec| {
                let n: usize = spec.shape.iter().product::<usize>();
                let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let shape: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                (data, shape)
            })
            .collect()
    }

    /// Execute one artifact on the given inputs; returns the flattened f32
    /// output (all artifacts return a 1-tuple — lowered with
    /// return_tuple=True, unwrapped with to_tuple1).
    ///
    /// Crate-visible only (ADR-003): external callers evaluate through
    /// [`crate::eval::PjrtEvaluator`] / [`Self::validate_variant`], never
    /// the raw executor.
    #[cfg(feature = "pjrt")]
    pub(crate) fn execute(
        &mut self,
        rel_path: &str,
        inputs: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(shape).map_err(|e| errmsg!("reshape {shape:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(rel_path)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| errmsg!("executing {rel_path}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| errmsg!("fetch result: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| errmsg!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| errmsg!("to_vec: {e:?}"))
    }

    /// Non-pjrt stub: unreachable in practice (`open` already failed), but
    /// keeps the call sites compiling in every build.
    #[cfg(not(feature = "pjrt"))]
    pub(crate) fn execute(
        &mut self,
        rel_path: &str,
        _inputs: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<f32>> {
        Err(errmsg!("cannot execute {rel_path}: built without the `pjrt` feature"))
    }

    /// Validate a candidate variant against its problem's reference on
    /// identical seeded inputs.
    pub fn validate_variant(
        &mut self,
        problem: &str,
        variant: &str,
        seed: u64,
    ) -> Result<ValidationReport> {
        let prob = self
            .manifest
            .problems
            .get(problem)
            .ok_or_else(|| errmsg!("unknown problem {problem}"))?
            .clone();
        let vpath = prob
            .variants
            .get(variant)
            .ok_or_else(|| errmsg!("unknown variant {problem}/{variant}"))?
            .clone();
        let inputs = Self::gen_inputs(&prob, seed);
        let expected = self.execute(&prob.reference, &inputs)?;
        let got = self.execute(&vpath, &inputs)?;
        if expected.len() != got.len() {
            return Err(errmsg!(
                "output shape mismatch: ref {} vs candidate {}",
                expected.len(),
                got.len()
            ));
        }
        let mut max_abs = 0f64;
        let mut max_rel = 0f64;
        let mut pass = true;
        for (e, g) in expected.iter().zip(&got) {
            let abs = (*e as f64 - *g as f64).abs();
            let rel = abs / (e.abs() as f64).max(1e-30);
            max_abs = max_abs.max(abs);
            max_rel = max_rel.max(rel);
            if abs > prob.atol + prob.rtol * (e.abs() as f64) {
                pass = false;
            }
        }
        Ok(ValidationReport {
            problem: problem.to_string(),
            variant: variant.to_string(),
            max_abs_err: max_abs,
            max_rel_err: max_rel,
            elems: expected.len(),
            pass,
        })
    }

    /// Map a compiled plan onto the nearest AOT variant of an artifact
    /// problem (the runtime side of Figure 1's backend routing). Reads the
    /// resolved tile/dtype straight off the plan's primary kernel.
    pub fn select_variant(prob: &ManifestProblem, plan: &KernelPlan) -> Option<String> {
        let k = plan.primary();
        Self::select_variant_for(prob, (k.tile.m, k.tile.n, k.tile.k), k.dtype_input)
    }

    /// Lower-level selection for callers that only have a tile/dtype pair
    /// (e.g. raw-CUDA attempt configs without a plan).
    pub fn select_variant_for(
        prob: &ManifestProblem,
        tile: (u64, u64, u64),
        dtype: DType,
    ) -> Option<String> {
        let want_bf16 = matches!(dtype, DType::Bf16 | DType::Fp16);
        let mut best: Option<(f64, String)> = None;
        for name in prob.variants.keys() {
            let score = variant_distance(name, tile, want_bf16);
            if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
                best = Some((score, name.clone()));
            }
        }
        best.map(|(_, n)| n)
    }
}

/// Distance between a variant name (t64x64x32_fp32 / rows16 / bq32 / …) and
/// a requested tile/dtype.
fn variant_distance(name: &str, tile: (u64, u64, u64), want_bf16: bool) -> f64 {
    let mut score = 0.0;
    if let Some(rest) = name.strip_prefix('t') {
        // tile variant: t{m}x{n}x{k}[_dtype]
        let core = rest.split('_').next().unwrap_or("");
        let dims: Vec<u64> = core.split('x').filter_map(|d| d.parse().ok()).collect();
        if dims.len() == 3 {
            let lg = |a: u64, b: u64| ((a.max(1) as f64).ln() - (b.max(1) as f64).ln()).abs();
            score += lg(dims[0], tile.0) + lg(dims[1], tile.1) + lg(dims[2], tile.2);
        }
        let is_bf16 = name.ends_with("bf16");
        if is_bf16 != want_bf16 {
            score += 10.0;
        }
    } else if let Some(r) = name.strip_prefix("rows").and_then(|s| s.parse::<u64>().ok()) {
        score += ((r as f64).ln() - (tile.0.min(64) as f64).ln()).abs();
    } else if let Some(q) = name.strip_prefix("bq").and_then(|s| s.parse::<u64>().ok()) {
        score += ((q as f64).ln() - (tile.0.min(64) as f64).ln()).abs();
    }
    score
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;

    fn plan_for(tile: (u64, u64, u64), dtype: &str) -> std::sync::Arc<dsl::KernelPlan> {
        let src = format!(
            "gemm().with_dtype(input={dtype}, acc=fp32, output={dtype})\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
             .with_threadblockshape(m={}, n={}, k={})",
            tile.0, tile.1, tile.2
        );
        dsl::compile(&src).unwrap().plan
    }

    #[test]
    fn variant_distance_prefers_matching_tile_and_dtype() {
        let t = (64, 64, 64);
        assert!(variant_distance("t64x64x64_fp32", t, false)
            < variant_distance("t32x32x32_fp32", t, false));
        assert!(variant_distance("t64x64x64_fp32", t, false)
            < variant_distance("t64x64x64_bf16", t, false));
    }

    #[test]
    fn select_variant_picks_nearest_from_plan() {
        let mut prob = ManifestProblem::empty_for_test();
        for v in ["t32x32x32_fp32", "t64x64x32_fp32", "t64x64x64_fp32", "t64x64x64_bf16"] {
            prob.variants.insert(v.into(), format!("{v}.hlo.txt"));
        }
        let got = Runtime::select_variant(&prob, &plan_for((64, 64, 64), "fp16")).unwrap();
        assert_eq!(got, "t64x64x64_bf16");
        let got = Runtime::select_variant(&prob, &plan_for((128, 128, 32), "fp32")).unwrap();
        assert_eq!(got, "t64x64x32_fp32");
    }

    #[test]
    fn select_variant_for_raw_configs() {
        let mut prob = ManifestProblem::empty_for_test();
        for v in ["t32x32x32_fp32", "t64x64x64_fp32"] {
            prob.variants.insert(v.into(), format!("{v}.hlo.txt"));
        }
        let got = Runtime::select_variant_for(&prob, (64, 64, 64), DType::Fp32).unwrap();
        assert_eq!(got, "t64x64x64_fp32");
    }

    #[test]
    fn corrupted_inputs_fail_execution() {
        // wrong-shape execution must error out, not silently succeed.
        // Lives here (not in tests/) because `execute` is crate-visible:
        // external callers go through validate_variant / PjrtEvaluator.
        // Skips when artifacts/ is absent, like the integration tests.
        let Ok(mut rt) = Runtime::open("artifacts") else { return };
        let prob = rt.manifest.problems.get("gemm_square").cloned().unwrap();
        let mut inputs = Runtime::gen_inputs(&prob, 7);
        inputs.pop();
        let r = rt.execute(&prob.reference, &inputs);
        assert!(r.is_err(), "executing with a missing operand must fail");
    }
}
