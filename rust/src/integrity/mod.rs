//! SOL-guided integrity checking (paper §4.4, §5.8, §6.3).
//!
//! Three detectors, applied offline to every attempt:
//!
//! 1. **SOL-ceiling**: measured time > 10% *below* the FP16 SOL bound is
//!    physically implausible ⇒ suspicious.
//! 2. **LLM-based game detector (LGD)**: reviews candidate code together
//!    with the SOL report; labels *No Issues / Minor Issues / Gaming*
//!    (gaming split into *Original* vs *Inherited*). Simulated here as a
//!    stochastic classifier with a calibrated detection rate — the SOL
//!    report's structured work description is what makes the high rate
//!    plausible (§4.4).
//! 3. **PyTorch-only (static)**: every profiled kernel-launch signature
//!    matches a library pattern ⇒ no custom kernel was written.
//!
//! Attempts labeled *No/Minor Issues* are accepted; everything else is
//! excluded from reported speedups. When both LGD-gaming and PyTorch-only
//! fire, PyTorch-only wins so the categories stay mutually exclusive.

use crate::agent::{AttemptOutcome, AttemptRecord, ProblemRun, SolutionKind};
use crate::perfmodel::ncu::is_library_kernel;
use crate::util::rng::{stream, Pcg32};

/// Review outcome (the six bands of Figure 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReviewLabel {
    NoIssues,
    MinorIssues,
    SolCeiling,
    PyTorchOnly,
    OriginalGaming,
    InheritedGaming,
}

impl ReviewLabel {
    pub const ALL: [ReviewLabel; 6] = [
        ReviewLabel::NoIssues,
        ReviewLabel::MinorIssues,
        ReviewLabel::SolCeiling,
        ReviewLabel::PyTorchOnly,
        ReviewLabel::OriginalGaming,
        ReviewLabel::InheritedGaming,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ReviewLabel::NoIssues => "no_issues",
            ReviewLabel::MinorIssues => "minor_issues",
            ReviewLabel::SolCeiling => "sol_ceiling",
            ReviewLabel::PyTorchOnly => "pytorch_only",
            ReviewLabel::OriginalGaming => "original_gaming",
            ReviewLabel::InheritedGaming => "inherited_gaming",
        }
    }

    /// Accepted attempts contribute to reported speedups (§5.8: Minor
    /// Issues are accepted because the offline review gave the agent no
    /// chance to fix them and they don't affect measured performance).
    pub fn accepted(&self) -> bool {
        matches!(self, ReviewLabel::NoIssues | ReviewLabel::MinorIssues)
    }
}

/// The three-stage pipeline with its calibration.
#[derive(Debug, Clone)]
pub struct IntegrityPipeline {
    /// Runtimes more than this fraction below FP16 SOL are flagged
    /// (paper: 10% buffer for measurement noise ⇒ 0.9).
    pub ceiling_slack: f64,
    /// P(LGD catches a gaming attempt) — high because the SOL report
    /// augments the spec, but not perfect.
    pub lgd_detect_rate: f64,
    /// P(LGD labels a genuine kernel Minor) beyond true minor issues
    /// (reviewer conservatism).
    pub lgd_minor_fp_rate: f64,
}

impl Default for IntegrityPipeline {
    fn default() -> Self {
        IntegrityPipeline { ceiling_slack: 0.9, lgd_detect_rate: 0.93, lgd_minor_fp_rate: 0.02 }
    }
}

impl IntegrityPipeline {
    /// Label one attempt. Only correct attempts are reviewed (others never
    /// enter the speedup computation anyway).
    ///
    /// `t_sol_ms` is the TF32 SOL bound, `t_sol_fp16_ms` the FP16-augmented
    /// bound. Attempts that carry a compiled [`crate::dsl::KernelPlan`]
    /// declare their compute dtype, so the ceiling uses the *matching*
    /// bound: a full-precision plan claiming a sub-TF32-SOL runtime is
    /// implausible even when it sits above the FP16 bound. Attempts without
    /// a plan (raw CUDA, gaming) keep the conservative FP16 bound.
    pub fn label(
        &self,
        a: &AttemptRecord,
        t_sol_ms: f64,
        t_sol_fp16_ms: f64,
        rng: &mut Pcg32,
    ) -> ReviewLabel {
        // Pruned attempts (ADR-009) were never measured, so there is
        // nothing to review — but their unpruned twin is a correct DSL
        // attempt above the SOL ceiling (the prune gate guarantees the
        // ceiling branch is not taken, to ~6σ), whose review consumes one
        // minor-issues draw unless a recorded minor issue short-circuits
        // it. Consume the same draw here so every later label in the run
        // matches the unpruned twin bit-for-bit.
        if matches!(a.outcome, AttemptOutcome::Pruned { .. }) {
            if a.minor_issue.is_none() {
                let _ = rng.chance(self.lgd_minor_fp_rate);
            }
            return ReviewLabel::NoIssues;
        }
        let time = match a.outcome.time_ms() {
            Some(t) => t,
            None => return ReviewLabel::NoIssues, // not applicable
        };

        // static PyTorch-only detector: all launches match library patterns
        let pytorch_only = !a.kernel_names.is_empty()
            && a.kernel_names.iter().all(|k| is_library_kernel(k));

        // SOL-ceiling detector (strict runtime bounds check); the bound is
        // dtype-aware when the attempt's plan states full precision
        let sol_bound = match a.dsl_plan.as_deref() {
            Some(plan) if !plan.primary().reduced_precision() => t_sol_ms.max(t_sol_fp16_ms),
            _ => t_sol_fp16_ms,
        };
        if time < self.ceiling_slack * sol_bound {
            // physically implausible — flag regardless of LGD
            if pytorch_only {
                return ReviewLabel::PyTorchOnly; // categories stay exclusive
            }
            return ReviewLabel::SolCeiling;
        }

        // LGD review with the SOL report as specification augmentation
        let lgd_gaming = match &a.kind {
            SolutionKind::Gaming(_) => rng.chance(self.lgd_detect_rate),
            _ => false,
        };
        if lgd_gaming && pytorch_only {
            return ReviewLabel::PyTorchOnly;
        }
        if lgd_gaming {
            return if a.inherited {
                ReviewLabel::InheritedGaming
            } else {
                ReviewLabel::OriginalGaming
            };
        }
        if pytorch_only {
            return ReviewLabel::PyTorchOnly;
        }
        if a.minor_issue.is_some() || rng.chance(self.lgd_minor_fp_rate) {
            return ReviewLabel::MinorIssues;
        }
        ReviewLabel::NoIssues
    }

    /// Label every attempt of a run (deterministic given the seed).
    pub fn review_run(&self, run: &ProblemRun, seed: u64) -> Vec<ReviewLabel> {
        let mut rng =
            Pcg32::derive(seed, &[stream::INTEGRITY_REVIEW, run.problem_idx as u64]);
        run.attempts
            .iter()
            .map(|a| self.label(a, run.t_sol_ms, run.t_sol_fp16_ms, &mut rng))
            .collect()
    }

    /// Best accepted (integrity-filtered) time for a run.
    pub fn filtered_best_ms(&self, run: &ProblemRun, seed: u64) -> Option<f64> {
        let labels = self.review_run(run, seed);
        run.attempts
            .iter()
            .zip(&labels)
            .filter(|(_, l)| l.accepted())
            .filter_map(|(a, _)| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Filtered speedup (None = no accepted solution).
    pub fn filtered_speedup(&self, run: &ProblemRun, seed: u64) -> Option<f64> {
        self.filtered_best_ms(run, seed).map(|t| run.t_ref_ms / t)
    }

    /// Integrity-filtered geomean speedup of a whole run log (1.0 fallback
    /// per unsolved problem) — the one headline aggregation every
    /// reporting surface (CLI, examples, figures) must compute the same
    /// way.
    pub fn filtered_geomean(&self, log: &crate::agent::RunLog, seed: u64) -> f64 {
        let speedups: Vec<f64> = log
            .runs
            .iter()
            .map(|r| self.filtered_speedup(r, seed).unwrap_or(1.0))
            .collect();
        crate::metrics::geomean_speedup(&speedups)
    }

    /// Filtered speedup over only the first `prefix` attempts, without
    /// cloning the run (the scheduler-replay hot path: one call per policy
    /// per problem). Labels are deterministic per attempt given the seed,
    /// so reviewing a prefix equals truncating then reviewing.
    pub fn filtered_speedup_prefix(
        &self,
        run: &ProblemRun,
        seed: u64,
        prefix: usize,
    ) -> Option<f64> {
        // must mirror `review_run`'s derivation: labels are per-attempt
        // deterministic, so reviewing a prefix equals truncate-then-review
        let mut rng =
            Pcg32::derive(seed, &[stream::INTEGRITY_REVIEW, run.problem_idx as u64]);
        run.attempts
            .iter()
            .take(prefix)
            .map(|a| (a, self.label(a, run.t_sol_ms, run.t_sol_fp16_ms, &mut rng)))
            .filter(|(_, l)| l.accepted())
            .filter_map(|(a, _)| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .map(|t| run.t_ref_ms / t)
    }

    /// Partially-filtered speedup for the inflation analysis (Figure 12):
    /// `allow` lists labels to accept *in addition to* No/Minor.
    pub fn speedup_allowing(
        &self,
        run: &ProblemRun,
        seed: u64,
        allow: &[ReviewLabel],
    ) -> Option<f64> {
        let labels = self.review_run(run, seed);
        run.attempts
            .iter()
            .zip(&labels)
            .filter(|(_, l)| l.accepted() || allow.contains(l))
            .filter_map(|(a, _)| a.outcome.time_ms())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .map(|t| run.t_ref_ms / t)
    }
}

/// Aggregate label counts over a set of runs (Figure 10 bands).
pub fn outcome_counts(
    pipeline: &IntegrityPipeline,
    runs: &[ProblemRun],
    seed: u64,
) -> std::collections::BTreeMap<&'static str, usize> {
    let mut counts = std::collections::BTreeMap::new();
    for l in ReviewLabel::ALL {
        counts.insert(l.name(), 0usize);
    }
    for run in runs {
        for l in pipeline.review_run(run, seed) {
            // only correct attempts count toward review bands
            *counts.get_mut(l.name()).unwrap() += 1;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::{AttemptOutcome, GamingType};

    fn rec(kind: SolutionKind, time: f64, names: Vec<&str>, inherited: bool) -> AttemptRecord {
        AttemptRecord {
            problem_idx: 0,
            attempt: 0,
            outcome: AttemptOutcome::Correct { time_ms: time },
            kind,
            minor_issue: None,
            inherited,
            tokens: 0,
            tool_time_s: 0.0,
            config: None,
            kernel_names: names.into_iter().map(String::from).collect(),
            dsl_source: None,
            dsl_plan: None,
        }
    }

    fn pipeline() -> IntegrityPipeline {
        IntegrityPipeline { lgd_detect_rate: 1.0, ..Default::default() }
    }

    #[test]
    fn sol_ceiling_flags_implausible_runtime() {
        let p = pipeline();
        let mut rng = Pcg32::new(1, 1);
        let a = rec(SolutionKind::Gaming(GamingType::ConstantOutput), 0.01, vec!["k"], false);
        assert_eq!(p.label(&a, 1.0, 1.0, &mut rng), ReviewLabel::SolCeiling);
        // within 10% of SOL is fine
        let b = rec(SolutionKind::DslKernel, 0.95, vec!["ucutlass_x"], false);
        assert_eq!(p.label(&b, 1.0, 1.0, &mut rng), ReviewLabel::NoIssues);
    }

    #[test]
    fn sol_ceiling_is_dtype_aware_for_plan_attempts() {
        let p = pipeline();
        let mut rng = Pcg32::new(7, 1);
        let fp32_plan = crate::dsl::compile(
            "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
             .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)",
        )
        .unwrap()
        .plan;
        let fp16_plan = crate::dsl::compile(
            "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)",
        )
        .unwrap()
        .plan;
        // t = 1.2: above the FP16 bound (1.0) but below 0.9 × TF32 bound (2.0)
        let (t_sol, t_sol_fp16) = (2.0, 1.0);
        let mut a = rec(SolutionKind::DslKernel, 1.2, vec!["ucutlass_k"], false);
        a.dsl_plan = Some(fp32_plan);
        assert_eq!(
            p.label(&a, t_sol, t_sol_fp16, &mut rng),
            ReviewLabel::SolCeiling,
            "an fp32 plan claiming a sub-TF32-SOL runtime is implausible"
        );
        let mut b = rec(SolutionKind::DslKernel, 1.2, vec!["ucutlass_k"], false);
        b.dsl_plan = Some(fp16_plan);
        assert_eq!(p.label(&b, t_sol, t_sol_fp16, &mut rng), ReviewLabel::NoIssues,
            "the same runtime is plausible for a reduced-precision plan");
        // no plan → conservative FP16 bound, as before
        let c = rec(SolutionKind::RawCuda, 1.2, vec!["custom_k"], false);
        assert_eq!(p.label(&c, t_sol, t_sol_fp16, &mut rng), ReviewLabel::NoIssues);
    }

    #[test]
    fn pytorch_only_detected_statically() {
        let p = pipeline();
        let mut rng = Pcg32::new(2, 1);
        let a = rec(
            SolutionKind::PyTorchOnly,
            5.0,
            vec!["void at::native::vectorized_elementwise_kernel", "ampere_sgemm [cublas]"],
            false,
        );
        assert_eq!(p.label(&a, 1.0, 1.0, &mut rng), ReviewLabel::PyTorchOnly);
        // one custom kernel in the profile → not pytorch-only
        let b = rec(SolutionKind::RawCuda, 5.0, vec!["my_kernel", "cublas_helper"], false);
        assert_eq!(p.label(&b, 1.0, 1.0, &mut rng), ReviewLabel::NoIssues);
    }

    #[test]
    fn gaming_split_original_vs_inherited() {
        let p = pipeline();
        let mut rng = Pcg32::new(3, 1);
        let orig = rec(SolutionKind::Gaming(GamingType::SkippedComputation), 2.0, vec!["k"], false);
        let inh = rec(SolutionKind::Gaming(GamingType::SkippedComputation), 2.0, vec!["k"], true);
        assert_eq!(p.label(&orig, 1.0, 1.0, &mut rng), ReviewLabel::OriginalGaming);
        assert_eq!(p.label(&inh, 1.0, 1.0, &mut rng), ReviewLabel::InheritedGaming);
    }

    #[test]
    fn filtered_best_excludes_gaming() {
        let p = pipeline();
        let run = ProblemRun {
            problem_idx: 0,
            t_ref_ms: 10.0,
            t_sol_ms: 1.0,
            t_sol_fp16_ms: 1.0,
            attempts: vec![
                rec(SolutionKind::Gaming(GamingType::ConstantOutput), 1.2, vec!["k"], false),
                rec(SolutionKind::DslKernel, 2.0, vec!["ucutlass_k"], false),
            ],
        };
        // unfiltered best is the gamed 1.2ms; filtered is the honest 2.0ms
        assert_eq!(run.best_time_ms(), Some(1.2));
        assert_eq!(p.filtered_best_ms(&run, 7), Some(2.0));
        assert!((p.filtered_speedup(&run, 7).unwrap() - 5.0).abs() < 1e-9);
        // allowing gaming restores the inflated number (Figure 12 logic)
        let inflated = p
            .speedup_allowing(&run, 7, &[ReviewLabel::OriginalGaming, ReviewLabel::InheritedGaming])
            .unwrap();
        assert!(inflated > 8.0);
    }

    #[test]
    fn minor_issues_accepted() {
        let p = pipeline();
        let mut rng = Pcg32::new(5, 1);
        let mut a = rec(SolutionKind::DslKernel, 2.0, vec!["ucutlass_k"], false);
        a.minor_issue = Some(crate::agent::MinorIssueType::ContiguityAssumption);
        let l = p.label(&a, 1.0, 1.0, &mut rng);
        assert_eq!(l, ReviewLabel::MinorIssues);
        assert!(l.accepted());
    }
}
