//! Output rendering: aligned text tables, CSV files, and ASCII line plots
//! for the experiment drivers.

use std::fmt::Write as _;
use std::path::Path;

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in widths.iter() {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {:w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            let _ = write!(out, "| {:w$} ", cell, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Write rows as CSV (quotes cells containing commas).
pub fn write_csv(path: &Path, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut s = String::new();
    let esc = |c: &str| {
        if c.contains(',') || c.contains('"') {
            format!("\"{}\"", c.replace('"', "\"\""))
        } else {
            c.to_string()
        }
    };
    s.push_str(&headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
    s.push('\n');
    for row in rows {
        s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        s.push('\n');
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, s)
}

/// ASCII line plot of one or more named series over a shared x grid.
pub fn ascii_plot(
    title: &str,
    x: &[f64],
    series: &[(&str, &[f64])],
    width: usize,
    height: usize,
    log_x: bool,
) -> String {
    let mut out = format!("{title}\n");
    if x.is_empty() || series.is_empty() {
        return out;
    }
    let tx = |v: f64| if log_x { v.max(1e-12).ln() } else { v };
    let (x0, x1) = (tx(x[0]), tx(x[x.len() - 1]));
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MIN, f64::max)
        .max(1e-9);
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MAX, f64::min)
        .min(ymax);
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &xv) in x.iter().enumerate() {
            if xi >= ys.len() {
                break;
            }
            let px = if (x1 - x0).abs() < 1e-12 {
                0
            } else {
                (((tx(xv) - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize
            };
            let py = if (ymax - ymin).abs() < 1e-12 {
                height - 1
            } else {
                (height - 1)
                    - (((ys[xi] - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize
            };
            if px < width && py < height {
                grid[py][px] = marks[si % marks.len()];
            }
        }
    }
    let _ = writeln!(out, "  y: [{ymin:.2} .. {ymax:.2}]");
    for row in grid {
        let _ = writeln!(out, "  |{}", row.into_iter().collect::<String>());
    }
    let _ = writeln!(out, "  +{}", "-".repeat(width));
    let _ = writeln!(
        out,
        "   x: [{:.2} .. {:.2}]{}",
        x[0],
        x[x.len() - 1],
        if log_x { " (log)" } else { "" }
    );
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "   {} {}", marks[si % marks.len()], name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = table(&["a", "bbbb"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | bbbb |"));
        assert!(t.contains("| 1 | 2    |"));
    }

    #[test]
    fn csv_escapes() {
        let dir = std::env::temp_dir().join("ucutlass_csv_test");
        let p = dir.join("t.csv");
        write_csv(&p, &["x"], &[vec!["a,b".into()]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"a,b\""));
    }

    #[test]
    fn plot_contains_series() {
        let x = [1.0, 2.0, 4.0];
        let s = ascii_plot("T", &x, &[("a", &[1.0, 2.0, 3.0])], 20, 5, true);
        assert!(s.contains('*'));
        assert!(s.contains("T"));
    }
}
