//! Operator graph nodes with first-principles FLOP and byte accounting —
//! the *problem characterization* input to SOL analysis (paper §4.1).
//!
//! Byte counts follow the paper's best-case rule: each unique input element
//! is read from DRAM once, each output is written once, and intermediates
//! are fused where feasible. `Op::flops()`/`Op::out_elems()` encode the
//! per-operator work; graph-level fusion accounting lives in
//! [`super::problems::Problem`].

use crate::dsl::DType;

/// One operator in a problem's reference computation graph.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// C[m,n] = A[m,k] · B[k,n]
    Gemm { m: u64, n: u64, k: u64 },
    /// Batched GEMM over `b` independent problems.
    BatchedGemm { b: u64, m: u64, n: u64, k: u64 },
    /// Grouped GEMM (MoE-style), `groups` experts of m×n×k each.
    GroupedGemm { groups: u64, m: u64, n: u64, k: u64 },
    /// 2D convolution fprop, NHWC: out[n, p, q, co] from in[n, h, w, ci].
    Conv2d { n: u64, h: u64, w: u64, ci: u64, co: u64, kh: u64, kw: u64, stride: u64 },
    /// 1D convolution (SSM/long-conv style).
    Conv1d { n: u64, l: u64, ci: u64, co: u64, kw: u64, stride: u64, groups: u64 },
    /// Row softmax over [rows, cols].
    Softmax { rows: u64, cols: u64 },
    /// RMSNorm over [rows, cols] with per-feature weight.
    RmsNorm { rows: u64, cols: u64 },
    /// LayerNorm over [rows, cols] with weight+bias.
    LayerNorm { rows: u64, cols: u64 },
    /// Elementwise map (activation, scale, add, …): `ops_per_elem` FLOPs each.
    Elementwise { elems: u64, ops_per_elem: u64, inputs: u64 },
    /// Row reduction (sum/mean/max) from [rows, cols] to [rows].
    Reduce { rows: u64, cols: u64 },
    /// Prefix scan along rows of [rows, cols] (cumsum/cumprod).
    Scan { rows: u64, cols: u64 },
    /// Scaled dot-product attention: [b, h, s, d] q/k/v.
    Attention { b: u64, h: u64, s: u64, d: u64, causal: bool },
    /// Cross-entropy from [rows, classes] logits.
    CrossEntropy { rows: u64, classes: u64 },
    /// Matrix-vector product (decode GEMV).
    Gemv { m: u64, k: u64 },
}

impl Op {
    /// Total floating-point operations (2 FLOPs per MAC).
    pub fn flops(&self) -> u64 {
        match *self {
            Op::Gemm { m, n, k } => 2 * m * n * k,
            Op::BatchedGemm { b, m, n, k } => 2 * b * m * n * k,
            Op::GroupedGemm { groups, m, n, k } => 2 * groups * m * n * k,
            Op::Conv2d { n, h, w, ci, co, kh, kw, stride } => {
                let (p, q) = (h / stride, w / stride);
                2 * n * p * q * co * ci * kh * kw
            }
            Op::Conv1d { n, l, ci, co, kw, stride, groups } => {
                2 * n * (l / stride) * co * (ci / groups.max(1)) * kw
            }
            // max + sub + exp + sum + div  ≈ 5 passes of 1 flop
            Op::Softmax { rows, cols } => 5 * rows * cols,
            // square+sum (2), rsqrt-normalize (2), weight mul (1)
            Op::RmsNorm { rows, cols } => 5 * rows * cols,
            // mean (1), var (3), normalize (2), affine (2)
            Op::LayerNorm { rows, cols } => 8 * rows * cols,
            Op::Elementwise { elems, ops_per_elem, .. } => elems * ops_per_elem,
            Op::Reduce { rows, cols } => rows * cols,
            Op::Scan { rows, cols } => rows * cols,
            Op::Attention { b, h, s, d, causal } => {
                // QK^T + PV GEMMs (2·s²·d each) + softmax (5·s²); causal halves.
                let full = b * h * (4 * s * s * d + 5 * s * s);
                if causal {
                    full / 2
                } else {
                    full
                }
            }
            Op::CrossEntropy { rows, classes } => 6 * rows * classes,
            Op::Gemv { m, k } => 2 * m * k,
        }
    }

    /// Unique input elements read from DRAM (weights + activations).
    pub fn in_elems(&self) -> u64 {
        match *self {
            Op::Gemm { m, n, k } => m * k + k * n,
            Op::BatchedGemm { b, m, n, k } => b * (m * k + k * n),
            Op::GroupedGemm { groups, m, n, k } => groups * (m * k + k * n),
            Op::Conv2d { n, h, w, ci, co, kh, kw, .. } => n * h * w * ci + co * ci * kh * kw,
            Op::Conv1d { n, l, ci, co, kw, groups, .. } => {
                n * l * ci + co * (ci / groups.max(1)) * kw
            }
            Op::Softmax { rows, cols } => rows * cols,
            Op::RmsNorm { rows, cols } => rows * cols + cols,
            Op::LayerNorm { rows, cols } => rows * cols + 2 * cols,
            Op::Elementwise { elems, inputs, .. } => elems * inputs.max(1),
            Op::Reduce { rows, cols } => rows * cols,
            Op::Scan { rows, cols } => rows * cols,
            Op::Attention { b, h, s, d, .. } => 3 * b * h * s * d,
            Op::CrossEntropy { rows, classes } => rows * classes + rows,
            Op::Gemv { m, k } => m * k + k,
        }
    }

    /// Output elements written to DRAM.
    pub fn out_elems(&self) -> u64 {
        match *self {
            Op::Gemm { m, n, .. } => m * n,
            Op::BatchedGemm { b, m, n, .. } => b * m * n,
            Op::GroupedGemm { groups, m, n, .. } => groups * m * n,
            Op::Conv2d { n, h, w, co, stride, .. } => n * (h / stride) * (w / stride) * co,
            Op::Conv1d { n, l, co, stride, .. } => n * (l / stride) * co,
            Op::Softmax { rows, cols } => rows * cols,
            Op::RmsNorm { rows, cols } => rows * cols,
            Op::LayerNorm { rows, cols } => rows * cols,
            Op::Elementwise { elems, .. } => elems,
            Op::Reduce { rows, .. } => rows,
            Op::Scan { rows, cols } => rows * cols,
            Op::Attention { b, h, s, d, .. } => b * h * s * d,
            Op::CrossEntropy { .. } => 1,
            Op::Gemv { m, .. } => m,
        }
    }

    /// Best-case DRAM bytes when this op runs standalone (unfused):
    /// inputs read once + outputs written once.
    pub fn bytes(&self, dtype: DType) -> u64 {
        (self.in_elems() + self.out_elems()) * dtype.size()
    }

    /// Is this op's standalone roofline dominated by the MXU/tensor cores?
    pub fn is_matmul_like(&self) -> bool {
        matches!(
            self,
            Op::Gemm { .. }
                | Op::BatchedGemm { .. }
                | Op::GroupedGemm { .. }
                | Op::Conv2d { .. }
                | Op::Conv1d { .. }
                | Op::Attention { .. }
                | Op::Gemv { .. }
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::Gemm { .. } => "gemm",
            Op::BatchedGemm { .. } => "batched_gemm",
            Op::GroupedGemm { .. } => "grouped_gemm",
            Op::Conv2d { .. } => "conv2d",
            Op::Conv1d { .. } => "conv1d",
            Op::Softmax { .. } => "softmax",
            Op::RmsNorm { .. } => "rmsnorm",
            Op::LayerNorm { .. } => "layernorm",
            Op::Elementwise { .. } => "elementwise",
            Op::Reduce { .. } => "reduce",
            Op::Scan { .. } => "scan",
            Op::Attention { .. } => "attention",
            Op::CrossEntropy { .. } => "cross_entropy",
            Op::Gemv { .. } => "gemv",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_match_paper_example() {
        // Appendix A.2: N=4096 square GEMM → 2N³ = 1.374e11 FLOPs,
        // 3·N²·4 = 2.013e8 bytes.
        let op = Op::Gemm { m: 4096, n: 4096, k: 4096 };
        assert_eq!(op.flops(), 137_438_953_472);
        assert_eq!(op.bytes(DType::Fp32), 201_326_592);
    }

    #[test]
    fn gemm_arithmetic_intensity() {
        let op = Op::Gemm { m: 4096, n: 4096, k: 4096 };
        let ai = op.flops() as f64 / op.bytes(DType::Fp32) as f64;
        assert!((ai - 682.6).abs() < 1.0, "ai={ai}");
    }

    #[test]
    fn causal_attention_halves_flops() {
        let full = Op::Attention { b: 1, h: 8, s: 1024, d: 64, causal: false };
        let causal = Op::Attention { b: 1, h: 8, s: 1024, d: 64, causal: true };
        assert_eq!(causal.flops() * 2, full.flops());
    }

    #[test]
    fn conv_flops() {
        let op = Op::Conv2d { n: 1, h: 8, w: 8, ci: 16, co: 32, kh: 3, kw: 3, stride: 1 };
        assert_eq!(op.flops(), 2 * 64 * 32 * 16 * 9);
    }

    #[test]
    fn elementwise_bytes_scale_with_inputs() {
        let one = Op::Elementwise { elems: 100, ops_per_elem: 1, inputs: 1 };
        let two = Op::Elementwise { elems: 100, ops_per_elem: 1, inputs: 2 };
        assert!(two.bytes(DType::Fp32) > one.bytes(DType::Fp32));
    }

    #[test]
    fn softmax_is_memory_bound_shape() {
        let op = Op::Softmax { rows: 4096, cols: 4096 };
        let ai = op.flops() as f64 / op.bytes(DType::Fp32) as f64;
        assert!(ai < 10.0, "softmax AI should be tiny, got {ai}");
        assert!(!op.is_matmul_like());
    }
}
