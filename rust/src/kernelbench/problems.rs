//! The 59-problem KernelBench subset (paper Appendix A.3): LLM-relevant
//! problems from Levels 1–3, each with its reference op graph, shapes, and
//! fusion structure. Problems 2-80 and 2-24 are excluded exactly as in the
//! paper (their specifications admit shortcut implementations).
//!
//! Shapes follow KernelBench conventions where the paper pins them (L1-1 is
//! the 4096×4096 FP32 GEMM of Appendix A.2) and the A.3 rationale column
//! otherwise (e.g. L1-2 "M=2048, K=8192, N=4096").

use super::ops::Op;
use crate::dsl::DType;

/// Problem identity: KernelBench level (1–3) and problem number.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProblemId {
    pub level: u8,
    pub num: u32,
}

impl std::fmt::Display for ProblemId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}-{}", self.level, self.num)
    }
}

/// One evaluation problem: reference op graph + fusion accounting.
#[derive(Debug, Clone)]
pub struct Problem {
    pub id: ProblemId,
    pub name: &'static str,
    /// Appendix A.3 rationale for inclusion.
    pub rationale: &'static str,
    /// Reference computation as a chain of ops (op i+1 consumes op i's output).
    pub ops: Vec<Op>,
    /// Problem dtype as specified by KernelBench (always FP32).
    pub dtype: DType,
    /// Indices of ops whose output cannot be fused into the next op even in
    /// the best custom kernel (forces a DRAM round trip of that intermediate).
    pub unfusable_after: Vec<usize>,
    /// AOT artifact problem (python/compile/model.py) that numerically
    /// validates this problem's kernel family, when one exists.
    pub artifact: Option<&'static str>,
}

impl Problem {
    /// Total FLOPs of the reference computation.
    pub fn flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops()).sum()
    }

    /// Best-case DRAM bytes for a fully-fused implementation: external
    /// inputs once, final output once, plus unfusable intermediates
    /// (written + re-read). Assumes the op chain carries op i's output
    /// into op i+1.
    pub fn fused_bytes(&self) -> u64 {
        let mut elems: u64 = 0;
        for (i, op) in self.ops.iter().enumerate() {
            if i == 0 {
                elems += op.in_elems();
            } else {
                // aux inputs beyond the carried intermediate (weights, residuals)
                let carried = self.ops[i - 1].out_elems();
                elems += op.in_elems().saturating_sub(carried);
            }
        }
        elems += self.ops.last().map(|o| o.out_elems()).unwrap_or(0);
        for &i in &self.unfusable_after {
            // written once + read once
            elems += 2 * self.ops[i].out_elems();
        }
        elems * self.dtype.size()
    }

    /// Arithmetic intensity of the fused computation (FLOPs/byte).
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() as f64 / self.fused_bytes() as f64
    }

    /// Does any op in the graph use the tensor cores?
    pub fn is_matmul_like(&self) -> bool {
        self.ops.iter().any(|o| o.is_matmul_like())
    }

    /// The dominant (highest-FLOP) op.
    pub fn dominant_op(&self) -> &Op {
        self.ops.iter().max_by_key(|o| o.flops()).expect("non-empty graph")
    }
}

fn p(
    level: u8,
    num: u32,
    name: &'static str,
    rationale: &'static str,
    ops: Vec<Op>,
) -> Problem {
    Problem {
        id: ProblemId { level, num },
        name,
        rationale,
        ops,
        dtype: DType::Fp32,
        unfusable_after: vec![],
        artifact: None,
    }
}

fn with_artifact(mut prob: Problem, artifact: &'static str) -> Problem {
    prob.artifact = Some(artifact);
    prob
}

fn with_unfusable(mut prob: Problem, after: Vec<usize>) -> Problem {
    prob.unfusable_after = after;
    prob
}

const EW: u64 = 1 << 24; // 16M elements for L1 elementwise problems

/// Build the full 59-problem suite.
pub fn suite() -> Vec<Problem> {
    let mut v: Vec<Problem> = Vec::with_capacity(59);

    // =======================================================================
    // Level 1 — 31 problems
    // =======================================================================
    v.push(with_artifact(
        p(1, 1, "square_gemm", "Basic GEMM baseline.",
          vec![Op::Gemm { m: 4096, n: 4096, k: 4096 }]),
        "gemm_square"));
    v.push(p(1, 2, "llm_gemm", "LLM-like GEMM shapes (M=2048, K=8192, N=4096).",
          vec![Op::Gemm { m: 2048, n: 4096, k: 8192 }]));
    v.push(with_artifact(
        p(1, 3, "bmm", "Batched matmul (BMM) used in attention score/value products.",
          vec![Op::BatchedGemm { b: 128, m: 512, n: 512, k: 64 }]),
        "batched_gemm"));
    v.push(p(1, 4, "matvec", "Matrix-vector multiply representative of single-token decode.",
          vec![Op::Gemv { m: 4096, k: 4096 }]));
    v.push(p(1, 6, "large_k_gemm", "Matmul with large K (common in MLP projections).",
          vec![Op::Gemm { m: 1024, n: 1024, k: 16384 }]));
    v.push(p(1, 7, "small_k_gemm", "Matmul with small K (e.g., attention head dimension).",
          vec![Op::Gemm { m: 4096, n: 4096, k: 64 }]));
    v.push(p(1, 8, "irregular_gemm", "Irregular shapes (non power-of-2) that occur in practice.",
          vec![Op::Gemm { m: 1000, n: 1500, k: 700 }]));
    v.push(with_artifact(
        p(1, 9, "tall_skinny_gemm", "Tall-skinny matmul (prefill with long sequences).",
          vec![Op::Gemm { m: 16384, n: 512, k: 1024 }]),
        "gemm_tall_skinny"));
    v.push(p(1, 16, "gemm_at", "Transposed-A layout variant common in GEMM calls.",
          vec![Op::Gemm { m: 4096, n: 4096, k: 4096 }]));
    v.push(p(1, 17, "gemm_bt", "Transposed-B layout variant common for weight matrices.",
          vec![Op::Gemm { m: 4096, n: 4096, k: 4096 }]));
    v.push(p(1, 18, "gemm_atbt", "Both operands transposed (layout coverage).",
          vec![Op::Gemm { m: 4096, n: 4096, k: 4096 }]));
    v.push(p(1, 21, "sigmoid", "Sigmoid for gating patterns (e.g., GLU-style gates).",
          vec![Op::Elementwise { elems: EW, ops_per_elem: 4, inputs: 1 }]));
    v.push(p(1, 22, "tanh", "Tanh used in some gating/activation variants.",
          vec![Op::Elementwise { elems: EW, ops_per_elem: 4, inputs: 1 }]));
    v.push(with_artifact(
        p(1, 23, "softmax", "Softmax (core attention primitive).",
          vec![Op::Softmax { rows: 4096, cols: 4096 }]),
        "softmax"));
    v.push(p(1, 25, "silu", "SiLU/Swish (dominant MLP activation in many modern LLMs).",
          vec![Op::Elementwise { elems: EW, ops_per_elem: 5, inputs: 1 }]));
    v.push(p(1, 26, "gelu", "GELU (used in GPT-2/BERT and some contemporary models).",
          vec![Op::Elementwise { elems: EW, ops_per_elem: 8, inputs: 1 }]));
    v.push(with_artifact(
        p(1, 36, "rmsnorm", "RMSNorm (dominant normalization in modern decoder LLMs).",
          vec![Op::RmsNorm { rows: 4096, cols: 4096 }]),
        "rmsnorm"));
    v.push(with_artifact(
        p(1, 40, "layernorm", "LayerNorm (still used in many transformer variants).",
          vec![Op::LayerNorm { rows: 4096, cols: 4096 }]),
        "layernorm"));
    v.push(p(1, 47, "sum_reduce", "Sum reduction used inside normalization and statistics.",
          vec![Op::Reduce { rows: 4096, cols: 4096 }]));
    v.push(p(1, 48, "mean_reduce", "Mean reduction used inside LayerNorm and statistics.",
          vec![Op::Reduce { rows: 4096, cols: 4096 }]));
    v.push(p(1, 67, "conv1d", "1D convolution used in SSM/long-conv text models.",
          vec![Op::Conv1d { n: 16, l: 4096, ci: 512, co: 512, kw: 4, stride: 1, groups: 1 }]));
    v.push(p(1, 76, "conv1d_dilated", "Dilated/strided 1D conv variant for hierarchical SSM designs.",
          vec![Op::Conv1d { n: 16, l: 4096, ci: 512, co: 512, kw: 4, stride: 2, groups: 1 }]));
    v.push(p(1, 86, "depthwise_sep_conv", "Depthwise-separable conv (efficient channel-wise processing).",
          vec![
              Op::Conv1d { n: 16, l: 4096, ci: 512, co: 512, kw: 4, stride: 1, groups: 512 },
              Op::Conv1d { n: 16, l: 4096, ci: 512, co: 512, kw: 1, stride: 1, groups: 1 },
          ]));
    v.push(p(1, 87, "pointwise_conv", "Pointwise conv (channel mixing / fusion proxy).",
          vec![Op::Conv1d { n: 16, l: 4096, ci: 512, co: 512, kw: 1, stride: 1, groups: 1 }]));
    v.push(p(1, 88, "fast_gelu", "Fast GELU approximation (common fused activation variant).",
          vec![Op::Elementwise { elems: EW, ops_per_elem: 6, inputs: 1 }]));
    v.push(with_artifact(
        p(1, 89, "cumsum", "Cumsum (prefix-scan) used in SSM/linear-attention recurrences.",
          vec![Op::Scan { rows: 4096, cols: 4096 }]),
        "cumsum"));
    v.push(p(1, 90, "cumprod", "Cumprod used in some state-space dynamics.",
          vec![Op::Scan { rows: 4096, cols: 4096 }]));
    v.push(p(1, 91, "excl_cumsum", "Exclusive cumsum variant (scan coverage).",
          vec![Op::Scan { rows: 4096, cols: 4096 }]));
    v.push(p(1, 92, "rev_cumsum", "Reverse cumsum variant (reverse-time scan coverage).",
          vec![Op::Scan { rows: 4096, cols: 4096 }]));
    v.push(p(1, 95, "cross_entropy", "Cross-entropy loss (standard LLM training objective).",
          vec![Op::CrossEntropy { rows: 8192, classes: 50257 }]));
    v.push(with_artifact(
        p(1, 97, "sdpa", "Scaled dot-product attention (maps to FlashAttention in practice).",
          vec![Op::Attention { b: 8, h: 16, s: 1024, d: 64, causal: false }]),
        "attention"));

    // =======================================================================
    // Level 2 — 20 problems (fused multi-operator kernels)
    // =======================================================================
    let g1k = Op::Gemm { m: 1024, n: 1024, k: 1024 };
    v.push(p(2, 9, "gemm_sub_mul_relu", "Fused matmul + elementwise (proxy for epilogue and MLP fusions).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 3, inputs: 1 }]));
    v.push(p(2, 28, "bmm_instnorm_sum", "BMM fusion representative of multi-head attention dataflow.",
          vec![Op::BatchedGemm { b: 64, m: 256, n: 256, k: 64 },
               Op::LayerNorm { rows: 64 * 256, cols: 256 },
               Op::Reduce { rows: 64 * 256, cols: 256 }]));
    v.push(p(2, 29, "gemm_mish", "Fused linear + activation (MLP fusion pattern).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 8, inputs: 1 }]));
    v.push(p(2, 37, "gemm_swish_groupnorm", "Fused linear + normalization (proxy for norm-adjacent fusions).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 5, inputs: 1 },
               Op::LayerNorm { rows: 1024, cols: 1024 }]));
    v.push(p(2, 40, "gemm_scale_residual", "Fused linear + residual add (transformer block core pattern).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 2, inputs: 2 }]));
    v.push(p(2, 41, "gemm_bn_gelu_relu", "GEMM + multi-activation fusion (MLP epilogue diversity).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 12, inputs: 1 }]));
    v.push(p(2, 53, "gemm_scale_hardtanh_gelu", "GEMM + activation fusion (covers activation/scaling variants).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 10, inputs: 1 }]));
    v.push(p(2, 56, "gemm_sigmoid_sum", "Matmul + gating + reduction (proxy for gated aggregation patterns).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 4, inputs: 1 },
               Op::Reduce { rows: 1024, cols: 1024 }]));
    v.push(with_artifact(
        p(2, 59, "gemm_silu_scale", "Matmul + SiLU/Swish + scaling (common MLP fusion).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 6, inputs: 1 }]),
        "gemm_silu_scale"));
    v.push(p(2, 62, "gemm_groupnorm_leakyrelu", "Matmul + normalization + activation (fused post-linear processing).",
          vec![g1k.clone(),
               Op::LayerNorm { rows: 1024, cols: 1024 },
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 3, inputs: 1 }]));
    v.push(p(2, 63, "gemm_relu_div", "GEMM + ReLU + divide (activation + scaling fusion).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 2, inputs: 1 }]));
    v.push(p(2, 66, "attn_dropout", "Attention-like fusion with dropout (training attention pattern).",
          vec![Op::Attention { b: 8, h: 8, s: 512, d: 64, causal: false },
               Op::Elementwise { elems: 8 * 8 * 512 * 64, ops_per_elem: 2, inputs: 1 }]));
    v.push(with_artifact(
        p(2, 70, "gemm_sigmoid_residual", "GEMM + sigmoid gate + residual add (SwiGLU-like gating proxy).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 6, inputs: 2 }]),
        "gemm_sigmoid_residual"));
    v.push(with_artifact(
        p(2, 76, "gemm_bias_relu", "GEMM + bias add + ReLU (classic epilogue fusion).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 2, inputs: 1 }]),
        "gemm_bias_relu"));
    v.push(p(2, 81, "gemm_swish_clamp", "Complex epilogue fusion with Swish (stress fused elementwise).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 9, inputs: 1 }]));
    v.push(with_artifact(
        p(2, 86, "gemm_div_gelu", "Matmul + divide + GELU (MLP fusion with scaling).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 9, inputs: 1 }]),
        "gemm_divide_gelu"));
    v.push(p(2, 88, "swiglu_gate", "SwiGLU-like gated fusion (common LLM MLP pattern proxy).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 7, inputs: 2 }]));
    v.push(p(2, 94, "expert_mlp", "Expert MLP proxy: GEMM + bias/activation + normalization.",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 10, inputs: 1 },
               Op::LayerNorm { rows: 1024, cols: 1024 }]));
    v.push(p(2, 97, "gemm_bn_swish", "Matmul + bias + norm + Swish (fused post-linear processing).",
          vec![g1k.clone(),
               Op::LayerNorm { rows: 1024, cols: 1024 },
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 5, inputs: 1 }]));
    v.push(p(2, 99, "gemm_gelu_softmax", "Attention-like fusion (matmul + GELU + softmax).",
          vec![g1k.clone(),
               Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 8, inputs: 1 },
               Op::Softmax { rows: 1024, cols: 1024 }]));

    // =======================================================================
    // Level 3 — 8 problems (module-level workloads)
    // =======================================================================
    v.push(with_unfusable(with_artifact(
        p(3, 1, "mlp", "MLP (basic feedforward block).",
          vec![
              Op::Gemm { m: 1024, n: 4096, k: 1024 },
              Op::Elementwise { elems: 1024 * 4096, ops_per_elem: 1, inputs: 1 },
              Op::Gemm { m: 1024, n: 1024, k: 4096 },
          ]),
        "mlp_block"), vec![1]));
    v.push(with_unfusable(
        p(3, 2, "wide_mlp", "Shallow wide MLP (LLM FFN-like width).",
          vec![
              Op::Gemm { m: 512, n: 8192, k: 2048 },
              Op::Elementwise { elems: 512 * 8192, ops_per_elem: 1, inputs: 1 },
              Op::Gemm { m: 512, n: 2048, k: 8192 },
          ]), vec![1]));
    v.push(with_unfusable(
        p(3, 3, "deep_mlp", "Deep narrow MLP (depth/width trade-off).",
          vec![
              Op::Gemm { m: 1024, n: 1024, k: 1024 },
              Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 1, inputs: 1 },
              Op::Gemm { m: 1024, n: 1024, k: 1024 },
              Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 1, inputs: 1 },
              Op::Gemm { m: 1024, n: 1024, k: 1024 },
              Op::Elementwise { elems: 1024 * 1024, ops_per_elem: 1, inputs: 1 },
              Op::Gemm { m: 1024, n: 1024, k: 1024 },
          ]), vec![1, 3, 5]));
    v.push(with_artifact(
        p(3, 43, "causal_attention", "Causal attention block (core decoder attention).",
          vec![Op::Attention { b: 16, h: 12, s: 1024, d: 64, causal: true }]),
        "causal_attention"));
    v.push(with_unfusable(
        p(3, 44, "gpt_block", "Full GPT block (attention + FFN).",
          vec![
              Op::LayerNorm { rows: 16 * 1024, cols: 768 },
              Op::Gemm { m: 16 * 1024, n: 3 * 768, k: 768 },          // qkv proj
              Op::Attention { b: 16, h: 12, s: 1024, d: 64, causal: true },
              Op::Gemm { m: 16 * 1024, n: 768, k: 768 },              // out proj
              Op::LayerNorm { rows: 16 * 1024, cols: 768 },
              Op::Gemm { m: 16 * 1024, n: 4 * 768, k: 768 },          // fc1
              Op::Elementwise { elems: 16 * 1024 * 4 * 768, ops_per_elem: 8, inputs: 1 },
              Op::Gemm { m: 16 * 1024, n: 768, k: 4 * 768 },          // fc2
          ]), vec![1, 2, 3, 5, 6]));
    v.push(with_unfusable(
        p(3, 48, "mamba_block", "Mamba SSM block (emerging text SSM architecture).",
          vec![
              Op::Gemm { m: 16 * 1024, n: 2 * 1024, k: 512 },          // in proj
              Op::Conv1d { n: 16, l: 1024, ci: 1024, co: 1024, kw: 4, stride: 1, groups: 1024 },
              Op::Elementwise { elems: 16 * 1024 * 1024, ops_per_elem: 5, inputs: 1 },
              Op::Scan { rows: 16 * 1024, cols: 1024 },                // selective scan
              Op::Gemm { m: 16 * 1024, n: 512, k: 1024 },              // out proj
          ]), vec![0, 3]));
    v.push(with_unfusable(
        p(3, 49, "mamba_state", "Mamba SSM with state output (streaming/stateful variant).",
          vec![
              Op::Gemm { m: 16 * 1024, n: 2 * 1024, k: 512 },
              Op::Conv1d { n: 16, l: 1024, ci: 1024, co: 1024, kw: 4, stride: 1, groups: 1024 },
              Op::Elementwise { elems: 16 * 1024 * 1024, ops_per_elem: 5, inputs: 1 },
              Op::Scan { rows: 16 * 1024, cols: 1024 },
              Op::Elementwise { elems: 16 * 1024 * 1024, ops_per_elem: 2, inputs: 2 },
              Op::Gemm { m: 16 * 1024, n: 512, k: 1024 },
          ]), vec![0, 3]));
    v.push(with_unfusable(
        p(3, 50, "relu_attention", "ReLU self-attention variant (alternative attention formulation).",
          vec![
              Op::BatchedGemm { b: 16 * 12, m: 1024, n: 1024, k: 64 }, // QK^T
              Op::Elementwise { elems: 192 * 1024 * 1024, ops_per_elem: 2, inputs: 1 }, // relu+scale
              Op::BatchedGemm { b: 16 * 12, m: 1024, n: 64, k: 1024 }, // PV
          ]), vec![1]));

    debug_assert_eq!(v.len(), 59);
    v
}

/// Look up one problem by id string like "L1-1" / "1-1".
pub fn find(suite: &[Problem], key: &str) -> Option<usize> {
    let k = key.trim_start_matches('L').trim_start_matches('l');
    let (lvl, num) = k.split_once('-')?;
    let id = ProblemId { level: lvl.parse().ok()?, num: num.parse().ok()? };
    suite.iter().position(|p| p.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_59_problems_matching_appendix_a3() {
        let s = suite();
        assert_eq!(s.len(), 59);
        let l1: Vec<u32> = s.iter().filter(|p| p.id.level == 1).map(|p| p.id.num).collect();
        let l2: Vec<u32> = s.iter().filter(|p| p.id.level == 2).map(|p| p.id.num).collect();
        let l3: Vec<u32> = s.iter().filter(|p| p.id.level == 3).map(|p| p.id.num).collect();
        assert_eq!(l1, vec![1, 2, 3, 4, 6, 7, 8, 9, 16, 17, 18, 21, 22, 23, 25, 26, 36, 40,
                            47, 48, 67, 76, 86, 87, 88, 89, 90, 91, 92, 95, 97]);
        assert_eq!(l2, vec![9, 28, 29, 37, 40, 41, 53, 56, 59, 62, 63, 66, 70, 76, 81, 86,
                            88, 94, 97, 99]);
        assert_eq!(l3, vec![1, 2, 3, 43, 44, 48, 49, 50]);
    }

    #[test]
    fn excluded_problems_absent() {
        let s = suite();
        // L2-80 (Gemm_Max_Subtract_GELU) and L2-24 are excluded per §5.2.
        assert!(!s.iter().any(|p| p.id.level == 2 && (p.id.num == 80 || p.id.num == 24)));
    }

    #[test]
    fn fused_bytes_below_unfused_sum() {
        for prob in suite() {
            let unfused: u64 = prob.ops.iter().map(|o| o.bytes(prob.dtype)).sum();
            assert!(prob.fused_bytes() <= unfused,
                "{}: fused {} > unfused {}", prob.id, prob.fused_bytes(), unfused);
        }
    }

    #[test]
    fn gemm_problems_are_compute_bound_shapes() {
        let s = suite();
        let p11 = &s[find(&s, "L1-1").unwrap()];
        assert!(p11.arithmetic_intensity() > 500.0);
        let softmax = &s[find(&s, "L1-23").unwrap()];
        assert!(softmax.arithmetic_intensity() < 10.0);
    }

    #[test]
    fn find_parses_ids() {
        let s = suite();
        assert!(find(&s, "L1-1").is_some());
        assert!(find(&s, "2-76").is_some());
        assert!(find(&s, "L9-1").is_none());
    }

    #[test]
    fn artifacts_reference_real_python_problems() {
        let known = ["gemm_square", "gemm_tall_skinny", "batched_gemm", "softmax",
                     "rmsnorm", "layernorm", "cumsum", "attention", "causal_attention",
                     "gemm_bias_relu", "gemm_divide_gelu", "gemm_silu_scale",
                     "gemm_sigmoid_residual", "mlp_block"];
        for prob in suite() {
            if let Some(a) = prob.artifact {
                assert!(known.contains(&a), "{}: unknown artifact {a}", prob.id);
            }
        }
    }

    #[test]
    fn every_problem_has_positive_work() {
        for prob in suite() {
            assert!(prob.flops() > 0, "{}", prob.id);
            assert!(prob.fused_bytes() > 0, "{}", prob.id);
        }
    }
}
