//! The KernelBench evaluation suite: operator graphs with first-principles
//! FLOP/byte accounting ([`ops`]) and the paper's 59-problem LLM-relevant
//! subset ([`problems`], Appendix A.3).

pub mod ops;
pub mod problems;

pub use ops::Op;
pub use problems::{find, suite, Problem, ProblemId};
