//! Single-pass multi-policy sweep engine (ADR-005).
//!
//! The paper's efficiency headline (fig8/fig9) compares 72 budgeting
//! policies — the full ε×w grid — per variant. Driving sessions once *per
//! policy* pays for the grid 72 times; this module pays for it once:
//!
//! 1. drive each (variant, problem, seed) session **once to exhaustion**
//!    (the fixed-budget pass, fanned across `exec::parallel_map` workers —
//!    bit-identical at any job count, ADR-002) against whatever oracle the
//!    `Env` carries (analytic, or a recorded trace, ADR-004);
//! 2. build one [`ReplayCache`] over the exhausted log (each attempt is
//!    reviewed exactly once);
//! 3. apply every [`StopRule`](super::StopRule) policy of the grid
//!    offline.
//!
//! This is sound because online stops provably agree with offline replay
//! (the shared `StopRule`, pinned by the scheduler determinism tests) and
//! an early-stopped session's log is the exact per-problem prefix of the
//! exhausted log (ADR-002 session semantics). The golden test
//! `sweep_equals_per_policy_replay` closes the loop end-to-end: every
//! number `repro schedule` would have produced by re-driving sessions per
//! policy falls out of the one exhausted pass, field for field, while a
//! [`TraceMonitor`](crate::eval::TraceMonitor)-based test shows the sweep
//! issues ≤ 1/72 of the per-policy evaluator calls.

use crate::agent::controller::{Env, VariantSpec};
use crate::agent::{ProblemRun, RunLog};
use crate::integrity::IntegrityPipeline;

use super::online::run_online;
use super::{
    best_policy, epsilon_grid, window_grid, Policy, ReplayCache, ReplayResult,
};

/// The full fig8/fig9 policy grid: every (ε, w) combination, ε outer and
/// w inner — exactly the order the per-log `scheduler::sweep()` function
/// has always produced, so grid index i is comparable across all sweep
/// surfaces.
pub fn policy_grid() -> Vec<Policy> {
    let mut grid = Vec::new();
    for &e in &epsilon_grid() {
        for &w in &window_grid() {
            grid.push(Policy { epsilon: e, window: w });
        }
    }
    grid
}

/// One variant's offline policy sweep: a single [`ReplayCache`] build
/// (one review pass over every attempt) shared by the fixed-allocation
/// reference and all 72 grid policies. fig8, fig9, and the CLI sweep all
/// consume this one structure instead of rebuilding caches per figure.
pub struct PolicySweep {
    /// The shared per-log precomputation — kept public so callers can
    /// replay off-grid policies (e.g. `repro schedule --eps/--window`)
    /// against the same single pass.
    pub cache: ReplayCache,
    /// Fixed-allocation (never-stop) reference replay.
    pub fixed: ReplayResult,
    /// One result per [`policy_grid`] entry, in grid order.
    pub results: Vec<ReplayResult>,
}

impl PolicySweep {
    pub fn over(log: &RunLog, pipeline: &IntegrityPipeline, review_seed: u64) -> PolicySweep {
        let cache = ReplayCache::build(log, pipeline, review_seed);
        let fixed = cache.replay(&Policy::fixed());
        let results = policy_grid().iter().map(|p| cache.replay(p)).collect();
        PolicySweep { cache, fixed, results }
    }

    /// Best grid policy by efficiency gain under a retention floor
    /// (fig9's ≥95% constraint).
    pub fn best(&self, min_retention: f64) -> Option<&ReplayResult> {
        best_policy(&self.results, min_retention)
    }
}

/// Per-problem prefix of `log` under the given stop indices: the log the
/// online scheduler would have produced had the policy run live (the
/// prefix property of ADR-002 sessions; the sweep golden test pins the
/// equality against real online runs).
pub fn truncate_log(log: &RunLog, attempts_used: &[usize]) -> RunLog {
    assert_eq!(log.runs.len(), attempts_used.len(), "one stop index per problem");
    RunLog {
        variant: log.variant.clone(),
        tier_name: log.tier_name.clone(),
        price_per_mtok: log.price_per_mtok,
        runs: log
            .runs
            .iter()
            .zip(attempts_used)
            .map(|(r, &n)| ProblemRun {
                problem_idx: r.problem_idx,
                t_ref_ms: r.t_ref_ms,
                t_sol_ms: r.t_sol_ms,
                t_sol_fp16_ms: r.t_sol_fp16_ms,
                attempts: r.attempts[..n.min(r.attempts.len())].to_vec(),
            })
            .collect(),
    }
}

/// One variant driven once to exhaustion plus its full offline grid —
/// the unit `repro sweep` and `repro schedule` are built on.
pub struct SweepRun {
    pub spec: VariantSpec,
    /// The exhausted (fixed-budget) session log: the only session pass
    /// this sweep ever executes.
    pub log: RunLog,
    pub sweep: PolicySweep,
}

impl SweepRun {
    /// Derive the outcome of one (possibly off-grid) policy offline from
    /// the exhausted pass: attempts, tokens, and the truncated log equal
    /// to what a live online run of that policy would have produced.
    pub fn outcome(&self, policy: &Policy) -> ScheduleOutcome {
        let replay = self.sweep.cache.replay(policy);
        let log = truncate_log(&self.log, &replay.attempts_used);
        ScheduleOutcome {
            policy: *policy,
            tokens_used: replay.tokens_used,
            tokens_fixed: replay.tokens_fixed,
            attempts_used: replay.attempts_used,
            attempts_budget: self.spec.total_budget() as usize,
            log,
        }
    }
}

/// Drive every (problem) session of one variant once to exhaustion
/// (fanned across the `exec` pool at `jobs > 1`; bit-identical at any job
/// count) and apply the full policy grid offline. Orchestrated variants
/// run as per-problem sessions (per-session memory), exactly like the
/// online scheduler they stand in for (ADR-002 boundary).
pub fn sweep_sessions(
    env: &Env,
    spec: &VariantSpec,
    seed: u64,
    jobs: usize,
    pipeline: &IntegrityPipeline,
    review_seed: u64,
) -> SweepRun {
    // Policy::fixed() never stops: run_online's rotation degenerates into
    // driving each session to exhaustion, parallelized via
    // exec::parallel_map with bit-identical output (online tests pin it).
    let full = run_online(env, spec, seed, &Policy::fixed(), jobs);
    let sweep = PolicySweep::over(&full.log, pipeline, review_seed);
    SweepRun { spec: *spec, log: full.log, sweep }
}

/// What one `repro schedule` invocation reports for one policy, derived
/// offline from the single exhausted pass. Field-for-field equal to the
/// realized online run of the same policy (golden-tested), at 1/Nth the
/// evaluator cost of re-driving sessions per policy.
pub struct ScheduleOutcome {
    pub policy: Policy,
    /// Attempts the policy lets each problem consume.
    pub attempts_used: Vec<usize>,
    /// Nominal per-problem budget had no rule fired.
    pub attempts_budget: usize,
    /// Tokens under the policy (== `log.total_tokens()`).
    pub tokens_used: u64,
    /// Tokens of the full fixed-allocation pass.
    pub tokens_fixed: u64,
    /// The truncated log: per problem, exactly the attempts the online
    /// scheduler would have executed.
    pub log: RunLog,
}

impl ScheduleOutcome {
    pub fn attempts_total(&self) -> usize {
        self.attempts_used.iter().sum()
    }

    /// Fraction of the fixed attempt budget the policy does not spend.
    pub fn attempt_savings(&self) -> f64 {
        let full = (self.attempts_budget * self.attempts_used.len()).max(1);
        1.0 - self.attempts_total() as f64 / full as f64
    }

    /// Problems a stopping rule retires before budget exhaustion.
    pub fn stopped_early(&self) -> usize {
        self.attempts_used.iter().filter(|&&u| u < self.attempts_budget).count()
    }

    pub fn token_savings(&self) -> f64 {
        1.0 - self.tokens_used as f64 / self.tokens_fixed.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::ControllerKind;
    use crate::agent::ModelTier;
    use crate::experiments::runner::{run_variant, Bench};
    use crate::scheduler;

    #[test]
    fn policy_grid_is_the_72_point_fig8_grid_in_sweep_order() {
        let grid = policy_grid();
        assert_eq!(grid.len(), 72, "12 ε × 6 w");
        // same order the free sweep() function has always produced
        let mut i = 0;
        for &e in &epsilon_grid() {
            for &w in &window_grid() {
                assert_eq!(grid[i], Policy { epsilon: e, window: w });
                i += 1;
            }
        }
    }

    #[test]
    fn policy_sweep_matches_per_policy_replay_per_log() {
        // one cache build must be observationally identical to 72 + 1
        // independent replays (the pre-existing contract of ReplayCache,
        // restated at the PolicySweep level)
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini);
        let log = run_variant(&bench, &spec, 9, None);
        let pipeline = IntegrityPipeline::default();
        let ps = PolicySweep::over(&log, &pipeline, 9);
        assert_eq!(ps.results.len(), 72);
        for (p, r) in policy_grid().iter().zip(&ps.results) {
            let direct = scheduler::replay(&log, p, &pipeline, 9);
            assert_eq!(r.attempts_used, direct.attempts_used, "{}", p.label());
            assert_eq!(r.tokens_used, direct.tokens_used);
            assert_eq!(r.geomean, direct.geomean);
            assert_eq!(r.median, direct.median);
        }
        let fixed = scheduler::replay(&log, &Policy::fixed(), &pipeline, 9);
        assert_eq!(ps.fixed.attempts_used, fixed.attempts_used);
        assert_eq!(ps.fixed.tokens_used, fixed.tokens_used);
    }

    #[test]
    fn truncate_log_takes_exact_prefixes_and_clamps() {
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini);
        let log = run_variant(&bench, &spec, 3, None);
        let mut stops: Vec<usize> = log.runs.iter().map(|r| r.attempts.len()).collect();
        stops[0] = 1;
        stops[1] = 0;
        stops[2] = usize::MAX; // clamped to the full run
        let t = truncate_log(&log, &stops);
        assert_eq!(t.runs[0].attempts[..], log.runs[0].attempts[..1]);
        assert!(t.runs[1].attempts.is_empty());
        assert_eq!(t.runs[2], log.runs[2]);
        assert_eq!(t.runs[3..], log.runs[3..]);
        assert_eq!(t.variant, log.variant);
        // metadata (baselines, SOL bounds) survives truncation untouched
        assert_eq!(t.runs[1].t_ref_ms, log.runs[1].t_ref_ms);
        assert_eq!(t.runs[1].t_sol_fp16_ms, log.runs[1].t_sol_fp16_ms);
    }

    #[test]
    fn schedule_outcome_accounting() {
        let bench = Bench::new();
        let env = bench.env();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini);
        let pipeline = IntegrityPipeline::default();
        let run = sweep_sessions(&env, &spec, 5, 1, &pipeline, 5);
        let out = run.outcome(&Policy { epsilon: 1.0, window: 8 });
        assert_eq!(out.attempts_total(), out.attempts_used.iter().sum::<usize>());
        assert_eq!(out.tokens_used, out.log.total_tokens());
        assert_eq!(out.tokens_fixed, run.log.total_tokens());
        assert_eq!(out.attempts_budget, spec.total_budget() as usize);
        let fixed_out = run.outcome(&Policy::fixed());
        assert_eq!(fixed_out.stopped_early(), 0);
        assert_eq!(fixed_out.log, run.log, "fixed outcome is the exhausted pass itself");
        assert!(fixed_out.attempt_savings().abs() < 1e-12);
    }
}
