//! SOL-guided budget scheduling (paper §4.3, §5.7, §6.2).
//!
//! Offline replay over completed run logs: simulate what would have
//! happened had problems been stopped earlier under a policy, then compare
//! token cost and achieved (integrity-filtered) speedup against the fixed
//! 40-attempt allocation.
//!
//! Eligibility (breadth-first round-robin): a problem keeps receiving
//! attempts while it is still behind PyTorch, or while neither criterion
//! has fired:
//! * **SOL-headroom stop** — `t_best ≤ (1+ε)·t_SOL_fp16` and ahead of
//!   PyTorch;
//! * **no-progress window** — best speedup unimproved for `w` consecutive
//!   attempts while ahead of PyTorch.

pub mod online;
pub mod sweep;

use crate::agent::RunLog;
use crate::integrity::IntegrityPipeline;
use crate::metrics;

pub use online::{run_online, OnlineRun};
pub use sweep::{
    policy_grid, sweep_sessions, truncate_log, PolicySweep, ScheduleOutcome, SweepRun,
};

/// A scheduling policy: ε (fraction, e.g. 0.25 = 25%) and window w.
/// `epsilon = f64::INFINITY` disables the SOL rule; `window = 0` disables
/// the no-progress rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Policy {
    pub epsilon: f64,
    pub window: u32,
}

impl Policy {
    pub fn fixed() -> Policy {
        Policy { epsilon: f64::INFINITY, window: 0 }
    }

    pub fn label(&self) -> String {
        let e = if self.epsilon.is_finite() {
            format!("ε={}%", (self.epsilon * 100.0).round())
        } else {
            "ε=off".into()
        };
        let w = if self.window > 0 { format!("w={}", self.window) } else { "w=off".into() };
        format!("{e}, {w}")
    }
}

/// Incremental form of the stopping rules: the state a scheduler carries
/// per problem while attempts stream in. Both the offline [`stop_index`]
/// replay and the online engine ([`online::run_online`]) feed attempts
/// through this one implementation, so "what replay predicts" and "what
/// the live scheduler did" agree by construction, not by coincidence.
#[derive(Debug, Clone)]
pub struct StopRule {
    best: f64,
    stale: u32,
}

impl Default for StopRule {
    fn default() -> Self {
        StopRule::new()
    }
}

impl StopRule {
    pub fn new() -> StopRule {
        StopRule { best: f64::INFINITY, stale: 0 }
    }

    /// The SOL-headroom band: is `t_ms` within the policy's `(1+ε)` band
    /// above the FP16 SOL bound? This one predicate is shared by the
    /// stopping rule (`observe` applies it to the best measurement once a
    /// problem is ahead of its reference) and the fleet's admission
    /// ordering (ADR-007 applies it to the *baseline*: a reference already
    /// inside the band has little headroom left to win, so its work is
    /// deprioritized fleet-wide) — the paper's SOL guidance applied at the
    /// cluster level through the same arithmetic as the per-session rule.
    pub fn sol_band(policy: &Policy, t_ms: f64, t_sol_fp16_ms: f64) -> bool {
        policy.epsilon.is_finite() && t_ms <= (1.0 + policy.epsilon) * t_sol_fp16_ms
    }

    /// Feed one attempt's measurement; `true` means the problem stops
    /// *after* this attempt (the attempt itself was still executed).
    pub fn observe(
        &mut self,
        t_ref_ms: f64,
        t_sol_fp16_ms: f64,
        time_ms: Option<f64>,
        policy: &Policy,
    ) -> bool {
        // The SOL-ceiling detector runs online as a strict runtime bounds
        // check (§4.4): measurements >10% below the FP16 SOL bound are
        // physically implausible and must not drive stopping decisions.
        let t = time_ms.filter(|&t| t >= 0.9 * t_sol_fp16_ms);
        match t {
            Some(t) if t < self.best => {
                self.best = t;
                self.stale = 0;
            }
            _ => self.stale += 1,
        }
        if self.best >= t_ref_ms {
            return false; // still behind PyTorch: always eligible
        }
        if Self::sol_band(policy, self.best, t_sol_fp16_ms) {
            return true;
        }
        policy.window > 0 && self.stale >= policy.window
    }
}

/// Attempts a problem receives before the policy stops it (index into the
/// recorded attempt sequence; == len when never stopped).
pub fn stop_index(
    t_ref_ms: f64,
    t_sol_fp16_ms: f64,
    attempt_times: &[Option<f64>],
    policy: &Policy,
) -> usize {
    let mut rule = StopRule::new();
    for (i, t) in attempt_times.iter().enumerate() {
        if rule.observe(t_ref_ms, t_sol_fp16_ms, *t, policy) {
            return i + 1;
        }
    }
    attempt_times.len()
}

/// Result of replaying one policy over a run log.
#[derive(Debug, Clone)]
pub struct ReplayResult {
    pub policy: Policy,
    /// Attempts consumed per problem.
    pub attempts_used: Vec<usize>,
    pub tokens_used: u64,
    pub tokens_fixed: u64,
    /// Integrity-filtered geomean speedup under the policy (1.0 fallback).
    pub geomean: f64,
    pub median: f64,
    /// Fixed-allocation (full budget) filtered geomean / median.
    pub geomean_fixed: f64,
    pub median_fixed: f64,
}

impl ReplayResult {
    pub fn token_savings(&self) -> f64 {
        1.0 - self.tokens_used as f64 / self.tokens_fixed.max(1) as f64
    }

    pub fn attempt_savings(&self, budget: usize) -> f64 {
        let used: usize = self.attempts_used.iter().sum();
        1.0 - used as f64 / (budget * self.attempts_used.len()).max(1) as f64
    }

    pub fn geomean_retention(&self) -> f64 {
        metrics::retention(self.geomean, self.geomean_fixed)
    }

    pub fn median_retention(&self) -> f64 {
        if self.median_fixed == 0.0 {
            0.0
        } else {
            self.median / self.median_fixed
        }
    }

    pub fn efficiency_gain(&self) -> f64 {
        metrics::efficiency_gain(
            self.geomean,
            self.geomean_fixed,
            self.tokens_used as f64,
            self.tokens_fixed as f64,
        )
    }
}

/// Per-log precomputation shared by every policy in a sweep: attempt
/// times, token prefix sums, and the integrity-filtered best-so-far
/// speedup after each attempt count — all policy-independent, so a 72-
/// policy sweep reviews each attempt exactly once instead of 72 times.
pub struct ReplayCache {
    per_problem: Vec<ProblemCache>,
    tokens_fixed: u64,
    speedups_fixed: Vec<f64>,
}

struct ProblemCache {
    t_ref_ms: f64,
    t_sol_fp16_ms: f64,
    times: Vec<Option<f64>>,
    /// token_prefix[i] = tokens of the first i attempts.
    token_prefix: Vec<u64>,
    /// filtered_best_after[i] = integrity-filtered speedup using the first
    /// i attempts (1.0 fallback).
    filtered_best_after: Vec<f64>,
}

impl ReplayCache {
    pub fn build(log: &RunLog, pipeline: &IntegrityPipeline, review_seed: u64) -> Self {
        let mut per_problem = Vec::with_capacity(log.runs.len());
        let mut tokens_fixed = 0u64;
        let mut speedups_fixed = Vec::with_capacity(log.runs.len());
        for run in &log.runs {
            let labels = pipeline.review_run(run, review_seed);
            let n = run.attempts.len();
            let mut token_prefix = Vec::with_capacity(n + 1);
            let mut filtered_best_after = Vec::with_capacity(n + 1);
            let mut tokens = 0u64;
            let mut best: Option<f64> = None;
            token_prefix.push(0);
            filtered_best_after.push(1.0);
            for (a, l) in run.attempts.iter().zip(&labels) {
                tokens += a.tokens;
                token_prefix.push(tokens);
                if l.accepted() {
                    if let Some(t) = a.outcome.time_ms() {
                        best = Some(best.map_or(t, |b: f64| b.min(t)));
                    }
                }
                filtered_best_after.push(best.map(|t| run.t_ref_ms / t).unwrap_or(1.0));
            }
            tokens_fixed += tokens;
            speedups_fixed.push(*filtered_best_after.last().unwrap());
            per_problem.push(ProblemCache {
                t_ref_ms: run.t_ref_ms,
                t_sol_fp16_ms: run.t_sol_fp16_ms,
                times: run.attempts.iter().map(|a| a.outcome.time_ms()).collect(),
                token_prefix,
                filtered_best_after,
            });
        }
        ReplayCache { per_problem, tokens_fixed, speedups_fixed }
    }

    /// Replay one policy against the cache.
    pub fn replay(&self, policy: &Policy) -> ReplayResult {
        let mut attempts_used = Vec::with_capacity(self.per_problem.len());
        let mut tokens_used = 0u64;
        let mut speedups = Vec::with_capacity(self.per_problem.len());
        for p in &self.per_problem {
            let stop = stop_index(p.t_ref_ms, p.t_sol_fp16_ms, &p.times, policy);
            attempts_used.push(stop);
            tokens_used += p.token_prefix[stop];
            speedups.push(p.filtered_best_after[stop]);
        }
        ReplayResult {
            policy: *policy,
            attempts_used,
            tokens_used,
            tokens_fixed: self.tokens_fixed,
            geomean: metrics::geomean_speedup(&speedups),
            median: metrics::median_speedup(&speedups),
            geomean_fixed: metrics::geomean_speedup(&self.speedups_fixed),
            median_fixed: metrics::median_speedup(&self.speedups_fixed),
        }
    }
}

/// Replay a policy over a run log. Stopping decisions see the *online*
/// (unfiltered) measurements, as the real scheduler would; reported
/// speedups are integrity-filtered on the truncated prefix, as in §6.2.
pub fn replay(
    log: &RunLog,
    policy: &Policy,
    pipeline: &IntegrityPipeline,
    review_seed: u64,
) -> ReplayResult {
    ReplayCache::build(log, pipeline, review_seed).replay(policy)
}

/// The paper's sweep grids (§6.2.2): ε ∈ {25%…300%}, w ∈ {0,4,…,20}.
pub fn epsilon_grid() -> Vec<f64> {
    (1..=12).map(|i| 0.25 * i as f64).collect()
}

pub fn window_grid() -> Vec<u32> {
    vec![0, 4, 8, 12, 16, 20]
}

/// Joint sweep of all (ε, w) combinations (one shared [`ReplayCache`]).
/// Thin wrapper over [`PolicySweep`] — callers that also need the fixed
/// reference or off-grid replays should hold the `PolicySweep` instead of
/// rebuilding the cache.
pub fn sweep(
    log: &RunLog,
    pipeline: &IntegrityPipeline,
    review_seed: u64,
) -> Vec<ReplayResult> {
    PolicySweep::over(log, pipeline, review_seed).results
}

/// Indices of the Pareto-optimal points (maximize geomean, minimize cost).
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.retain(|&i| {
        !points.iter().enumerate().any(|(j, &(cj, gj))| {
            j != i && cj <= points[i].0 && gj >= points[i].1 && (cj, gj) != points[i]
        })
    });
    idx.sort_by(|&a, &b| points[a].0.partial_cmp(&points[b].0).unwrap());
    idx
}

/// Best policy by efficiency gain subject to a geomean-retention floor
/// (paper §6.2.3 uses ≥ 95%).
pub fn best_policy(results: &[ReplayResult], min_retention: f64) -> Option<&ReplayResult> {
    results
        .iter()
        .filter(|r| r.geomean_retention() >= min_retention)
        .max_by(|a, b| a.efficiency_gain().partial_cmp(&b.efficiency_gain()).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stop_index_sol_rule() {
        // t_ref 10, fp16 SOL 1.0, ε=100% → stop once best ≤ 2.0 and ahead
        let p = Policy { epsilon: 1.0, window: 0 };
        let times = vec![Some(12.0), Some(5.0), Some(1.8), Some(1.5)];
        assert_eq!(stop_index(10.0, 1.0, &times, &p), 3);
        // never reaches the bound → full budget
        let times2 = vec![Some(5.0), Some(4.0), Some(3.0)];
        assert_eq!(stop_index(10.0, 1.0, &times2, &p), 3);
    }

    #[test]
    fn stop_index_window_rule() {
        let p = Policy { epsilon: f64::INFINITY, window: 2 };
        // ahead after attempt 0; no improvement on 1,2 → stop after 3 attempts
        let times = vec![Some(5.0), Some(6.0), None, Some(5.5)];
        assert_eq!(stop_index(10.0, 1.0, &times, &p), 3);
    }

    #[test]
    fn behind_pytorch_never_stopped() {
        let p = Policy { epsilon: 0.25, window: 2 };
        let times = vec![Some(20.0), None, None, None, None];
        assert_eq!(stop_index(10.0, 1.0, &times, &p), 5);
    }

    #[test]
    fn fixed_policy_never_stops() {
        let p = Policy::fixed();
        let times = vec![Some(1.0); 40];
        assert_eq!(stop_index(10.0, 1.0, &times, &p), 40);
    }

    #[test]
    fn sol_band_agrees_with_the_stop_rule() {
        // the band predicate is the SOL branch of `observe`: with the
        // no-progress rule off and a measurement ahead of the reference,
        // observe() stops exactly when sol_band() holds for that time
        for &(eps, t) in &[(0.25, 1.2), (0.25, 2.0), (1.0, 1.9), (1.0, 2.1), (3.0, 3.9)] {
            let p = Policy { epsilon: eps, window: 0 };
            let mut rule = StopRule::new();
            let stopped = rule.observe(10.0, 1.0, Some(t), &p);
            assert_eq!(
                stopped,
                StopRule::sol_band(&p, t, 1.0),
                "ε={eps} t={t}: observe and sol_band must agree"
            );
        }
        // ε=off disables the band entirely
        assert!(!StopRule::sol_band(&Policy::fixed(), 0.5, 1.0));
    }

    #[test]
    fn pareto_front_filters_dominated() {
        // (cost, geomean)
        let pts = vec![(1.0, 2.0), (0.5, 1.9), (0.9, 1.5), (0.4, 1.0)];
        let front = pareto_front(&pts);
        assert!(front.contains(&0));
        assert!(front.contains(&1));
        assert!(front.contains(&3));
        assert!(!front.contains(&2), "(0.9,1.5) is dominated by (0.5,1.9)");
    }

    #[test]
    fn cached_replay_equals_direct_replay() {
        // the ReplayCache fast path must be observationally identical to a
        // from-scratch replay for every policy on a real run log
        use crate::agent::controller::{run_problem, ControllerKind, Env, VariantSpec};
        use crate::agent::{ModelTier, RunLog};
        use crate::integrity::IntegrityPipeline;
        use crate::kernelbench::suite;
        use crate::perfmodel::PerfModel;
        use crate::sol::{analyze, H100_SXM};

        let problems = suite();
        let sols: Vec<_> = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let model = PerfModel::new(H100_SXM.clone());
        let compiled = crate::perfmodel::CompiledCostModel::compile(&model, &problems);
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max);
        let runs: Vec<_> = (0..12).map(|i| run_problem(&env, &spec, i, 5)).collect();
        let log = RunLog {
            variant: "t".into(),
            tier_name: "gpt-5.2".into(),
            price_per_mtok: 1.75,
            runs,
        };
        let pipeline = IntegrityPipeline::default();
        let cache = ReplayCache::build(&log, &pipeline, 9);
        for &e in &[0.25, 1.0, 3.0, f64::INFINITY] {
            for &w in &[0u32, 4, 16] {
                let p = Policy { epsilon: e, window: w };
                let a = cache.replay(&p);
                let b = replay(&log, &p, &pipeline, 9);
                assert_eq!(a.attempts_used, b.attempts_used, "{}", p.label());
                assert_eq!(a.tokens_used, b.tokens_used);
                assert!((a.geomean - b.geomean).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn policy_labels() {
        assert_eq!(Policy { epsilon: 0.25, window: 16 }.label(), "ε=25%, w=16");
        assert_eq!(Policy::fixed().label(), "ε=off, w=off");
    }
}
