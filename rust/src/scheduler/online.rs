//! Online SOL-budgeted scheduling (paper §4.3): the eligibility rules
//! applied *during* execution, so attempt and token savings are realized,
//! not just simulated by offline replay.
//!
//! The engine turns every problem into a resumable
//! [`ProblemSession`](crate::agent::session::ProblemSession) and serves
//! attempts breadth-first round-robin: every live problem receives attempt
//! `k` before any problem receives attempt `k+1`, exactly the fairness
//! order a shared GPU-tool budget imposes. After each attempt the
//! problem's [`StopRule`] — the same incremental implementation offline
//! [`stop_index`](super::stop_index) replays — decides whether the problem
//! leaves the rotation.
//!
//! Because sessions are mutually independent and each owns a derived RNG
//! stream, the round-robin order does not influence any measurement, so
//! the parallel path (each worker drives whole sessions to completion)
//! produces bit-identical logs; a test asserts it. For the same reason an
//! online run under `Policy::fixed()` reproduces the classic fixed-40 log
//! exactly, and the log of any early-stopped run is a per-problem prefix
//! of that fixed log — the replay-agreement tests below close the
//! replay-vs-reality gap.

use crate::agent::controller::{Env, VariantSpec};
use crate::agent::session::ProblemSession;
use crate::agent::{ProblemRun, RunLog};
use crate::exec;

use super::{Policy, StopRule};

/// Result of one online-scheduled suite run.
#[derive(Debug, Clone)]
pub struct OnlineRun {
    pub policy: Policy,
    /// The truncated log: per problem, exactly the attempts that executed.
    pub log: RunLog,
    /// Attempts consumed per problem (== `log.runs[i].attempts.len()`).
    pub attempts_used: Vec<usize>,
    /// Nominal per-problem budget had no rule fired.
    pub attempts_budget: usize,
    /// Tokens actually spent (== `log.total_tokens()`).
    pub tokens_used: u64,
}

impl OnlineRun {
    pub fn attempts_total(&self) -> usize {
        self.attempts_used.iter().sum()
    }

    /// Fraction of the fixed attempt budget the policy did not spend.
    pub fn attempt_savings(&self) -> f64 {
        let full = (self.attempts_budget * self.attempts_used.len()).max(1);
        1.0 - self.attempts_total() as f64 / full as f64
    }

    /// Problems a stopping rule retired before budget exhaustion.
    pub fn stopped_early(&self) -> usize {
        self.attempts_used.iter().filter(|&&u| u < self.attempts_budget).count()
    }

    /// Realized token savings against a full fixed-budget run of the same
    /// (variant, seed) — the paper's §6.2 headline number, measured on
    /// execution rather than replay.
    pub fn token_savings_vs(&self, fixed: &RunLog) -> f64 {
        1.0 - self.tokens_used as f64 / fixed.total_tokens().max(1) as f64
    }
}

/// Drive one session to completion under `policy` (the per-task body of
/// the parallel path).
fn drive(mut session: ProblemSession<'_>, policy: &Policy) -> ProblemRun {
    let mut rule = StopRule::new();
    let t_ref = session.t_ref_ms();
    let t_sol = session.t_sol_fp16_ms();
    while let Some(step) = session.step() {
        if rule.observe(t_ref, t_sol, step.time_ms, policy) {
            break;
        }
    }
    session.finish()
}

/// Run one variant over the whole suite with online budgeting. `jobs <= 1`
/// uses the literal breadth-first round-robin rotation; `jobs > 1` fans
/// sessions across the work-stealing pool (bit-identical output, since
/// sessions are independent). Orchestrated variants run with per-session
/// memory — the sequential cross-problem chain cannot be round-robin
/// scheduled (ADR-002).
pub fn run_online(
    env: &Env,
    spec: &VariantSpec,
    seed: u64,
    policy: &Policy,
    jobs: usize,
) -> OnlineRun {
    let n = env.problems.len();
    let runs: Vec<ProblemRun> = if exec::effective_jobs(jobs) > 1 {
        exec::parallel_map(jobs, n, |pidx| {
            drive(ProblemSession::new(*env, spec, pidx, seed), policy)
        })
    } else {
        // Breadth-first round-robin (§4.3): one rotation serves every live
        // problem one attempt, then stopped/exhausted problems retire.
        let mut slots: Vec<Option<(ProblemSession, StopRule)>> = (0..n)
            .map(|pidx| Some((ProblemSession::new(*env, spec, pidx, seed), StopRule::new())))
            .collect();
        let mut done: Vec<Option<ProblemRun>> = (0..n).map(|_| None).collect();
        let mut live: Vec<usize> = (0..n).collect();
        while !live.is_empty() {
            let mut next = Vec::with_capacity(live.len());
            for &i in &live {
                let (session, rule) = slots[i].as_mut().expect("live slot");
                let retired = match session.step() {
                    None => true,
                    Some(step) => {
                        let t_ref = session.t_ref_ms();
                        let t_sol = session.t_sol_fp16_ms();
                        rule.observe(t_ref, t_sol, step.time_ms, policy)
                    }
                };
                if retired {
                    let (session, _) = slots[i].take().expect("live slot");
                    done[i] = Some(session.finish());
                } else {
                    next.push(i);
                }
            }
            live = next;
        }
        done.into_iter().map(|r| r.expect("every problem finishes")).collect()
    };

    let attempts_used: Vec<usize> = runs.iter().map(|r| r.attempts.len()).collect();
    let log = RunLog {
        variant: spec.label(),
        tier_name: spec.tier.name().to_string(),
        price_per_mtok: spec.tier.params().price_per_mtok,
        runs,
    };
    OnlineRun {
        policy: *policy,
        tokens_used: log.total_tokens(),
        attempts_used,
        attempts_budget: spec.total_budget() as usize,
        log,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::controller::{run_problem, ControllerKind};
    use crate::agent::ModelTier;
    use crate::experiments::runner::Bench;
    use crate::integrity::IntegrityPipeline;
    use crate::metrics;
    use crate::scheduler::{self, Policy};

    fn fixed_reference(env: &Env, spec: &VariantSpec, seed: u64) -> Vec<ProblemRun> {
        (0..env.problems.len()).map(|p| run_problem(env, spec, p, seed)).collect()
    }

    #[test]
    fn online_fixed_policy_determinism() {
        // under Policy::fixed() the online engine must reproduce the
        // classic fixed-budget logs exactly, serial and parallel alike
        let bench = Bench::new();
        let env = bench.env();
        for spec in [
            VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid),
            VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini),
        ] {
            let reference = fixed_reference(&env, &spec, 21);
            let serial = run_online(&env, &spec, 21, &Policy::fixed(), 1);
            let par = run_online(&env, &spec, 21, &Policy::fixed(), 4);
            assert_eq!(serial.log.runs, reference, "{}", spec.label());
            assert_eq!(par.log.runs, reference, "{}", spec.label());
            assert_eq!(serial.stopped_early(), 0);
            // budget accounting must use the controller's structural
            // budget, not the (orchestrated-ignored) attempts field
            assert_eq!(serial.attempts_budget, spec.total_budget() as usize);
            assert!((serial.attempt_savings()).abs() < 1e-12);
        }
    }

    #[test]
    fn online_stops_agree_with_offline_replay_determinism() {
        // replay-vs-reality closure: replaying the policy over the FULL
        // fixed log must predict exactly where the online engine stopped,
        // and the online log must be the per-problem prefix of that log
        let bench = Bench::new();
        let env = bench.env();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Max);
        let full = fixed_reference(&env, &spec, 12345);
        for policy in [
            Policy { epsilon: 1.0, window: 8 },
            Policy { epsilon: 0.25, window: 0 },
            Policy { epsilon: f64::INFINITY, window: 4 },
        ] {
            let online = run_online(&env, &spec, 12345, &policy, 2);
            for (pidx, run) in online.log.runs.iter().enumerate() {
                let times: Vec<Option<f64>> =
                    full[pidx].attempts.iter().map(|a| a.outcome.time_ms()).collect();
                let predicted = scheduler::stop_index(
                    full[pidx].t_ref_ms,
                    full[pidx].t_sol_fp16_ms,
                    &times,
                    &policy,
                );
                assert_eq!(
                    run.attempts.len(),
                    predicted,
                    "policy {} problem {pidx}",
                    policy.label()
                );
                assert_eq!(
                    run.attempts[..],
                    full[pidx].attempts[..predicted],
                    "online log must be the exact prefix of the fixed log"
                );
            }
        }
    }

    #[test]
    fn online_epsilon100_w8_saves_budget_and_retains_geomean() {
        // the paper's headline policy must realize savings during
        // execution while keeping ≥95% of the fixed geomean (§6.2)
        let bench = Bench::new();
        let env = bench.env();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Max);
        let policy = Policy { epsilon: 1.0, window: 8 };
        let online = run_online(&env, &spec, 12345, &policy, 2);
        let fixed = run_online(&env, &spec, 12345, &Policy::fixed(), 2);

        assert!(online.stopped_early() > 0, "some problems must stop early");
        assert!(
            online.attempts_total() < fixed.attempts_total(),
            "attempts: online {} vs fixed {}",
            online.attempts_total(),
            fixed.attempts_total()
        );
        assert!(online.tokens_used < fixed.tokens_used);
        assert!(online.token_savings_vs(&fixed.log) > 0.0);

        let pipeline = IntegrityPipeline::default();
        let retention = metrics::retention(
            pipeline.filtered_geomean(&online.log, 99),
            pipeline.filtered_geomean(&fixed.log, 99),
        );
        assert!(
            retention >= 0.95,
            "ε=100%/w=8 must retain ≥95% of fixed geomean, got {retention:.3}"
        );
    }

    #[test]
    fn online_savings_accounting() {
        let run = OnlineRun {
            policy: Policy { epsilon: 1.0, window: 8 },
            log: RunLog {
                variant: "t".into(),
                tier_name: "t".into(),
                price_per_mtok: 1.0,
                runs: vec![],
            },
            attempts_used: vec![10, 40, 30],
            attempts_budget: 40,
            tokens_used: 50,
        };
        assert_eq!(run.attempts_total(), 80);
        assert_eq!(run.stopped_early(), 2);
        assert!((run.attempt_savings() - (1.0 - 80.0 / 120.0)).abs() < 1e-12);
    }
}
