//! Minimal error plumbing for the runtime layer (anyhow is not in the
//! vendored crate set). A string-backed error type, a `Result` alias, a
//! formatting constructor macro, and a `with_context` extension that
//! mirrors the subset of the anyhow API the crate uses.

use std::fmt;

/// A string-backed error with optional context chain (joined with `: `).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }

    /// Prepend a context layer, anyhow-style.
    pub fn context(self, ctx: impl Into<String>) -> Self {
        Error { msg: format!("{}: {}", ctx.into(), self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Crate-local `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `errmsg!("parsing {}: {e}", path)` — formatted [`Error`] constructor.
#[macro_export]
macro_rules! errmsg {
    ($($arg:tt)*) => {
        $crate::util::errors::Error::msg(format!($($arg)*))
    };
}

/// `with_context` on any displayable error, mirroring anyhow's combinator.
pub trait ResultExt<T> {
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> ResultExt<T> for std::result::Result<T, E> {
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f().into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn result_ext_adds_context() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "formatting").unwrap_err();
        assert!(e.to_string().starts_with("formatting: "));
    }

    #[test]
    fn errmsg_formats() {
        let e = errmsg!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
    }
}
