//! In-house substrates for the offline environment: JSON, seeded RNG,
//! statistics helpers, error plumbing, and a tiny property-testing driver.
//!
//! serde / rand / proptest / anyhow are not in the vendored crate set, so
//! these are implemented from scratch (DESIGN.md §2 substitution table).

pub mod errors;
pub mod json;
pub mod rng;
pub mod stats;
pub mod prop;

/// Format a float with engineering-friendly precision (for tables).
pub fn fmt_f(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

/// `ceil(a / b)` for positive integers.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// FNV-1a 64-bit — the one content-hash every identity in the crate uses
/// (DSL config hashes, candidate-config fingerprints, shard assignment,
/// RNG label forks). One implementation, so the copies can never drift.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Streaming FNV-1a 128-bit hasher — the substrate of the interned
/// [`EvalKey`](crate::eval::EvalKey) (ADR-005). Process-stable by
/// construction: the digest is a pure function of the byte stream, with no
/// dependence on `std::hash` randomization, pointer values, or build
/// layout, so a key computed today matches one computed by any other build
/// of this code. 128 bits keeps the birthday bound far beyond any suite
/// enumeration (~2^64 keys for a 50% collision chance).
///
/// Field writes go through the typed helpers (`write_u64` little-endian,
/// `write_str` length-prefixed) so that variable-length fields cannot
/// alias each other's encodings.
#[derive(Debug, Clone, Copy)]
pub struct Fnv128 {
    h: u128,
}

impl Fnv128 {
    pub const OFFSET_BASIS: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    pub const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013B;

    pub fn new() -> Fnv128 {
        Fnv128 { h: Self::OFFSET_BASIS }
    }

    pub fn write(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.h ^= b as u128;
            self.h = self.h.wrapping_mul(Self::PRIME);
        }
        self
    }

    pub fn write_u8(&mut self, v: u8) -> &mut Self {
        self.write(&[v])
    }

    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write(&v.to_le_bytes())
    }

    /// Bit-exact float identity (`to_bits`): distinguishes `0.0` from
    /// `-0.0`, exactly like the shortest-roundtrip string forms do.
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    /// Length-prefixed string write (prefix keeps `"ab"+"c"` and
    /// `"a"+"bc"` from hashing identically across adjacent fields).
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes())
    }

    pub fn finish(&self) -> u128 {
        self.h
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 128 over a byte slice.
pub fn fnv128(bytes: &[u8]) -> u128 {
    let mut h = Fnv128::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv128_process_stability_golden_vectors() {
        // pinned against an independent reference implementation of the
        // published FNV-1a 128 constants: these digests must never change
        // across builds, platforms, or refactors (EvalKey stability rests
        // on them)
        assert_eq!(fnv128(b""), Fnv128::OFFSET_BASIS);
        assert_eq!(fnv128(b"a"), 0xd228_cb69_6f1a_8caf_7891_2b70_4e4a_8964);
        assert_eq!(fnv128(b"hello world"), 0x6c15_5799_fdc8_eec4_b915_2380_8e77_26b7);
    }

    #[test]
    fn fnv128_typed_writes_compose_like_raw_bytes() {
        let mut a = Fnv128::new();
        a.write_u64(3).write_str("ab").write_u8(7).write_f64(-0.0);
        let mut raw = Vec::new();
        raw.extend_from_slice(&3u64.to_le_bytes());
        raw.extend_from_slice(&2u64.to_le_bytes());
        raw.extend_from_slice(b"ab");
        raw.push(7);
        raw.extend_from_slice(&(-0.0f64).to_bits().to_le_bytes());
        assert_eq!(a.finish(), fnv128(&raw));
        // length prefix: shifting bytes between adjacent strings must
        // change the digest
        let mut b = Fnv128::new();
        b.write_str("ab").write_str("c");
        let mut c = Fnv128::new();
        c.write_str("a").write_str("bc");
        assert_ne!(b.finish(), c.finish());
        // -0.0 and 0.0 are distinct identities
        let mut p = Fnv128::new();
        p.write_f64(0.0);
        let mut n = Fnv128::new();
        n.write_f64(-0.0);
        assert_ne!(p.finish(), n.finish());
    }
}
