//! In-house substrates for the offline environment: JSON, seeded RNG,
//! statistics helpers, error plumbing, and a tiny property-testing driver.
//!
//! serde / rand / proptest / anyhow are not in the vendored crate set, so
//! these are implemented from scratch (DESIGN.md §2 substitution table).

pub mod errors;
pub mod json;
pub mod rng;
pub mod stats;
pub mod prop;

/// Format a float with engineering-friendly precision (for tables).
pub fn fmt_f(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

/// `ceil(a / b)` for positive integers.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// FNV-1a 64-bit — the one content-hash every identity in the crate uses
/// (DSL config hashes, candidate-config fingerprints, shard assignment,
/// RNG label forks). One implementation, so the copies can never drift.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}
