//! Tiny property-testing driver (proptest is not in the vendored crate set).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases` random
//! inputs drawn from a seeded RNG and panics with the failing seed so the
//! case can be replayed deterministically:
//!
//! ```no_run
//! use ucutlass_repro::util::prop;
//! prop::check("add-commutes", 100, |r| {
//!     let (a, b) = (r.f64(), r.f64());
//!     assert!((a + b - (b + a)).abs() < 1e-15);
//! });
//! ```

use super::rng::{stream, Pcg32};

/// Run `f` against `cases` seeded RNGs; panic identifies the failing seed.
pub fn check<F: Fn(&mut Pcg32)>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Pcg32::derive(seed, &[stream::PROP_CASE, case]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("trivial", 10, |r| {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        check("fails", 5, |_r| panic!("boom"));
    }
}
