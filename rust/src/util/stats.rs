//! Statistics helpers used across metrics and experiments: geometric mean,
//! median/quantiles, coefficient of variation, trapezoid integration.

/// Geometric mean of strictly positive values; `fallback` substitutes for
/// non-positive entries (the paper keeps the PyTorch-seed 1.0× for problems
/// the agent never solved — see metrics::fastp for the Fast-p convention).
pub fn geomean_with_fallback(values: &[f64], fallback: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let s: f64 = values
        .iter()
        .map(|&v| if v > 0.0 { v.ln() } else { fallback.max(1e-12).ln() })
        .sum();
    (s / values.len() as f64).exp()
}

/// Geometric mean of positive values, ignoring non-positive ones.
pub fn geomean(values: &[f64]) -> f64 {
    let pos: Vec<f64> = values.iter().copied().filter(|v| *v > 0.0).collect();
    if pos.is_empty() {
        return 0.0;
    }
    (pos.iter().map(|v| v.ln()).sum::<f64>() / pos.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Population standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    (values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / values.len() as f64).sqrt()
}

/// Coefficient of variation σ/µ (paper §6.4, Figure 13).
pub fn cv(values: &[f64]) -> f64 {
    let m = mean(values);
    if m == 0.0 {
        return 0.0;
    }
    stddev(values) / m
}

/// Quantile with linear interpolation, q in [0, 1].
pub fn quantile(values: &[f64], q: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(values: &[f64]) -> f64 {
    quantile(values, 0.5)
}

/// Trapezoid integral of y(x) over sample points (x must be ascending).
pub fn trapz(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = 0.0;
    for i in 1..x.len() {
        acc += 0.5 * (y[i] + y[i - 1]) * (x[i] - x[i - 1]);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_fallback_substitutes() {
        let g = geomean_with_fallback(&[4.0, 0.0], 1.0);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quantile_endpoints() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 3.0);
    }

    #[test]
    fn cv_scale_invariant() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        assert!((cv(&a) - cv(&b)).abs() < 1e-12);
    }

    #[test]
    fn trapz_linear() {
        let x = [0.0, 1.0, 2.0];
        let y = [0.0, 1.0, 2.0];
        assert!((trapz(&x, &y) - 2.0).abs() < 1e-12);
    }
}
