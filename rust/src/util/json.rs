//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), run-log
//! serialization, and experiment outputs. Covers the full JSON grammar
//! (RFC 8259) minus exotic number edge cases we never emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Strict unsigned-integer view: negative, fractional, and
    /// beyond-2^53 numbers yield `None` instead of silently saturating or
    /// truncating (callers parse indices and counts, where a wrong value
    /// is worse than an in-band parse failure). u64s needing more than 53
    /// bits travel as hex strings (see `eval::stream_to_json`).
    pub fn as_u64(&self) -> Option<u64> {
        match self.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f <= (1u64 << 53) as f64 => {
                Some(f as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (idx, v) in a.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (idx, (k, v)) in m.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.i += 1;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\\n\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn pretty_roundtrips() {
        let mut o = Json::obj();
        o.set("x", 1u64).set("y", vec!["a", "b"]);
        let p = o.to_pretty();
        assert_eq!(Json::parse(&p).unwrap(), o);
    }

    #[test]
    fn prop_random_values_roundtrip() {
        // fuzz: arbitrary nested values survive serialize → parse
        crate::util::prop::check("json-roundtrip", 200, |rng| {
            fn gen(rng: &mut crate::util::rng::Pcg32, depth: usize) -> Json {
                match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.chance(0.5)),
                    2 => Json::Num((rng.f64() * 2e6 - 1e6).round() / 8.0),
                    3 => {
                        let n = rng.below(12);
                        Json::Str((0..n).map(|_| *rng.choice(&['a', '"', '\\', 'é', '\n', '7'])).collect())
                    }
                    4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..rng.below(5) {
                            m.insert(format!("k{i}"), gen(rng, depth - 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            let v = gen(rng, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        });
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn as_u64_is_strict() {
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num((1u64 << 53) as f64).as_u64(), Some(1 << 53));
        // negative, fractional, oversized, and mistyped values fail in-band
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(1e18).as_u64(), None);
        assert_eq!(Json::Str("7".into()).as_u64(), None);
        assert_eq!(Json::Null.as_u64(), None);
    }
}
