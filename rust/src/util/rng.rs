//! Deterministic seeded RNG (PCG32 + SplitMix64 seeding).
//!
//! Every stochastic component in the reproduction (SimLLM behaviours,
//! measurement noise, archive generation) draws from a `Pcg32` derived from
//! an experiment seed, so every figure is exactly reproducible.

/// SplitMix64 — used to expand a user seed into PCG state.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Well-known first components for [`Pcg32::derive`] paths. Every
/// independent consumer of randomness derives its stream under a distinct
/// root, so streams from different subsystems (and therefore from
/// different parallel tasks) can never collide even when the remaining
/// path components (variant, problem index, …) coincide.
pub mod stream {
    /// Flat MI / in-prompt controller loops (one stream per variant×problem).
    pub const FLAT_CONTROLLER: u64 = 0x01;
    /// Orchestrated MANTIS sessions.
    pub const MANTIS: u64 = 0x02;
    /// Integrity-pipeline review labelling.
    pub const INTEGRITY_REVIEW: u64 = 0x03;
    /// Evolutionary-archive generation.
    pub const ARCHIVE_GEN: u64 = 0x04;
    /// Evolutionary-archive review order.
    pub const ARCHIVE_REVIEW: u64 = 0x05;
    /// PJRT runtime validation inputs.
    pub const RUNTIME_INPUTS: u64 = 0x06;
    /// Property-test case generation.
    pub const PROP_CASE: u64 = 0x07;
    /// Measurement-noise streams (one derived stream per measurement,
    /// handed out by [`super::MeasureSeq`] — see ADR-003).
    pub const MEASURE: u64 = 0x08;
    /// Fleet fault-injection schedules (one derived stream per worker
    /// slot, `derive(seed, &[FAULT, slot])` — see ADR-007).
    pub const FAULT: u64 = 0x09;
}

/// Serializable identity of a derived RNG stream: an experiment seed plus
/// the [`Pcg32::derive`] path. An `eval::EvalRequest` carries one of these
/// so a measurement replayed in another process draws the exact same noise
/// as the in-process run — the draw depends only on this identity, never on
/// where in a session's shared draw order the measurement happened to fall
/// (ADR-003).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamPath {
    pub seed: u64,
    pub path: Vec<u64>,
}

impl StreamPath {
    pub fn new(seed: u64, path: &[u64]) -> StreamPath {
        StreamPath { seed, path: path.to_vec() }
    }

    /// Extend the path by one component (a child stream).
    pub fn child(&self, component: u64) -> StreamPath {
        let mut path = self.path.clone();
        path.push(component);
        StreamPath { seed: self.seed, path }
    }

    /// The derived RNG this identity names.
    pub fn rng(&self) -> Pcg32 {
        Pcg32::derive(self.seed, &self.path)
    }
}

/// Hands out one derived stream per measurement, in execution order: the
/// k-th measurement of a session draws from `base.child(k)` regardless of
/// which thread or process executes it. Sessions own one of these next to
/// their behavioural RNG; truncating a session truncates the sequence, so
/// the prefix property of ADR-002 is preserved.
#[derive(Debug, Clone)]
pub struct MeasureSeq {
    base: StreamPath,
    next: u64,
}

impl MeasureSeq {
    pub fn new(base: StreamPath) -> MeasureSeq {
        MeasureSeq { base, next: 0 }
    }

    /// Stream identity for the next measurement.
    pub fn next_stream(&mut self) -> StreamPath {
        let sp = self.base.child(self.next);
        self.next += 1;
        sp
    }

    /// Measurements handed out so far.
    pub fn issued(&self) -> u64 {
        self.next
    }
}

/// PCG32 (XSH-RR variant) — small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm) ^ stream;
        let mut rng = Pcg32 { state: 0, inc: (s1 << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(s0);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream from an experiment seed and a
    /// hierarchical path, e.g. `Pcg32::derive(seed, &[stream::MANTIS,
    /// variant_id, pidx])`. Each component is mixed through SplitMix64 and
    /// folded with a rotate-multiply, so distinct paths — including
    /// permutations, prefixes, and adjacent small integers — yield
    /// decorrelated streams. This replaces ad-hoc `(pidx << 8) | tag`
    /// stream arithmetic, which collides as soon as two call sites shift
    /// by different amounts; parallel (variant, problem, seed) tasks each
    /// derive their own stream and can never observe another task's draws.
    pub fn derive(seed: u64, path: &[u64]) -> Pcg32 {
        let mut acc = seed ^ 0x6A09_E667_F3BC_C908; // √2 frac: decorrelate raw seeds
        let mut h = splitmix64(&mut acc);
        for &c in path {
            let mut t = c;
            h ^= splitmix64(&mut t);
            h = h.rotate_left(27).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        let mut s = h;
        let state_seed = splitmix64(&mut s);
        let inc = splitmix64(&mut s);
        Pcg32::new(state_seed, inc)
    }

    /// Derive a child RNG for a named sub-component (hash of the label).
    pub fn fork(&mut self, label: &str) -> Pcg32 {
        let h = crate::util::fnv64(label.as_bytes());
        Pcg32::new(self.next_u64() ^ h, h | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine at our scales.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal multiplicative noise with the given sigma (mean ≈ 1).
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Weighted pick: weights need not be normalized.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(43, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn derive_is_deterministic() {
        let mut a = Pcg32::derive(42, &[stream::MANTIS, 3, 7]);
        let mut b = Pcg32::derive(42, &[stream::MANTIS, 3, 7]);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_separates_paths() {
        // permutations, prefixes, neighbouring components, and different
        // roots must all yield distinct streams
        let paths: &[&[u64]] = &[
            &[stream::FLAT_CONTROLLER, 1, 2],
            &[stream::FLAT_CONTROLLER, 2, 1],
            &[stream::FLAT_CONTROLLER, 1],
            &[stream::FLAT_CONTROLLER, 1, 2, 0],
            &[stream::FLAT_CONTROLLER, 1, 3],
            &[stream::MANTIS, 1, 2],
            &[stream::INTEGRITY_REVIEW, 1, 2],
        ];
        let mut firsts = std::collections::HashSet::new();
        for p in paths {
            let mut r = Pcg32::derive(99, p);
            assert!(
                firsts.insert((r.next_u64(), r.next_u64())),
                "stream collision for path {p:?}"
            );
        }
    }

    #[test]
    fn derive_separates_seeds() {
        let mut a = Pcg32::derive(1, &[stream::MANTIS, 0]);
        let mut b = Pcg32::derive(2, &[stream::MANTIS, 0]);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::new(7, 3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(9, 5);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_mean_near_one() {
        let mut r = Pcg32::new(11, 1);
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_noise(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Pcg32::new(13, 1);
        let mut counts = [0usize; 3];
        for _ in 0..9000 {
            counts[r.weighted_choice(&[1.0, 2.0, 6.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }

    #[test]
    fn measure_seq_is_order_and_process_independent() {
        // the k-th measurement stream depends only on (seed, base, k)
        let base = StreamPath::new(7, &[stream::MEASURE, stream::FLAT_CONTROLLER, 3]);
        let mut a = MeasureSeq::new(base.clone());
        let mut b = MeasureSeq::new(base.clone());
        let s0 = a.next_stream();
        let s1 = a.next_stream();
        assert_eq!(s0, b.next_stream());
        assert_eq!(s1, b.next_stream());
        assert_ne!(s0, s1, "consecutive measurements use distinct streams");
        assert_eq!(s1, base.child(1));
        assert_eq!(a.issued(), 2);
        // the named RNG is exactly the derive of the path
        let mut x = s0.rng();
        let mut y = Pcg32::derive(7, &[stream::MEASURE, stream::FLAT_CONTROLLER, 3, 0]);
        assert_eq!(x.next_u64(), y.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::new(17, 1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
