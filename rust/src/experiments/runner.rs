//! Shared experiment plumbing: environment construction and variant
//! execution over the full 59-problem suite.

use crate::agent::controller::{run_problem, ControllerKind, Env, VariantSpec};
use crate::agent::{ModelTier, RunLog};
use crate::kernelbench::{suite, Problem};
use crate::mantis::{run_orchestrated, CrossMemory, MantisConfig};
use crate::perfmodel::{CompiledCostModel, PerfModel};
use crate::sol::{analyze, SolAnalysis, GpuSpec, H100_SXM};

/// Owns the evaluation substrate: perf model, problems, SOL analyses, the
/// per-problem compiled cost models (lowered once here — ADR-006), and
/// (optionally) a measurement-oracle override that every [`Env`] handed
/// out by [`Bench::env`] carries (record/replay, ADR-004).
pub struct Bench {
    pub model: PerfModel,
    pub problems: Vec<Problem>,
    pub sols: Vec<SolAnalysis>,
    /// Every (problem, arch) pair of this bench, lowered exactly once.
    pub compiled: CompiledCostModel,
    oracle: Option<Box<crate::eval::DynEvaluator>>,
}

impl Bench {
    pub fn new() -> Self {
        Self::on(H100_SXM.clone())
    }

    pub fn on(gpu: GpuSpec) -> Self {
        let problems = suite();
        let sols = problems.iter().map(|p| analyze(p, &gpu)).collect();
        let model = PerfModel::new(gpu);
        let compiled = CompiledCostModel::compile(&model, &problems);
        Bench { model, problems, sols, compiled, oracle: None }
    }

    /// Install a measurement-oracle override: every subsequent `env()` /
    /// `evaluator()` routes all evaluation through it (ADR-004).
    pub fn set_oracle(&mut self, oracle: Box<crate::eval::DynEvaluator>) {
        self.oracle = Some(oracle);
    }

    /// Remove the override, restoring the analytic fast path.
    pub fn clear_oracle(&mut self) {
        self.oracle = None;
    }

    pub fn env(&self) -> Env<'_> {
        Env::new(&self.model, &self.problems, &self.sols, &self.compiled)
            .with_oracle(self.oracle.as_deref())
    }

    /// The measurement oracle over this bench (ADR-003/ADR-004).
    pub fn evaluator(&self) -> crate::eval::Oracle<'_> {
        self.env().evaluator()
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Run one variant over the whole suite. Orchestrated variants thread
/// cross-problem memory in problem order (paper: summaries are "persisted
/// as cross-problem memory so that later problems can retrieve" them).
pub fn run_variant(
    bench: &Bench,
    spec: &VariantSpec,
    seed: u64,
    mantis_cfg: Option<&MantisConfig>,
) -> RunLog {
    let env = bench.env();
    let tier = spec.tier.params();
    let runs = match spec.controller {
        ControllerKind::OrchestratedSol => {
            let default_cfg = MantisConfig::default();
            let cfg = mantis_cfg.unwrap_or(&default_cfg);
            let mut memory = CrossMemory::default();
            (0..bench.problems.len())
                .map(|pidx| {
                    if cfg.cross_memory {
                        run_orchestrated(&env, spec, pidx, seed, Some((cfg, &mut memory)))
                    } else {
                        let mut fresh = CrossMemory::default();
                        run_orchestrated(&env, spec, pidx, seed, Some((cfg, &mut fresh)))
                    }
                })
                .collect()
        }
        _ => (0..bench.problems.len())
            .map(|pidx| run_problem(&env, spec, pidx, seed))
            .collect(),
    };
    RunLog {
        variant: spec.label(),
        tier_name: spec.tier.name().to_string(),
        price_per_mtok: tier.price_per_mtok,
        runs,
    }
}

/// The four main variants per tier (Figure 3): MI, µC+MI, SOL-guided, and
/// µC+SOL-guided. Per §6.1, the SOL-guided result uses whichever steering
/// form (in-prompt vs orchestrated) yields the higher geomean; we run the
/// orchestrated form for Mini/Mid and in-prompt for Max-with-DSL,
/// matching the paper's §6.1.1 finding.
pub fn main_variants(tier: ModelTier) -> Vec<VariantSpec> {
    let sol_controller = |dsl: bool| match (tier, dsl) {
        (ModelTier::Max, true) => ControllerKind::InPromptSol,
        _ => ControllerKind::OrchestratedSol,
    };
    vec![
        VariantSpec::new(ControllerKind::Mi, false, tier),
        VariantSpec::new(ControllerKind::Mi, true, tier),
        VariantSpec::new(sol_controller(false), false, tier),
        VariantSpec::new(sol_controller(true), true, tier),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrity::IntegrityPipeline;
    use crate::metrics;

    #[test]
    fn run_variant_covers_suite() {
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini);
        let log = run_variant(&bench, &spec, 1, None);
        assert_eq!(log.runs.len(), 59);
        assert!(log.total_tokens() > 0);
    }

    /// Headline shape check (Figure 3, mini row): MI regresses vs PyTorch;
    /// µCUTLASS turns it into a speedup; adding SOL steering improves it
    /// further.
    #[test]
    fn mini_headline_ordering() {
        let bench = Bench::new();
        let pipeline = IntegrityPipeline::default();
        let geo = |spec: &VariantSpec| {
            let log = run_variant(&bench, spec, 12345, None);
            let speedups: Vec<f64> = log
                .runs
                .iter()
                .map(|r| pipeline.filtered_speedup(r, 99).unwrap_or(1.0))
                .collect();
            metrics::geomean_speedup(&speedups)
        };
        let mi = geo(&VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini));
        let dsl = geo(&VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini));
        let dsl_sol = geo(&VariantSpec::new(
            ControllerKind::OrchestratedSol,
            true,
            ModelTier::Mini,
        ));
        assert!(mi < 1.0, "mini MI should regress vs PyTorch, got {mi:.2}");
        assert!(dsl > 1.0, "mini µCUTLASS should beat PyTorch, got {dsl:.2}");
        assert!(dsl_sol > dsl * 0.95, "SOL steering should not hurt much: {dsl_sol:.2} vs {dsl:.2}");
    }
}
