//! One driver per paper figure/table (§6). Each regenerates the figure's
//! rows/series from fresh seeded runs, renders a text report, and writes
//! CSVs into the output directory.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::agent::controller::{ControllerKind, VariantSpec};
use crate::agent::{GamingType, ModelTier, RunLog, SolutionKind};
use crate::integrity::{outcome_counts, IntegrityPipeline, ReviewLabel};
use crate::mantis::MantisConfig;
use crate::metrics;
use crate::report::{ascii_plot, table, write_csv};
use crate::scheduler::{self, Policy};
use crate::util::stats;

use super::archive::{generate_archive, review_archive, EvoParams};
use super::runner::{main_variants, Bench};

/// Shared experiment context with a run-log cache (several figures reuse
/// the same variant runs).
pub struct ExpCtx {
    pub bench: Bench,
    pub outdir: PathBuf,
    pub seed: u64,
    pub review_seed: u64,
    pub pipeline: IntegrityPipeline,
    /// Worker threads for suite evaluation (1 = serial reference path;
    /// results are bit-identical either way, see `exec`).
    pub jobs: usize,
    cache: BTreeMap<String, RunLog>,
}

impl ExpCtx {
    pub fn new(outdir: impl Into<PathBuf>, seed: u64) -> Self {
        ExpCtx {
            bench: Bench::new(),
            outdir: outdir.into(),
            seed,
            review_seed: seed ^ 0xBEEF,
            pipeline: IntegrityPipeline::default(),
            jobs: 1,
            cache: BTreeMap::new(),
        }
    }

    /// Select the worker count for suite evaluation (0 = all cores).
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = crate::exec::effective_jobs(jobs);
        self
    }

    /// Install a measurement-oracle override (record/replay, ADR-004):
    /// every figure's suite runs — and batched evaluations like fig14's
    /// SOL curve — route through it instead of the analytic backend.
    pub fn with_oracle(mut self, oracle: Box<crate::eval::DynEvaluator>) -> Self {
        self.bench.set_oracle(oracle);
        self
    }

    fn key(spec: &VariantSpec, seed: u64, cfg: Option<&MantisConfig>) -> String {
        format!("{}|{}|{:?}|{}|{}", spec.label(), seed, cfg.map(|c| format!("{c:?}")), spec.guardrails, spec.online_integrity)
    }

    /// Run (or fetch cached) one variant over the suite.
    pub fn log(&mut self, spec: &VariantSpec, cfg: Option<&MantisConfig>) -> &RunLog {
        self.log_seeded(spec, self.seed, cfg)
    }

    pub fn log_seeded(&mut self, spec: &VariantSpec, seed: u64, cfg: Option<&MantisConfig>) -> &RunLog {
        let key = Self::key(spec, seed, cfg);
        if !self.cache.contains_key(&key) {
            let log = crate::exec::run_variant_jobs(&self.bench, spec, seed, cfg, self.jobs);
            self.cache.insert(key.clone(), log);
        }
        self.cache.get(&key).unwrap()
    }

    /// Integrity-filtered per-problem speedups (1.0 fallback).
    pub fn filtered_speedups(&self, log: &RunLog) -> Vec<f64> {
        log.runs
            .iter()
            .map(|r| self.pipeline.filtered_speedup(r, self.review_seed).unwrap_or(1.0))
            .collect()
    }

    fn save(&self, name: &str, text: &str) {
        let p = self.outdir.join(name);
        if let Some(parent) = p.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let _ = std::fs::write(&p, text);
    }
}

fn sol_label(tier: ModelTier, dsl: bool) -> ControllerKind {
    match (tier, dsl) {
        (ModelTier::Max, true) => ControllerKind::InPromptSol,
        _ => ControllerKind::OrchestratedSol,
    }
}

// ===========================================================================
// Figure 3: geomean speedups, 4 main variants × 3 tiers
// ===========================================================================
pub fn fig3(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for tier in ModelTier::ALL {
        for spec in main_variants(tier) {
            let log = ctx.log(&spec, None).clone();
            let sp = ctx.filtered_speedups(&log);
            let geo = metrics::geomean_speedup(&sp);
            let med = metrics::median_speedup(&sp);
            rows.push(vec![
                spec.label(),
                format!("{geo:.2}x"),
                format!("{med:.2}x"),
                format!("{}", sp.iter().filter(|&&s| s > 1.0).count()),
                format!("{}", sp.iter().filter(|&&s| s >= 2.0).count()),
            ]);
            csv.push(vec![spec.label(), format!("{geo}"), format!("{med}")]);
        }
    }
    let t = table(&["variant", "geomean", "median", ">1x (of 59)", ">=2x"], &rows);
    let _ = write_csv(&ctx.outdir.join("fig3.csv"), &["variant", "geomean", "median"], &csv);
    let out = format!("== Figure 3: geomean speedup over PyTorch (integrity-filtered) ==\n{t}");
    ctx.save("fig3.txt", &out);
    out
}

// ===========================================================================
// Figure 4: Fast-p + Attempt-Fast-p(2) per tier
// ===========================================================================
pub fn fig4(ctx: &mut ExpCtx) -> String {
    let grid = metrics::default_grid();
    let mut out = String::from("== Figure 4: Fast-p and Attempt-Fast-p(2) per tier ==\n");
    for tier in ModelTier::ALL {
        let mut series_data: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
        for spec in main_variants(tier) {
            let log = ctx.log(&spec, None).clone();
            let sp = ctx.filtered_speedups(&log);
            let fp = metrics::fast_p(&sp, &grid);
            // best-so-far progression for attempt-fast-p (unfiltered online view)
            let prog: Vec<Vec<f64>> = log
                .runs
                .iter()
                .map(|r| {
                    (1..=r.attempts.len())
                        .map(|n| r.best_time_after(n).map(|t| r.t_ref_ms / t).unwrap_or(0.0))
                        .collect()
                })
                .collect();
            let afp = metrics::attempt_fast_p(&prog, 2.0);
            series_data.push((spec.label(), fp.pct, afp));
        }
        let refs: Vec<(&str, &[f64])> =
            series_data.iter().map(|(n, fp, _)| (n.as_str(), fp.as_slice())).collect();
        out.push_str(&ascii_plot(
            &format!("--- Fast-p, {} ---", tier.name()),
            &grid,
            &refs,
            72,
            16,
            true,
        ));
        let attempts_x: Vec<f64> = (1..=40).map(|a| a as f64).collect();
        let refs2: Vec<(&str, &[f64])> =
            series_data.iter().map(|(n, _, a)| (n.as_str(), a.as_slice())).collect();
        out.push_str(&ascii_plot(
            &format!("--- Attempt-Fast-p(2), {} ---", tier.name()),
            &attempts_x,
            &refs2,
            72,
            12,
            false,
        ));
        // CSV per tier
        let mut rows = Vec::new();
        for (i, &r) in grid.iter().enumerate() {
            let mut row = vec![format!("{r}")];
            for (_, fp, _) in &series_data {
                row.push(format!("{}", fp[i]));
            }
            rows.push(row);
        }
        let headers: Vec<String> =
            std::iter::once("r".to_string()).chain(series_data.iter().map(|(n, _, _)| n.clone())).collect();
        let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let _ = write_csv(&ctx.outdir.join(format!("fig4_fastp_{}.csv", tier.name())), &hrefs, &rows);
    }
    ctx.save("fig4.txt", &out);
    out
}

// ===========================================================================
// Figure 5: orchestrated vs in-prompt signed areas
// ===========================================================================
pub fn fig5(ctx: &mut ExpCtx) -> String {
    let grid = metrics::default_grid();
    let mut rows = Vec::new();
    for tier in ModelTier::ALL {
        for dsl in [false, true] {
            let orch = ctx
                .log(&VariantSpec::new(ControllerKind::OrchestratedSol, dsl, tier), None)
                .clone();
            let inp = ctx
                .log(&VariantSpec::new(ControllerKind::InPromptSol, dsl, tier), None)
                .clone();
            let fo = metrics::fast_p(&ctx.filtered_speedups(&orch), &grid);
            let fi = metrics::fast_p(&ctx.filtered_speedups(&inp), &grid);
            let area = metrics::signed_area(&fo, &fi);
            rows.push(vec![
                tier.name().to_string(),
                if dsl { "+µCUTLASS".into() } else { "w/o µCUTLASS".into() },
                format!("{area:+.2}"),
                if area > 0.0 { "orchestrated".into() } else { "in-prompt".into() },
            ]);
        }
    }
    let t = table(&["tier", "dsl", "signed area (orch - in-prompt)", "winner"], &rows);
    let out = format!(
        "== Figure 5: orchestrated vs in-prompt SOL steering ==\n\
         (positive signed area: orchestrated Fast-p curve lies higher)\n{t}"
    );
    let _ = write_csv(
        &ctx.outdir.join("fig5.csv"),
        &["tier", "dsl", "signed_area"],
        &rows.iter().map(|r| r[..3].to_vec()).collect::<Vec<_>>(),
    );
    ctx.save("fig5.txt", &out);
    out
}

// ===========================================================================
// Figure 6: MANTIS component ablations
// ===========================================================================
pub fn fig6(ctx: &mut ExpCtx) -> String {
    let ablations = ["MANTIS", "MNTIS", "MANIS", "MANTI", "MANTIS-noXmem"];
    // the configurations where orchestration matters (paper §6.1.2)
    let settings = [
        (ModelTier::Max, false, "gpt-5.2 w/o µCUTLASS"),
        (ModelTier::Mini, false, "gpt-5-mini w/o µCUTLASS"),
        (ModelTier::Mini, true, "gpt-5-mini + µCUTLASS"),
    ];
    let mut out = String::from("== Figure 6: MANTIS component ablations ==\n");
    let mut csv = Vec::new();
    for (tier, dsl, label) in settings {
        let mut rows = Vec::new();
        for name in ablations {
            let cfg = MantisConfig::ablation(name);
            let spec = VariantSpec::new(ControllerKind::OrchestratedSol, dsl, tier);
            let log = ctx.log(&spec, Some(&cfg)).clone();
            let sp = ctx.filtered_speedups(&log);
            let geo = metrics::geomean_speedup(&sp);
            rows.push(vec![name.to_string(), format!("{geo:.2}x")]);
            csv.push(vec![label.to_string(), name.to_string(), format!("{geo}")]);
        }
        out.push_str(&format!("--- {label} ---\n{}", table(&["config", "geomean"], &rows)));
    }
    let _ = write_csv(&ctx.outdir.join("fig6.csv"), &["setting", "ablation", "geomean"], &csv);
    ctx.save("fig6.txt", &out);
    out
}

// ===========================================================================
// Figure 7: independent scheduler parameter sweeps (ε / w)
// ===========================================================================
pub fn fig7(ctx: &mut ExpCtx) -> String {
    // GPT-5.2 µCUTLASS + SOL-guided, as in the paper
    let spec = VariantSpec::new(sol_label(ModelTier::Max, true), true, ModelTier::Max);
    let log = ctx.log(&spec, None).clone();
    // one ReplayCache build shared by every policy of both sub-sweeps
    // (ADR-005): each attempt is reviewed exactly once
    let cache = scheduler::ReplayCache::build(&log, &ctx.pipeline, ctx.review_seed);
    let mut out = String::from("== Figure 7: scheduler parameter sweeps (GPT-5.2 µCUTLASS+SOL) ==\n");
    let mut rows = Vec::new();
    out.push_str("--- (a) SOL-headroom threshold ε (w=0) ---\n");
    for &e in &scheduler::epsilon_grid() {
        let r = cache.replay(&Policy { epsilon: e, window: 0 });
        rows.push(vec![
            format!("ε={}%", (e * 100.0) as u64),
            format!("{:.0}%", r.token_savings() * 100.0),
            format!("{:.0}%", r.attempt_savings(40) * 100.0),
            format!("{:.0}%", r.geomean_retention() * 100.0),
            format!("{:.0}%", r.median_retention() * 100.0),
        ]);
    }
    out.push_str(&table(&["policy", "token savings", "attempt savings", "geo retention", "median retention"], &rows));
    let mut rows2 = Vec::new();
    out.push_str("--- (b) no-progress window w (ε=100%) ---\n");
    for &w in &scheduler::window_grid()[1..] {
        let r = cache.replay(&Policy { epsilon: 1.0, window: w });
        rows2.push(vec![
            format!("w={w}"),
            format!("{:.0}%", r.token_savings() * 100.0),
            format!("{:.0}%", r.attempt_savings(40) * 100.0),
            format!("{:.0}%", r.geomean_retention() * 100.0),
            format!("{:.0}%", r.median_retention() * 100.0),
        ]);
    }
    out.push_str(&table(&["policy", "token savings", "attempt savings", "geo retention", "median retention"], &rows2));
    let _ = write_csv(
        &ctx.outdir.join("fig7.csv"),
        &["policy", "token_savings", "attempt_savings", "geo_retention", "median_retention"],
        &rows.iter().chain(rows2.iter()).cloned().collect::<Vec<_>>(),
    );
    ctx.save("fig7.txt", &out);
    out
}

/// The nine variants of the Pareto study (three per tier: µC+SOL, µC+MI,
/// SOL-only) — shared with `repro sweep`, which replays the same fig8/fig9
/// policy grid from one session pass per variant.
pub fn pareto_variants() -> Vec<VariantSpec> {
    let mut v = Vec::new();
    for tier in ModelTier::ALL {
        v.push(VariantSpec::new(sol_label(tier, true), true, tier));
        v.push(VariantSpec::new(ControllerKind::Mi, true, tier));
        v.push(VariantSpec::new(sol_label(tier, false), false, tier));
    }
    v
}

// ===========================================================================
// Figure 8: Pareto frontiers, normalized dollar cost vs geomean
// ===========================================================================
pub fn fig8(ctx: &mut ExpCtx) -> String {
    let mut out = String::from("== Figure 8: scheduler-policy Pareto frontiers ==\n");
    let mut all_points: Vec<(String, f64, f64)> = Vec::new();
    // normalization: most expensive fixed run
    let mut max_cost = 0.0f64;
    let mut logs = Vec::new();
    for spec in pareto_variants() {
        let log = ctx.log(&spec, None).clone();
        let cost = log.dollar_cost();
        max_cost = max_cost.max(cost);
        logs.push((spec, log));
    }
    let mut csv = Vec::new();
    for (spec, log) in &logs {
        // single-pass sweep engine (ADR-005): one ReplayCache per variant
        // serves the fixed reference and all 72 grid policies
        let sweep = scheduler::PolicySweep::over(log, &ctx.pipeline, ctx.review_seed);
        let price = log.price_per_mtok;
        let fixed_cost = log.dollar_cost() / max_cost;
        all_points
            .push((format!("{} [fixed]", spec.label()), fixed_cost, sweep.fixed.geomean_fixed));
        let pts: Vec<(f64, f64)> = sweep
            .results
            .iter()
            .map(|r| (r.tokens_used as f64 / 1e6 * price / max_cost, r.geomean))
            .collect();
        let front = scheduler::pareto_front(&pts);
        out.push_str(&format!(
            "--- {} --- fixed: (cost {:.2}, geo {:.2}x); frontier ({} of {} policies):\n",
            spec.label(),
            fixed_cost,
            sweep.fixed.geomean_fixed,
            front.len(),
            pts.len()
        ));
        for &i in &front {
            out.push_str(&format!(
                "    {}  -> (cost {:.2}, geo {:.2}x)\n",
                sweep.results[i].policy.label(),
                pts[i].0,
                pts[i].1
            ));
            csv.push(vec![
                spec.label(),
                sweep.results[i].policy.label(),
                format!("{}", pts[i].0),
                format!("{}", pts[i].1),
            ]);
        }
    }
    let _ = write_csv(&ctx.outdir.join("fig8.csv"), &["variant", "policy", "norm_cost", "geomean"], &csv);
    ctx.save("fig8.txt", &out);
    out
}

// ===========================================================================
// Figure 9: best scheduler policy per variant (efficiency gain)
// ===========================================================================
pub fn fig9(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for spec in pareto_variants() {
        let log = ctx.log(&spec, None).clone();
        let sweep = scheduler::PolicySweep::over(&log, &ctx.pipeline, ctx.review_seed);
        match sweep.best(0.95) {
            Some(best) => rows.push(vec![
                spec.label(),
                best.policy.label(),
                format!("{:.2}x", best.efficiency_gain()),
                format!("{:.0}%", best.token_savings() * 100.0),
                format!("{:.0}%", best.geomean_retention() * 100.0),
            ]),
            None => rows.push(vec![spec.label(), "-".into(), "-".into(), "-".into(), "-".into()]),
        }
    }
    let t = table(&["variant", "best policy", "efficiency gain", "token savings", "geo retention"], &rows);
    let out = format!("== Figure 9: best scheduler policy per variant (≥95% geomean retention) ==\n{t}");
    let _ = write_csv(
        &ctx.outdir.join("fig9.csv"),
        &["variant", "policy", "gain", "savings", "retention"],
        &rows,
    );
    ctx.save("fig9.txt", &out);
    out
}

// ===========================================================================
// Figure 10: review outcome composition
// ===========================================================================
pub fn fig10(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for tier in ModelTier::ALL {
        for spec in main_variants(tier) {
            let log = ctx.log(&spec, None).clone();
            let counts = outcome_counts(&ctx.pipeline, &log.runs, ctx.review_seed);
            rows.push(vec![
                spec.label(),
                counts["no_issues"].to_string(),
                counts["minor_issues"].to_string(),
                counts["sol_ceiling"].to_string(),
                counts["pytorch_only"].to_string(),
                counts["original_gaming"].to_string(),
                counts["inherited_gaming"].to_string(),
            ]);
        }
    }
    let t = table(
        &["variant", "no issues", "minor", "SOL ceiling", "pytorch-only", "orig gaming", "inherited"],
        &rows,
    );
    let out = format!("== Figure 10: review outcome composition (counts over correct attempts) ==\n{t}");
    let _ = write_csv(
        &ctx.outdir.join("fig10.csv"),
        &["variant", "no_issues", "minor", "sol_ceiling", "pytorch_only", "orig_gaming", "inherited"],
        &rows,
    );
    ctx.save("fig10.txt", &out);
    out
}

// ===========================================================================
// Figure 11: LGD category breakdown (gaming + minor subcategories)
// ===========================================================================
pub fn fig11(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for tier in ModelTier::ALL {
        for spec in main_variants(tier) {
            let log = ctx.log(&spec, None).clone();
            let mut gaming: BTreeMap<&'static str, usize> = BTreeMap::new();
            let mut minor: BTreeMap<&'static str, usize> = BTreeMap::new();
            for run in &log.runs {
                let labels = ctx.pipeline.review_run(run, ctx.review_seed);
                for (a, l) in run.attempts.iter().zip(&labels) {
                    match l {
                        ReviewLabel::OriginalGaming | ReviewLabel::InheritedGaming => {
                            if let SolutionKind::Gaming(g) = &a.kind {
                                *gaming.entry(g.name()).or_default() += 1;
                            }
                        }
                        ReviewLabel::MinorIssues => {
                            if let Some(m) = a.minor_issue {
                                *minor.entry(m.name()).or_default() += 1;
                            }
                        }
                        _ => {}
                    }
                }
            }
            let g = |k: GamingType| gaming.get(k.name()).copied().unwrap_or(0).to_string();
            rows.push(vec![
                spec.label(),
                g(GamingType::BenchmarkInputExploitation),
                g(GamingType::ConstantOutput),
                g(GamingType::SkippedComputation),
                g(GamingType::FakeTranspose),
                g(GamingType::IncompleteComputation),
                minor.values().sum::<usize>().to_string(),
            ]);
        }
    }
    let t = table(
        &["variant", "bench-input", "const-out", "skipped", "fake-transpose", "incomplete", "minor (all)"],
        &rows,
    );
    let out = format!("== Figure 11: LGD category breakdown ==\n{t}");
    let _ = write_csv(
        &ctx.outdir.join("fig11.csv"),
        &["variant", "bench_input", "const_out", "skipped", "fake_transpose", "incomplete", "minor"],
        &rows,
    );
    ctx.save("fig11.txt", &out);
    out
}

// ===========================================================================
// Figure 12: speedup inflation without integrity filtering
// ===========================================================================
pub fn fig12(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for tier in ModelTier::ALL {
        for spec in main_variants(tier) {
            let log = ctx.log(&spec, None).clone();
            let geo = |allow: &[ReviewLabel]| {
                let sp: Vec<f64> = log
                    .runs
                    .iter()
                    .map(|r| ctx.pipeline.speedup_allowing(r, ctx.review_seed, allow).unwrap_or(1.0))
                    .collect();
                metrics::geomean_speedup(&sp)
            };
            let filtered = geo(&[]);
            let plus_pt = geo(&[ReviewLabel::PyTorchOnly]);
            let plus_gaming = geo(&[
                ReviewLabel::PyTorchOnly,
                ReviewLabel::OriginalGaming,
                ReviewLabel::InheritedGaming,
            ]);
            let unfiltered = geo(&ReviewLabel::ALL);
            rows.push(vec![
                spec.label(),
                format!("{filtered:.2}x"),
                format!("{plus_pt:.2}x"),
                format!("{plus_gaming:.2}x"),
                format!("{unfiltered:.2}x"),
                format!("{:.2}x", unfiltered / filtered.max(1e-9)),
            ]);
        }
    }
    let t = table(
        &["variant", "filtered", "+pytorch-only", "+gaming", "unfiltered", "inflation"],
        &rows,
    );
    let out = format!("== Figure 12: speedup inflation without the integrity pipeline ==\n{t}");
    let _ = write_csv(
        &ctx.outdir.join("fig12.csv"),
        &["variant", "filtered", "plus_pytorch", "plus_gaming", "unfiltered", "inflation"],
        &rows,
    );
    ctx.save("fig12.txt", &out);
    out
}

// ===========================================================================
// Figure 13: run-to-run variation (CV across nearby configurations)
// ===========================================================================
pub fn fig13(ctx: &mut ExpCtx) -> String {
    let ablations = ["MANTIS", "MNTIS", "MANIS", "MANTI", "MANTIS-noXmem"];
    let mut rows = Vec::new();
    let mut groups: Vec<(String, Vec<f64>)> = Vec::new();
    for (tier, dsl) in [
        (ModelTier::Max, false),
        (ModelTier::Max, true),
        (ModelTier::Mini, false),
        (ModelTier::Mini, true),
    ] {
        let mut geos = Vec::new();
        let n_abl = if tier == ModelTier::Max && dsl { 4 } else { 5 };
        for name in ablations.iter().take(n_abl) {
            let cfg = MantisConfig::ablation(name);
            let spec = VariantSpec::new(ControllerKind::OrchestratedSol, dsl, tier);
            let log = ctx.log(&spec, Some(&cfg)).clone();
            geos.push(metrics::geomean_speedup(&ctx.filtered_speedups(&log)));
        }
        if tier == ModelTier::Mini {
            // independent repeat with the guardrail prompt (§6.4)
            let mut spec = VariantSpec::new(ControllerKind::OrchestratedSol, dsl, tier);
            spec.guardrails = true;
            let log = ctx.log_seeded(&spec, ctx.seed + 777, None).clone();
            geos.push(metrics::geomean_speedup(&ctx.filtered_speedups(&log)));
        }
        let label = format!(
            "{} {}",
            tier.name(),
            if dsl { "+µCUTLASS" } else { "w/o µCUTLASS" }
        );
        rows.push(vec![
            label.clone(),
            format!("{}", geos.len()),
            format!("{:.2}", stats::mean(&geos)),
            format!("{:.2}-{:.2}", geos.iter().cloned().fold(f64::MAX, f64::min),
                    geos.iter().cloned().fold(f64::MIN, f64::max)),
            format!("{:.0}%", stats::cv(&geos) * 100.0),
        ]);
        groups.push((label, geos));
    }
    let t = table(&["group", "N", "mean geomean", "range", "CV"], &rows);
    let out = format!("== Figure 13: run-to-run variation across nearby configurations ==\n{t}");
    let _ = write_csv(&ctx.outdir.join("fig13.csv"), &["group", "n", "mean", "range", "cv"], &rows);
    ctx.save("fig13.txt", &out);
    out
}

// ===========================================================================
// Figure 14: comparison vs Sakana archive + FP16 SOL curve
// ===========================================================================
pub fn fig14(ctx: &mut ExpCtx) -> String {
    let grid = metrics::default_grid();
    let mut out = String::from("== Figure 14: µCUTLASS+SOL vs evolutionary archive ==\n");

    // our three tiers (µC + SOL)
    let mut series: Vec<(String, Vec<f64>, f64)> = Vec::new();
    let mut per_tier_speedups: Vec<Vec<f64>> = Vec::new();
    for tier in ModelTier::ALL {
        let spec = VariantSpec::new(sol_label(tier, true), true, tier);
        let log = ctx.log(&spec, None).clone();
        let sp = ctx.filtered_speedups(&log);
        let fp = metrics::fast_p(&sp, &grid);
        let geo = metrics::geomean_speedup(&sp);
        series.push((format!("µC+SOL [{}]", tier.name()), fp.pct, geo));
        per_tier_speedups.push(sp);
    }

    // archive with fallback review
    let env = ctx.bench.env();
    let params = EvoParams::default();
    let mut archive_sp = Vec::new();
    let mut accepted = 0;
    let mut missing = 0;
    let mut rejected_all = 0;
    for pidx in 0..ctx.bench.problems.len() {
        let archive = generate_archive(&env, pidx, &params, ctx.seed);
        if archive.is_empty() {
            missing += 1;
            archive_sp.push(0.0);
            continue;
        }
        let (speedup, _) = review_archive(&env, pidx, &archive, &ctx.pipeline, ctx.seed);
        if speedup > 0.0 {
            accepted += 1;
        } else {
            rejected_all += 1;
        }
        archive_sp.push(speedup);
    }
    let fp_archive = metrics::fast_p(&archive_sp, &grid);
    let geo_archive = metrics::geomean_speedup(
        &archive_sp.iter().map(|&s| if s > 0.0 { s } else { 1e-2 }).collect::<Vec<_>>(),
    );
    let geo_archive_accepted =
        metrics::geomean_speedup(&archive_sp.iter().copied().filter(|&s| s > 0.0).collect::<Vec<_>>());

    // FP16 SOL curve (theoretical limit): one batched SOL-gap evaluation
    // over the whole suite (ADR-003)
    let gap_reqs: Vec<crate::eval::EvalRequest> =
        (0..ctx.bench.problems.len()).map(crate::eval::EvalRequest::sol_gap).collect();
    let sol_sp: Vec<f64> = {
        use crate::eval::Evaluator as _;
        ctx.bench.evaluator().eval_batch(&gap_reqs).into_iter().map(|r| r.value).collect()
    };
    let fp_sol = metrics::fast_p(&sol_sp, &grid);
    let geo_sol = metrics::geomean_speedup(&sol_sp);

    // best-of-all-variants ensemble
    let mut best_sp = vec![0.0f64; ctx.bench.problems.len()];
    for tier_sp in &per_tier_speedups {
        for (i, &s) in tier_sp.iter().enumerate() {
            best_sp[i] = best_sp[i].max(s);
        }
    }
    for tier in ModelTier::ALL {
        let spec = VariantSpec::new(ControllerKind::Mi, true, tier);
        let log = ctx.log(&spec, None).clone();
        for (i, s) in ctx.filtered_speedups(&log).iter().enumerate() {
            best_sp[i] = best_sp[i].max(*s);
        }
    }
    let geo_best = metrics::geomean_speedup(&best_sp);

    out.push_str(&format!(
        "archive: {} accepted, {} missing, {} all-rejected; geomean (accepted) {:.2}x\n",
        accepted, missing, rejected_all, geo_archive_accepted
    ));
    out.push_str(&format!("best-of-all-variants geomean: {geo_best:.2}x\n"));
    out.push_str(&format!("FP16 SOL theoretical-limit geomean: {geo_sol:.2}x\n"));
    let mut plot_series: Vec<(&str, &[f64])> =
        series.iter().map(|(n, fp, _)| (n.as_str(), fp.as_slice())).collect();
    plot_series.push(("archive (evo)", &fp_archive.pct));
    plot_series.push(("FP16 SOL limit", &fp_sol.pct));
    out.push_str(&ascii_plot("--- Fast-p ---", &grid, &plot_series, 72, 16, true));
    for (n, _, geo) in &series {
        out.push_str(&format!("   {n}: geomean {geo:.2}x\n"));
    }
    let _ = write_csv(
        &ctx.outdir.join("fig14.csv"),
        &["series", "geomean"],
        &series
            .iter()
            .map(|(n, _, g)| vec![n.clone(), format!("{g}")])
            .chain(std::iter::once(vec!["archive".to_string(), format!("{geo_archive}")]))
            .chain(std::iter::once(vec!["fp16_sol".to_string(), format!("{geo_sol}")]))
            .chain(std::iter::once(vec!["best_of_all".to_string(), format!("{geo_best}")]))
            .collect::<Vec<_>>(),
    );
    ctx.save("fig14.txt", &out);
    out
}

// ===========================================================================
// Table 4: prompt-level integrity guardrails (GPT-5-mini, run 1 vs run 2)
// ===========================================================================
pub fn tab4(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for spec0 in main_variants(ModelTier::Mini) {
        let mut counts = Vec::new();
        for guard in [false, true] {
            let mut spec = spec0;
            spec.guardrails = guard;
            let log = ctx.log(&spec, None).clone();
            let c = outcome_counts(&ctx.pipeline, &log.runs, ctx.review_seed);
            counts.push((
                c["pytorch_only"],
                c["original_gaming"] + c["inherited_gaming"],
            ));
        }
        rows.push(vec![
            spec0.label(),
            counts[0].0.to_string(),
            counts[1].0.to_string(),
            counts[0].1.to_string(),
            counts[1].1.to_string(),
        ]);
    }
    let t = table(
        &["variant", "pytorch-only r1", "pytorch-only r2", "gaming r1", "gaming r2"],
        &rows,
    );
    let out = format!(
        "== Table 4: prompt-level guardrails (run 1 = plain, run 2 = anti-PyTorch/anti-gaming prompt) ==\n{t}"
    );
    let _ = write_csv(
        &ctx.outdir.join("tab4.csv"),
        &["variant", "pt_r1", "pt_r2", "gaming_r1", "gaming_r2"],
        &rows,
    );
    ctx.save("tab4.txt", &out);
    out
}

// ===========================================================================
// Table 2: experimental variants and default budgets
// ===========================================================================
pub fn tab2(ctx: &mut ExpCtx) -> String {
    let rows = vec![
        vec!["MI w/o µCUTLASS".into(), "×".into(), "—".into(), "40".into()],
        vec!["MI + µCUTLASS".into(), "✓".into(), "—".into(), "40".into()],
        vec!["In-prompt steering w/o µCUTLASS".into(), "×".into(), "In-Prompt".into(), "40".into()],
        vec!["In-prompt steering + µCUTLASS".into(), "✓".into(), "In-Prompt".into(), "40".into()],
        vec!["Orchestrated steering w/o µCUTLASS".into(), "×".into(), "Orchestrated".into(),
             "40 (5 x 2 x 4)".into()],
        vec!["Orchestrated steering + µCUTLASS".into(), "✓".into(), "Orchestrated".into(),
             "40 (5 x 2 x 4)".into()],
    ];
    let t = table(&["variant", "µCUTLASS", "SOL-guidance", "total attempts"], &rows);
    let out = format!(
        "== Table 2: experimental variants and matched per-problem budgets ==\n{t}\
         Orchestrated budgets: {} iterations x {} hypotheses x {} attempts (mantis::*).\n",
        crate::mantis::ITERATIONS,
        crate::mantis::HYPOTHESES_PER_ITER,
        crate::mantis::ATTEMPTS_PER_HYPOTHESIS,
    );
    ctx.save("tab2.txt", &out);
    out
}

// ===========================================================================
// Extension (paper §7 future work): online integrity feedback
// ===========================================================================
pub fn ext1_online_integrity(ctx: &mut ExpCtx) -> String {
    let mut rows = Vec::new();
    for tier in [ModelTier::Max, ModelTier::Mid] {
        for online in [false, true] {
            let mut spec = VariantSpec::new(ControllerKind::Mi, true, tier);
            if online {
                spec = spec.with_online_integrity();
            }
            let log = ctx.log(&spec, None).clone();
            let counts = outcome_counts(&ctx.pipeline, &log.runs, ctx.review_seed);
            let sp = ctx.filtered_speedups(&log);
            rows.push(vec![
                format!("{}{}", spec.label(), if online { " +online-integrity" } else { "" }),
                format!("{:.2}x", metrics::geomean_speedup(&sp)),
                (counts["original_gaming"] + counts["inherited_gaming"]).to_string(),
                counts["inherited_gaming"].to_string(),
                counts["sol_ceiling"].to_string(),
            ]);
        }
    }
    let t = table(
        &["variant", "filtered geomean", "gaming attempts", "inherited", "sol-ceiling"],
        &rows,
    );
    let out = format!(
        "== Extension 1: online integrity feedback (paper §7 future work) ==\n\
         In-loop SOL-ceiling + LGD review rejects exploits immediately, so agents\n\
         correct instead of inheriting them. Expect: gaming (esp. inherited) counts\n\
         collapse while filtered geomean is preserved or improves (attempts are no\n\
         longer wasted on exploits).\n{t}"
    );
    let _ = write_csv(
        &ctx.outdir.join("ext1.csv"),
        &["variant", "geomean", "gaming", "inherited", "sol_ceiling"],
        &rows,
    );
    ctx.save("ext1.txt", &out);
    out
}

// ===========================================================================
// Extension 2 (paper §6.1.2 future work): adaptive hybrid steering
// ===========================================================================
/// "A hybrid approach between in-prompt and orchestrated steering that
/// adaptively selects MANTIS components based on model capability and
/// available tooling": probe both steering forms on a small problem prefix,
/// commit to the winner for the remainder, and compare against both fixed
/// choices under the same total budget.
pub fn ext2_adaptive_hybrid(ctx: &mut ExpCtx) -> String {
    use crate::agent::controller::run_problem;
    const PROBE: usize = 6;
    let mut rows = Vec::new();
    for tier in ModelTier::ALL {
        for dsl in [true, false] {
            let orch = VariantSpec::new(ControllerKind::OrchestratedSol, dsl, tier);
            let inp = VariantSpec::new(ControllerKind::InPromptSol, dsl, tier);
            let log_o = ctx.log(&orch, None).clone();
            let log_i = ctx.log(&inp, None).clone();
            let sp_o = ctx.filtered_speedups(&log_o);
            let sp_i = ctx.filtered_speedups(&log_i);
            let g_o = metrics::geomean_speedup(&sp_o);
            let g_i = metrics::geomean_speedup(&sp_i);

            // adaptive: probe both forms on the first PROBE problems (half
            // budget each to keep the total matched), pick the winner, then
            // run the remaining problems with the winning form
            let env = ctx.bench.env();
            let mut probe_o = orch;
            probe_o.attempts = 20;
            let mut probe_i = inp;
            probe_i.attempts = 20;
            let mut adaptive_sp = Vec::with_capacity(59);
            let mut probe_go = Vec::new();
            let mut probe_gi = Vec::new();
            for pidx in 0..PROBE {
                let ro = run_problem(&env, &probe_o, pidx, ctx.seed);
                let ri = run_problem(&env, &probe_i, pidx, ctx.seed ^ 0x77);
                let so = ctx.pipeline.filtered_speedup(&ro, ctx.review_seed).unwrap_or(1.0);
                let si = ctx.pipeline.filtered_speedup(&ri, ctx.review_seed).unwrap_or(1.0);
                probe_go.push(so);
                probe_gi.push(si);
                adaptive_sp.push(so.max(si)); // best probe result counts
            }
            let orch_wins = metrics::geomean_speedup(&probe_go)
                >= metrics::geomean_speedup(&probe_gi);
            let winner = if orch_wins { &log_o } else { &log_i };
            for run in winner.runs.iter().skip(PROBE) {
                adaptive_sp
                    .push(ctx.pipeline.filtered_speedup(run, ctx.review_seed).unwrap_or(1.0));
            }
            let g_a = metrics::geomean_speedup(&adaptive_sp);
            rows.push(vec![
                format!("{} {}", tier.name(), if dsl { "+µCUTLASS" } else { "w/o µCUTLASS" }),
                format!("{g_o:.2}x"),
                format!("{g_i:.2}x"),
                format!("{g_a:.2}x"),
                if orch_wins { "orchestrated".into() } else { "in-prompt".into() },
                if g_a >= g_o.min(g_i) - 1e-9 { "yes".into() } else { "no".into() },
            ]);
        }
    }
    let t = table(
        &["setting", "orchestrated", "in-prompt", "adaptive", "probe pick", "≥ worse fixed"],
        &rows,
    );
    let out = format!(
        "== Extension 2: adaptive hybrid steering (paper §6.1.2 future work) ==\n\
         Probe both steering forms on {PROBE} problems (half budget each), commit\n\
         to the winner. The adaptive controller should track the better fixed\n\
         choice without knowing the tier/tooling a priori.\n{t}"
    );
    let _ = write_csv(
        &ctx.outdir.join("ext2.csv"),
        &["setting", "orch", "inprompt", "adaptive", "pick", "robust"],
        &rows,
    );
    ctx.save("ext2.txt", &out);
    out
}

/// Run every experiment and return the combined report.
pub fn run_all(ctx: &mut ExpCtx) -> String {
    let mut out = String::new();
    out.push_str(&fig3(ctx));
    out.push_str(&fig4(ctx));
    out.push_str(&fig5(ctx));
    out.push_str(&fig6(ctx));
    out.push_str(&fig7(ctx));
    out.push_str(&fig8(ctx));
    out.push_str(&fig9(ctx));
    out.push_str(&fig10(ctx));
    out.push_str(&fig11(ctx));
    out.push_str(&fig12(ctx));
    out.push_str(&fig13(ctx));
    out.push_str(&fig14(ctx));
    out.push_str(&tab4(ctx));
    out.push_str(&ext1_online_integrity(ctx));
    out.push_str(&ext2_adaptive_hybrid(ctx));
    ctx.save("all.txt", &out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_runs_and_reports_12_variants() {
        let dir = std::env::temp_dir().join("ucutlass_fig3_test");
        let mut ctx = ExpCtx::new(&dir, 42);
        let out = fig3(&mut ctx);
        assert!(out.contains("gpt-5-mini"));
        assert!(out.contains("gpt-5.2"));
        assert_eq!(out.matches("µCUTLASS + ").count() >= 6, true);
        assert!(dir.join("fig3.csv").exists());
    }
}
