//! Simulated Sakana-AI-style kernel archive (paper §5.9, §6.5).
//!
//! The paper compares against the only public large-scale CUDA kernel
//! archive (≈30k kernels, Claude 3.5 Sonnet, evolutionary search). That
//! archive is substituted by an evolutionary-search policy run under the
//! same harness: a population of raw-CUDA candidates evolved by mutation +
//! selection, with no DSL and no SOL guidance, ~100 candidates per problem.
//! The paper's fallback review loop (fastest correct kernel reviewed;
//! rejected ⇒ next fastest) is then applied verbatim.

use crate::agent::controller::Env;
use crate::agent::{AttemptOutcome, AttemptRecord, GamingType, SolutionKind};
use crate::eval::EvalRequest;
use crate::integrity::IntegrityPipeline;
use crate::perfmodel::CandidateConfig;
use crate::util::rng::{stream, MeasureSeq, Pcg32, StreamPath};

/// One archived kernel for a problem.
#[derive(Debug, Clone)]
pub struct ArchivedKernel {
    pub time_ms: f64,
    pub kind: SolutionKind,
    pub kernel_names: Vec<String>,
}

/// Evolutionary parameters of the simulated archive generator.
pub struct EvoParams {
    pub population: usize,
    pub generations: usize,
    pub raw_quality_median: f64,
    pub raw_quality_sigma: f64,
    pub correct_rate: f64,
    pub gaming_rate: f64,
    pub pytorch_only_rate: f64,
    /// Probability a problem has no correct kernel at all (archive gaps).
    pub missing_rate: f64,
}

impl Default for EvoParams {
    fn default() -> Self {
        // Claude-3.5-Sonnet-era evolutionary search: decent code quality,
        // no principled steering, modest gaming, some archive gaps.
        EvoParams {
            population: 10,
            generations: 10,
            raw_quality_median: 0.40,
            raw_quality_sigma: 0.45,
            correct_rate: 0.55,
            gaming_rate: 0.02,
            pytorch_only_rate: 0.06,
            missing_rate: 0.035,
        }
    }
}

/// Generate the archive for one problem: evolutionary search over raw
/// configs (mutation of the fittest individual per generation).
pub fn generate_archive(
    env: &Env,
    pidx: usize,
    params: &EvoParams,
    seed: u64,
) -> Vec<ArchivedKernel> {
    let mut rng = Pcg32::derive(seed, &[stream::ARCHIVE_GEN, pidx as u64]);
    let ev = env.evaluator();
    // One derived noise stream per evolved measurement (ADR-003).
    let mut measure = MeasureSeq::new(StreamPath::new(
        seed,
        &[stream::MEASURE, stream::ARCHIVE_GEN, pidx as u64],
    ));
    let problem = &env.problems[pidx];
    if rng.chance(params.missing_rate) {
        return vec![]; // no correct kernel in the archive for this problem
    }
    let mut kernels: Vec<ArchivedKernel> = Vec::new();
    let mut best: Option<CandidateConfig> = None;

    for _gen in 0..params.generations {
        for _ind in 0..params.population {
            // gaming / pytorch-only members of the archive
            if rng.chance(params.gaming_rate) {
                let ty = *rng.choice(&GamingType::ALL);
                let honest = best
                    .as_ref()
                    .map(|c| ev.value(&EvalRequest::candidate(pidx, c.clone())))
                    .unwrap_or_else(|| ev.value(&EvalRequest::baseline(pidx)));
                let t = match ty {
                    GamingType::ConstantOutput => 0.01,
                    _ => honest * 0.5,
                };
                kernels.push(ArchivedKernel {
                    time_ms: t,
                    kind: SolutionKind::Gaming(ty),
                    kernel_names: vec!["evolved_kernel".into()],
                });
                continue;
            }
            if rng.chance(params.pytorch_only_rate) {
                kernels.push(ArchivedKernel {
                    time_ms: ev.value(&EvalRequest::baseline(pidx)) * rng.range_f64(0.6, 0.95),
                    kind: SolutionKind::PyTorchOnly,
                    kernel_names: vec!["void at::native::elementwise [cublas]".into()],
                });
                continue;
            }
            if !rng.chance(params.correct_rate) {
                continue; // incorrect individuals never enter the archive
            }
            // mutate the current best (or sample fresh)
            let cfg = match &best {
                Some(b) => {
                    let mut c = b.clone();
                    match rng.below(4) {
                        0 => c.tile = *rng.choice(crate::agent::policy::TILES),
                        1 => c.quality = (c.quality * rng.lognormal_noise(0.25)).clamp(0.05, 0.95),
                        2 => c.fused_epilogue = true,
                        _ => c.stages = (c.stages % 4) + 1,
                    }
                    c
                }
                None => CandidateConfig {
                    tile: *rng.choice(crate::agent::policy::TILES),
                    compute_dtype: crate::dsl::DType::Fp32,
                    tensor_cores: problem.is_matmul_like() && rng.chance(0.7),
                    fused_epilogue: rng.chance(0.5),
                    fusion_coverage: if rng.chance(0.5) { 1.0 } else { 0.3 },
                    scheduler: Default::default(),
                    stages: 2,
                    quality: (params.raw_quality_median
                        * rng.lognormal_noise(params.raw_quality_sigma))
                    .clamp(0.03, 0.95),
                },
            };
            let t = ev.value(&EvalRequest::measured(pidx, cfg.clone(), measure.next_stream()));
            let better = best
                .as_ref()
                .map(|b| t < ev.value(&EvalRequest::candidate(pidx, b.clone())))
                .unwrap_or(true);
            if better {
                best = Some(cfg.clone());
            }
            kernels.push(ArchivedKernel {
                time_ms: t,
                kind: SolutionKind::RawCuda,
                kernel_names: vec![format!("evolved_{}", problem.name)],
            });
        }
    }
    kernels
}

/// The paper's fallback review loop (§5.9): take the fastest correct
/// kernel; if the review rejects it (Gaming / PyTorch-only), move to the
/// next fastest; continue until accepted or exhausted. Returns the accepted
/// speedup (0.0 when none — counted against the archive in Fast-p).
pub fn review_archive(
    env: &Env,
    pidx: usize,
    kernels: &[ArchivedKernel],
    pipeline: &IntegrityPipeline,
    seed: u64,
) -> (f64, usize) {
    let t_ref = env.evaluator().value(&EvalRequest::baseline(pidx));
    let t_sol = env.sols[pidx].t_sol_ms;
    let t_sol_fp16 = env.sols[pidx].t_sol_fp16_ms;
    let mut sorted: Vec<&ArchivedKernel> = kernels.iter().collect();
    sorted.sort_by(|a, b| a.time_ms.partial_cmp(&b.time_ms).unwrap());
    let mut rng = Pcg32::derive(seed, &[stream::ARCHIVE_REVIEW, pidx as u64]);
    let mut reviewed = 0;
    for k in sorted {
        reviewed += 1;
        let rec = AttemptRecord {
            problem_idx: pidx,
            attempt: 0,
            outcome: AttemptOutcome::Correct { time_ms: k.time_ms },
            kind: k.kind.clone(),
            minor_issue: None,
            inherited: false,
            tokens: 0,
            tool_time_s: 0.0,
            config: None,
            kernel_names: k.kernel_names.clone(),
            dsl_source: None,
            dsl_plan: None,
        };
        let label = pipeline.label(&rec, t_sol, t_sol_fp16, &mut rng);
        if label.accepted() {
            return (t_ref / k.time_ms, reviewed);
        }
    }
    (0.0, reviewed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::Bench;

    #[test]
    fn archive_has_candidates_and_review_accepts_most() {
        let bench = Bench::new();
        let env = bench.env();
        let pipeline = IntegrityPipeline::default();
        let params = EvoParams::default();
        let mut accepted = 0;
        let mut total_reviewed = 0;
        for pidx in 0..bench.problems.len() {
            let archive = generate_archive(&env, pidx, &params, 77);
            let (speedup, reviewed) = review_archive(&env, pidx, &archive, &pipeline, 77);
            total_reviewed += reviewed;
            if speedup > 0.0 {
                accepted += 1;
            }
        }
        assert!(accepted >= 50, "most problems should have an accepted kernel, got {accepted}");
        assert!(total_reviewed >= 59);
    }

    #[test]
    fn evolution_improves_over_generations() {
        let bench = Bench::new();
        let env = bench.env();
        let params = EvoParams::default();
        let archive = generate_archive(&env, 0, &params, 3);
        let honest: Vec<f64> = archive
            .iter()
            .filter(|k| matches!(k.kind, SolutionKind::RawCuda))
            .map(|k| k.time_ms)
            .collect();
        assert!(honest.len() > 20);
        let early: f64 = honest[..5].iter().sum::<f64>() / 5.0;
        let late: f64 = honest[honest.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(late <= early, "selection should not regress: early {early} late {late}");
    }
}
