//! Experiment drivers: one function per paper figure/table (§6), each
//! regenerating the same rows/series from fresh seeded runs.

pub mod archive;
pub mod figures;
pub mod runner;

pub use runner::{Bench, run_variant};
