//! Analytical H100 performance model — the substitute for the paper's GPU
//! testbed (DESIGN.md §2).
//!
//! Runtimes are roofline-with-inefficiencies estimates over the same
//! configuration axes µCUTLASS exposes (tile shape, dtype, fusion,
//! scheduler, stages), so the *search landscape* the agents explore has the
//! same structure as on real silicon: tile quantization and wave
//! quantization penalize bad tiles, reduced precision doubles matmul
//! throughput, fusion removes intermediate DRAM round trips, persistent /
//! stream-k schedulers recover wave-quantization losses, and deeper
//! pipelines hide latency. Correctness of accepted kernels is established
//! separately by really executing the AOT artifacts ([`crate::runtime`]).

pub mod compiled;
pub mod ncu;

pub use compiled::{CompiledCostModel, CompiledCosts, ConfigBatch};
pub use ncu::NcuProfile;

use crate::dsl::ir::TileScheduler;
use crate::dsl::{DType, KernelPlan};
use crate::kernelbench::{Op, Problem};
use crate::sol::GpuSpec;
use crate::util::json::Json;
use crate::util::rng::StreamPath;

/// Scheduler kinds the model distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    #[default]
    Default,
    Persistent,
    StreamK,
}

impl SchedulerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Default => "default",
            SchedulerKind::Persistent => "persistent",
            SchedulerKind::StreamK => "stream_k",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s {
            "default" => Some(SchedulerKind::Default),
            "persistent" => Some(SchedulerKind::Persistent),
            "stream_k" => Some(SchedulerKind::StreamK),
            _ => None,
        }
    }
}

/// Abstract kernel-design descriptor the model costs. Derived from a
/// compiled [`KernelPlan`] (high-level, statically valid) or hand-built for
/// raw-CUDA candidates (where `quality` captures code-level inefficiency
/// the configuration axes don't).
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateConfig {
    /// Threadblock tile (m, n, k).
    pub tile: (u64, u64, u64),
    /// Compute dtype (DRAM I/O stays FP32 per KernelBench).
    pub compute_dtype: DType,
    /// Uses tensor cores (vs scalar CUDA cores).
    pub tensor_cores: bool,
    /// Epilogue chain fused into the main kernel.
    pub fused_epilogue: bool,
    /// Fraction of the problem's op graph covered by fused kernels [0, 1].
    pub fusion_coverage: f64,
    pub scheduler: SchedulerKind,
    pub stages: u64,
    /// Residual implementation quality in (0, 1]: 1.0 = library-grade code.
    /// Raw-CUDA agent output typically lands well below 1.
    pub quality: f64,
}

impl CandidateConfig {
    /// Library-grade defaults for a given tile/dtype.
    pub fn library(tile: (u64, u64, u64), dtype: DType) -> Self {
        CandidateConfig {
            tile,
            compute_dtype: dtype,
            tensor_cores: true,
            fused_epilogue: true,
            fusion_coverage: 1.0,
            scheduler: SchedulerKind::Default,
            stages: 3,
            quality: 1.0,
        }
    }

    /// Canonical field-by-field fingerprint (FNV-64 over the canonical
    /// serialization, hex) — the request-identity component for candidate
    /// configs that did not come from a compiled plan (raw-CUDA candidates
    /// have no [`KernelPlan`] config hash). Mirrors the canonicalization
    /// discipline of `dsl::plan::config_hash`: fields are serialized by
    /// name, never through `Debug`.
    pub fn fingerprint(&self) -> String {
        let canon = format!(
            "tile={}x{}x{};dtype={};tc={};epi={};cov={};sched={};stages={};q={}",
            self.tile.0,
            self.tile.1,
            self.tile.2,
            self.compute_dtype,
            self.tensor_cores,
            self.fused_epilogue,
            self.fusion_coverage,
            self.scheduler.name(),
            self.stages,
            self.quality,
        );
        format!("{:016x}", crate::util::fnv64(canon.as_bytes()))
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tile", vec![self.tile.0, self.tile.1, self.tile.2])
            .set("compute_dtype", self.compute_dtype.to_string())
            .set("tensor_cores", self.tensor_cores)
            .set("fused_epilogue", self.fused_epilogue)
            .set("fusion_coverage", self.fusion_coverage)
            .set("scheduler", self.scheduler.name())
            .set("stages", self.stages)
            .set("quality", self.quality);
        o
    }

    pub fn from_json(j: &Json) -> Option<CandidateConfig> {
        let tile = j.get("tile")?.as_arr()?;
        if tile.len() != 3 {
            return None;
        }
        Some(CandidateConfig {
            tile: (tile[0].as_u64()?, tile[1].as_u64()?, tile[2].as_u64()?),
            compute_dtype: DType::parse(j.get("compute_dtype")?.as_str()?)?,
            tensor_cores: j.get("tensor_cores")?.as_bool()?,
            fused_epilogue: j.get("fused_epilogue")?.as_bool()?,
            fusion_coverage: j.get("fusion_coverage")?.as_f64()?,
            scheduler: SchedulerKind::parse(j.get("scheduler")?.as_str()?)?,
            stages: j.get("stages")?.as_u64()?,
            quality: j.get("quality")?.as_f64()?,
        })
    }

    /// Build from a compiled [`KernelPlan`]: the cost model reads the same
    /// resolved tile/dtype/scheduler/stage numbers codegen emitted, instead
    /// of re-deriving them. DSL-generated code is CUTLASS-backed, so
    /// `quality` is library-grade by construction — this is the mechanism
    /// behind the paper's DSL advantage.
    pub fn from_plan(plan: &KernelPlan, covers_all_ops: bool) -> Self {
        let k = plan.primary();
        CandidateConfig {
            tile: (k.tile.m, k.tile.n, k.tile.k),
            compute_dtype: k.dtype_input,
            tensor_cores: true,
            fused_epilogue: !k.epilogue.is_empty(),
            fusion_coverage: if covers_all_ops { 1.0 } else { 0.6 },
            scheduler: match k.scheduler.tile {
                TileScheduler::Default => SchedulerKind::Default,
                TileScheduler::Persistent => SchedulerKind::Persistent,
                TileScheduler::StreamK => SchedulerKind::StreamK,
            },
            stages: k.stages,
            quality: 0.97,
        }
    }
}

/// Per-kernel launch overhead (µs) — the fixed cost every extra unfused
/// kernel pays; visible on small problems.
pub(crate) const LAUNCH_OVERHEAD_US: f64 = 3.0;

/// The analytical model.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub gpu: GpuSpec,
}

impl PerfModel {
    pub fn new(gpu: GpuSpec) -> Self {
        PerfModel { gpu }
    }

    /// Effective matmul peak for a compute dtype (FLOP/s).
    fn matmul_peak(&self, dtype: DType) -> f64 {
        match dtype {
            DType::Fp16 | DType::Bf16 => self.gpu.effective_fp16_flops(),
            DType::Fp8E4m3 | DType::Fp8E5m2 => self.gpu.effective_fp8_flops(),
            DType::Fp64 => self.gpu.effective_fp64_flops(),
            // FP32 inputs ride TF32 tensor cores
            _ => self.gpu.effective_tf32_flops(),
        }
    }

    /// Library efficiency for one op family (fraction of its roofline a
    /// well-tuned vendor kernel achieves). Calibrated to make PyTorch
    /// baselines land where KernelBench reports them.
    fn library_eff(op: &Op) -> (f64, f64) {
        // (compute_eff, memory_eff)
        match op {
            Op::Gemm { .. } => (0.82, 0.85),
            Op::BatchedGemm { .. } => (0.78, 0.85),
            Op::GroupedGemm { .. } => (0.60, 0.80),
            Op::Gemv { .. } => (0.50, 0.88),
            Op::Conv2d { .. } | Op::Conv1d { .. } => (0.65, 0.80),
            Op::Softmax { .. } => (0.50, 0.78),
            Op::RmsNorm { .. } | Op::LayerNorm { .. } => (0.45, 0.72),
            Op::Elementwise { .. } => (0.60, 0.88),
            Op::Reduce { .. } => (0.55, 0.82),
            // torch cumsum/cumprod are notoriously far from bandwidth
            Op::Scan { .. } => (0.20, 0.30),
            Op::Attention { .. } => (0.55, 0.75),
            Op::CrossEntropy { .. } => (0.40, 0.60),
        }
    }

    /// One op's runtime under a library implementation (seconds).
    fn op_library_time(&self, op: &Op, dtype: DType) -> f64 {
        let (ce, me) = Self::library_eff(op);
        let peak = if op.is_matmul_like() {
            self.matmul_peak(dtype)
        } else {
            self.gpu.effective_fp32_flops()
        };
        let t_c = op.flops() as f64 / (peak * ce);
        let t_m = op.bytes(DType::Fp32) as f64 / (self.gpu.effective_bandwidth() * me);
        t_c.max(t_m) + LAUNCH_OVERHEAD_US * 1e-6
    }

    /// PyTorch eager baseline t_ref (ms): every op is its own library
    /// kernel; intermediates round-trip DRAM (already in `Op::bytes`).
    pub fn baseline_ms(&self, problem: &Problem) -> f64 {
        problem
            .ops
            .iter()
            .map(|op| self.op_library_time(op, problem.dtype))
            .sum::<f64>()
            * 1e3
    }

    /// Pipeline-depth efficiency: shallow pipelines cannot hide HBM latency.
    pub(crate) fn stage_efficiency(stages: u64) -> f64 {
        match stages {
            0 | 1 => 0.72,
            2 => 0.90,
            3 => 0.97,
            _ => 0.98,
        }
    }

    /// Hoist every `candidate_ms` term that does not depend on the
    /// candidate configuration. A batched evaluation pays this once per
    /// problem instead of once per config (ADR-003) — the scalar path goes
    /// through the same helper, so batch and scalar results are
    /// bit-identical by construction.
    pub(crate) fn problem_costs(&self, problem: &Problem) -> ProblemCosts {
        ProblemCosts {
            flops: problem.flops() as f64,
            fused_bytes: problem.fused_bytes() as f64,
            unfused_bytes: problem.ops.iter().map(|o| o.bytes(DType::Fp32) as f64).sum(),
            n_ops: problem.ops.len() as f64,
            matmul_like: problem.is_matmul_like(),
            dom: DominantDims::of(problem),
        }
    }

    /// Wave-quantization efficiency: the last wave of threadblocks runs
    /// partially full; persistent / stream-k schedulers recover most of it.
    fn wave_efficiency(&self, dom: DominantDims, cfg: &CandidateConfig) -> f64 {
        let (bm, bn, _) = cfg.tile;
        let blocks = match dom {
            DominantDims::MatmulMn { m, n, batch } => {
                batch * m.div_ceil(bm) * n.div_ceil(bn)
            }
            DominantDims::Attention { s, bh, .. } => bh * s.div_ceil(bm),
            DominantDims::Other => return 1.0,
        };
        let sms = self.gpu.sm_count;
        let waves = blocks.div_ceil(sms).max(1);
        let natural = blocks as f64 / (waves * sms) as f64;
        match cfg.scheduler {
            SchedulerKind::Persistent => natural.max(0.93),
            SchedulerKind::StreamK => natural.max(0.96),
            SchedulerKind::Default => natural,
        }
    }

    /// `candidate_ms` body over hoisted per-problem terms. This is the
    /// *generic* (uncompiled) evaluator: it re-matches [`DominantDims`] and
    /// re-reads GPU peaks per call. [`compiled::CompiledCosts`] lowers the
    /// same arithmetic into a branch-free form and must stay bit-identical
    /// to it — treat this body as the specification (ADR-006).
    pub(crate) fn candidate_ms_with(&self, costs: &ProblemCosts, cfg: &CandidateConfig) -> f64 {
        // Bytes: interpolate between fully-fused best case and eager
        // per-op traffic with fusion coverage.
        let cov = cfg.fusion_coverage.clamp(0.0, 1.0);
        let epi_cov = if cfg.fused_epilogue { 1.0 } else { 0.75 };
        let bytes =
            costs.fused_bytes + (costs.unfused_bytes - costs.fused_bytes) * (1.0 - cov * epi_cov);

        // Compute peak.
        let peak = if costs.matmul_like && cfg.tensor_cores {
            self.matmul_peak(cfg.compute_dtype)
        } else {
            self.gpu.effective_fp32_flops()
        };

        // Structural efficiency product.
        let eff = costs.dom.tile_efficiency(cfg.tile)
            * self.wave_efficiency(costs.dom, cfg)
            * Self::stage_efficiency(cfg.stages)
            * cfg.quality.clamp(0.01, 1.0)
            // even perfect kernels don't hit 100% of peak
            * 0.96;
        let mem_eff = (0.92 * cfg.quality.clamp(0.01, 1.0)).clamp(0.01, 1.0);

        let t_c = costs.flops / (peak * eff);
        let t_m = bytes / (self.gpu.effective_bandwidth() * mem_eff);
        // Kernel launches: one per unfused region (approx).
        let launches = 1.0 + (costs.n_ops - 1.0) * (1.0 - cov);
        (t_c.max(t_m) + launches * LAUNCH_OVERHEAD_US * 1e-6) * 1e3
    }

    /// Candidate kernel runtime (ms) for a problem under this config,
    /// without measurement noise.
    pub fn candidate_ms(&self, problem: &Problem, cfg: &CandidateConfig) -> f64 {
        self.candidate_ms_with(&self.problem_costs(problem), cfg)
    }

    /// Vectorized [`Self::candidate_ms`] over a config batch: lowers the
    /// problem once ([`compiled::CompiledCosts`]) and evaluates the configs
    /// through the branch-free compiled path, so the MANTIS Nominate round
    /// and the move-selection policy cost one problem analysis per batch
    /// instead of one per hypothesis. Results are element-wise
    /// bit-identical to the scalar call (a property test asserts it).
    ///
    /// This entry point re-lowers per call — fine for one-shot callers.
    /// Anything evaluating the same problem repeatedly should hold a
    /// [`CompiledCostModel`] and skip the lowering (ADR-006).
    pub fn candidate_ms_batch(&self, problem: &Problem, cfgs: &[CandidateConfig]) -> Vec<f64> {
        CompiledCosts::lower(self, problem).eval_batch(&ConfigBatch::from_configs(cfgs))
    }

    /// Candidate runtime with measurement noise (the paper's NCU timings
    /// still jitter ~1%). The noise is drawn from the derived stream `at`
    /// names — one stream per measurement, handed out by
    /// [`crate::util::rng::MeasureSeq`] — so a serialized
    /// `eval::EvalRequest` replayed in another process reproduces the
    /// in-process value exactly instead of depending on a shared RNG's
    /// draw order (ADR-003).
    pub fn measure_ms(&self, problem: &Problem, cfg: &CandidateConfig, at: &StreamPath) -> f64 {
        self.candidate_ms(problem, cfg) * measurement_noise(at)
    }

    /// Baseline with measurement noise (same stream discipline).
    pub fn measure_baseline_ms(&self, problem: &Problem, at: &StreamPath) -> f64 {
        self.baseline_ms(problem) * measurement_noise(at)
    }
}

/// The ~1% lognormal measurement jitter for one stream identity.
pub fn measurement_noise(at: &StreamPath) -> f64 {
    at.rng().lognormal_noise(0.01)
}

/// `candidate_ms` terms that depend only on the problem (see
/// [`PerfModel::candidate_ms_batch`]).
#[derive(Debug, Clone)]
pub(crate) struct ProblemCosts {
    pub(crate) flops: f64,
    pub(crate) fused_bytes: f64,
    pub(crate) unfused_bytes: f64,
    pub(crate) n_ops: f64,
    pub(crate) matmul_like: bool,
    pub(crate) dom: DominantDims,
}

/// The dominant op's tiling-relevant dimensions, extracted once per
/// problem. Collapses the per-op-family match of the old
/// `tile_efficiency`/`wave_efficiency` pair into data, so the per-config
/// loop runs no op-graph inspection at all.
#[derive(Debug, Clone, Copy)]
pub(crate) enum DominantDims {
    /// GEMM-shaped: tile quantization over (m, n); `batch` independent
    /// block grids (1 for plain GEMM / convs, b for batched, groups for
    /// grouped).
    MatmulMn { m: u64, n: u64, batch: u64 },
    /// Attention: row blocks over s, head dim d, b·h independent tiles.
    Attention { s: u64, d: u64, bh: u64 },
    /// Non-tiled op: quantization and wave effects negligible.
    Other,
}

impl DominantDims {
    fn of(problem: &Problem) -> DominantDims {
        match *problem.dominant_op() {
            Op::Gemm { m, n, .. } => DominantDims::MatmulMn { m, n, batch: 1 },
            Op::BatchedGemm { b, m, n, .. } => DominantDims::MatmulMn { m, n, batch: b },
            Op::GroupedGemm { groups, m, n, .. } => {
                DominantDims::MatmulMn { m, n, batch: groups }
            }
            Op::Attention { b, h, s, d, .. } => DominantDims::Attention { s, d, bh: b * h },
            Op::Conv2d { n, h, w, co, stride, .. } => DominantDims::MatmulMn {
                m: n * (h / stride) * (w / stride),
                n: co,
                batch: 1,
            },
            Op::Conv1d { n, l, co, stride, .. } => {
                DominantDims::MatmulMn { m: n * (l / stride), n: co, batch: 1 }
            }
            _ => DominantDims::Other,
        }
    }

    /// Tile-quantization efficiency: fraction of computed tiles that is
    /// useful work.
    fn tile_efficiency(self, tile: (u64, u64, u64)) -> f64 {
        let (bm, bn, _) = tile;
        match self {
            DominantDims::MatmulMn { m, n, .. } => {
                quantization_eff(m, bm) * quantization_eff(n, bn)
            }
            DominantDims::Attention { s, d, .. } => {
                quantization_eff(s, bm) * quantization_eff(d.max(64), bn.min(128))
            }
            DominantDims::Other => 1.0, // tiles are row blocks, quantization negligible
        }
    }
}

/// Fraction of `ceil(dim/block)*block` that is useful.
pub(crate) fn quantization_eff(dim: u64, block: u64) -> f64 {
    if block == 0 {
        return 1.0;
    }
    let padded = dim.div_ceil(block) * block;
    dim as f64 / padded as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelbench::{find, suite};
    use crate::sol::{analyze, H100_SXM};

    fn model() -> PerfModel {
        PerfModel::new(H100_SXM.clone())
    }

    #[test]
    fn baseline_above_sol() {
        let m = model();
        for p in suite() {
            let sol = analyze(&p, &H100_SXM);
            let t_ref = m.baseline_ms(&p);
            assert!(t_ref > sol.t_sol_ms, "{}: t_ref {} <= SOL {}", p.id, t_ref, sol.t_sol_ms);
        }
    }

    #[test]
    fn good_candidate_above_fp16_sol() {
        let m = model();
        for p in suite() {
            let sol = analyze(&p, &H100_SXM);
            let cfg = CandidateConfig::library((128, 128, 64), DType::Fp16);
            let t = m.candidate_ms(&p, &cfg);
            assert!(t >= sol.t_sol_fp16_ms * 0.99,
                "{}: candidate {} below FP16 SOL {}", p.id, t, sol.t_sol_fp16_ms);
        }
    }

    #[test]
    fn fp16_beats_tf32_on_compute_bound() {
        let m = model();
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let t32 = m.candidate_ms(p, &CandidateConfig::library((128, 128, 64), DType::Fp32));
        let t16 = m.candidate_ms(p, &CandidateConfig::library((128, 128, 64), DType::Fp16));
        assert!(t16 < t32 * 0.65, "fp16 {} vs tf32 {}", t16, t32);
    }

    #[test]
    fn bad_tile_is_slower() {
        let m = model();
        let s = suite();
        // L1-8 irregular 1000x1500x700: tile 256x256 wastes heavily
        let p = &s[find(&s, "L1-8").unwrap()];
        let good = m.candidate_ms(p, &CandidateConfig::library((128, 64, 32), DType::Fp32));
        let bad = m.candidate_ms(p, &CandidateConfig::library((256, 256, 32), DType::Fp32));
        assert!(bad > good, "bad tile {} should beat good {}", bad, good);
    }

    #[test]
    fn streamk_recovers_wave_quantization() {
        let m = model();
        let s = suite();
        let p = &s[find(&s, "L1-7").unwrap()]; // small-K, wave-quantization-prone
        let mut base = CandidateConfig::library((256, 128, 32), DType::Fp32);
        base.scheduler = SchedulerKind::Default;
        let t_def = m.candidate_ms(p, &base);
        base.scheduler = SchedulerKind::StreamK;
        let t_sk = m.candidate_ms(p, &base);
        assert!(t_sk <= t_def);
    }

    #[test]
    fn fusion_beats_eager_on_l2() {
        let m = model();
        let s = suite();
        let p = &s[find(&s, "L2-76").unwrap()]; // gemm+bias+relu
        let t_ref = m.baseline_ms(p);
        let fused = m.candidate_ms(p, &CandidateConfig::library((128, 128, 32), DType::Fp32));
        assert!(fused < t_ref, "fused {} should beat eager {}", fused, t_ref);
    }

    #[test]
    fn low_quality_raw_cuda_is_slow() {
        let m = model();
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let mut cfg = CandidateConfig::library((128, 128, 32), DType::Fp32);
        cfg.quality = 0.25; // typical naive hand-written CUDA
        let t_naive = m.candidate_ms(p, &cfg);
        let t_ref = m.baseline_ms(p);
        assert!(t_naive > t_ref, "naive CUDA should regress vs cuBLAS");
    }

    #[test]
    fn measurement_noise_small() {
        use crate::util::rng::{stream, MeasureSeq};
        let m = model();
        let s = suite();
        let p = &s[0];
        let cfg = CandidateConfig::library((128, 128, 32), DType::Fp32);
        let t0 = m.candidate_ms(p, &cfg);
        let mut seq = MeasureSeq::new(StreamPath::new(3, &[stream::MEASURE, 0]));
        for _ in 0..50 {
            let at = seq.next_stream();
            let t = m.measure_ms(p, &cfg, &at);
            assert!((t / t0 - 1.0).abs() < 0.06);
            // replay: the value depends only on the stream identity
            assert_eq!(t, m.measure_ms(p, &cfg, &at));
        }
    }

    #[test]
    fn candidate_ms_batch_matches_scalar_bitwise() {
        let m = model();
        for p in suite() {
            let cfgs: Vec<CandidateConfig> = crate::agent::policy::TILES
                .iter()
                .flat_map(|&t| {
                    [
                        CandidateConfig::library(t, DType::Fp32),
                        CandidateConfig::library(t, DType::Fp16),
                        CandidateConfig {
                            scheduler: SchedulerKind::StreamK,
                            stages: 2,
                            fused_epilogue: false,
                            fusion_coverage: 0.3,
                            quality: 0.4,
                            ..CandidateConfig::library(t, DType::Bf16)
                        },
                    ]
                })
                .collect();
            let batch = m.candidate_ms_batch(&p, &cfgs);
            for (cfg, &b) in cfgs.iter().zip(&batch) {
                let s = m.candidate_ms(&p, cfg);
                assert!(s == b, "{}: batch {b} != scalar {s}", p.id);
            }
        }
    }

    #[test]
    fn config_fingerprint_is_canonical() {
        let a = CandidateConfig::library((128, 128, 64), DType::Fp16);
        let b = a.clone();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.stages = 2;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn config_json_roundtrips() {
        let mut a = CandidateConfig::library((256, 128, 32), DType::Bf16);
        a.scheduler = SchedulerKind::StreamK;
        a.quality = 0.3725;
        a.fusion_coverage = 0.6;
        let b = CandidateConfig::from_json(&Json::parse(&a.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn from_plan_reads_resolved_config() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
            .with_threadblockshape(m=128, n=64, k=64).with_stages(4)\
            .with_scheduler(tile=stream_k, kernel=tma, epilogue=auto) >> bias() >> relu()";
        let c = crate::dsl::compile(src).unwrap();
        let cfg = CandidateConfig::from_plan(&c.plan, true);
        assert_eq!(cfg.tile, (128, 64, 64));
        assert_eq!(cfg.compute_dtype, DType::Fp16);
        assert_eq!(cfg.scheduler, SchedulerKind::StreamK, "scheduler comes from the plan");
        assert_eq!(cfg.stages, 4, "stage count comes from the plan");
        assert!(cfg.fused_epilogue);
        assert!((cfg.quality - 0.97).abs() < 1e-12);
    }

    #[test]
    fn deeper_stages_help() {
        let m = model();
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let mut cfg = CandidateConfig::library((128, 128, 64), DType::Fp16);
        cfg.stages = 1;
        let t1 = m.candidate_ms(p, &cfg);
        cfg.stages = 4;
        let t4 = m.candidate_ms(p, &cfg);
        assert!(t4 < t1);
    }
}
