//! Compiled cost model: each (problem, arch) pair lowered **once** into a
//! small fixed struct of pre-resolved coefficients, evaluated over
//! struct-of-arrays config batches with zero dispatch (ROADMAP item 3).
//!
//! The generic path ([`PerfModel::candidate_ms`]) re-derives per-problem
//! terms on every call and matches on [`DominantDims`] per candidate. The
//! compiled path splits that work along its natural frequency boundary:
//!
//! * **Lowering** (once per problem per process): [`CompiledCosts::lower`]
//!   flattens `ProblemCosts`/`DominantDims` into plain `f64`/`u64` fields,
//!   resolves every arch-dependent peak (`effective_*_flops`,
//!   `effective_bandwidth`) into a 5-entry table, and selects one
//!   monomorphic evaluator `fn(&CompiledCosts, &ConfigBatch, &mut [f64])`
//!   per dominant-dims shape — the enum is gone before the first candidate
//!   is costed.
//! * **Config lowering** (once per candidate, at [`ConfigBatch::push`]):
//!   every term that depends only on the config — clamps, the fusion
//!   interpolation factor, the stage/quality/memory efficiencies, the
//!   scheduler's wave floor, the peak-table index — is folded into a
//!   [`LoweredCfg`] and appended to parallel contiguous columns.
//! * **Evaluation** (the hot loop): pure branch-free arithmetic over the
//!   columns. No enum dispatch, no per-candidate `match`, no allocation.
//!   (The one residual branch is `quantization_eff`'s `block == 0` guard —
//!   a trivially-predicted scalar compare, not a dispatch.)
//!
//! The contract is **bitwise**: for every config, the compiled value has
//! the exact bit pattern of [`PerfModel::candidate_ms`]. Lowering only
//! hoists computations — it never reassociates, never substitutes
//! algebraically unequal forms. The two non-obvious hoists are argued
//! inline and pinned by the property test below plus the golden test in
//! `eval::tests` over the full suite enumeration (ADR-006).

use super::{
    quantization_eff, CandidateConfig, DominantDims, PerfModel, ProblemCosts, SchedulerKind,
    LAUNCH_OVERHEAD_US,
};
use crate::dsl::DType;
use crate::kernelbench::Problem;

/// Index into [`CompiledCosts::peaks`]: the compute-peak class of a config,
/// with the `tensor_cores` flag folded in (no `if` at eval time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
enum PeakClass {
    /// FP32 inputs on tensor cores ride TF32.
    Tf32 = 0,
    Fp16 = 1,
    Fp8 = 2,
    Fp64 = 3,
    /// Scalar CUDA-core FP32 (tensor cores off).
    Fp32Cuda = 4,
}

impl PeakClass {
    /// Mirrors the `costs.matmul_like && cfg.tensor_cores` branch of
    /// `candidate_ms_with` from the config side: the problem side is folded
    /// into the peak *table* (a non-matmul problem's table holds the CUDA
    /// peak in every slot), so `peaks[class]` is the exact peak the scalar
    /// path would compute.
    fn of(cfg: &CandidateConfig) -> PeakClass {
        if !cfg.tensor_cores {
            return PeakClass::Fp32Cuda;
        }
        match cfg.compute_dtype {
            DType::Fp16 | DType::Bf16 => PeakClass::Fp16,
            DType::Fp8E4m3 | DType::Fp8E5m2 => PeakClass::Fp8,
            DType::Fp64 => PeakClass::Fp64,
            _ => PeakClass::Tf32,
        }
    }
}

/// Per-config terms of `candidate_ms`, pre-resolved at push time. Every
/// field is the bit-exact value the scalar path computes from the same
/// config — lowering moves the work, not the math.
#[derive(Debug, Clone, Copy)]
pub(crate) struct LoweredCfg {
    /// Threadblock tile m / n (k never enters the cost).
    bm: u64,
    bn: u64,
    /// `bn.min(128)` — the Attention head-dim block cap, hoisted.
    bn_cap: u64,
    /// Index into [`CompiledCosts::peaks`].
    peak_idx: u8,
    /// Scheduler recovery floor for wave efficiency: Default → `0.0`
    /// (`natural.max(0.0) ≡ natural` bitwise — `natural` is a quotient of
    /// non-negative integers, so it is `+0.0` or positive, never `-0.0`
    /// and never NaN), Persistent → `0.93`, StreamK → `0.96`.
    wave_floor: f64,
    /// `stage_efficiency(stages)`.
    stage_eff: f64,
    /// `quality.clamp(0.01, 1.0)`.
    q_eff: f64,
    /// `(0.92 * quality.clamp(0.01, 1.0)).clamp(0.01, 1.0)`.
    mem_eff: f64,
    /// `1.0 - cov * epi_cov` — the fused↔unfused byte interpolation factor.
    one_minus_cov_epi: f64,
    /// `1.0 - cov` — the launch-count interpolation factor.
    one_minus_cov: f64,
}

impl LoweredCfg {
    pub(crate) fn of(cfg: &CandidateConfig) -> LoweredCfg {
        let cov = cfg.fusion_coverage.clamp(0.0, 1.0);
        let epi_cov = if cfg.fused_epilogue { 1.0 } else { 0.75 };
        let q_eff = cfg.quality.clamp(0.01, 1.0);
        LoweredCfg {
            bm: cfg.tile.0,
            bn: cfg.tile.1,
            bn_cap: cfg.tile.1.min(128),
            peak_idx: PeakClass::of(cfg) as u8,
            wave_floor: match cfg.scheduler {
                SchedulerKind::Default => 0.0,
                SchedulerKind::Persistent => 0.93,
                SchedulerKind::StreamK => 0.96,
            },
            stage_eff: PerfModel::stage_efficiency(cfg.stages),
            q_eff,
            mem_eff: (0.92 * cfg.quality.clamp(0.01, 1.0)).clamp(0.01, 1.0),
            one_minus_cov_epi: 1.0 - cov * epi_cov,
            one_minus_cov: 1.0 - cov,
        }
    }
}

/// Struct-of-arrays candidate batch: one contiguous column per
/// [`LoweredCfg`] field (plus the raw `bk`/`stages` axes for
/// completeness), so the evaluators stream parallel slices instead of
/// chasing `CandidateConfig` structs. Reusable: `clear()` + `push()`
/// refill it with no reallocation once capacity is warm — the move-pool
/// generators in `policy::select_move` and MANTIS Nominate fill one
/// thread-local batch in place per round.
#[derive(Debug, Clone, Default)]
pub struct ConfigBatch {
    bm: Vec<u64>,
    bn: Vec<u64>,
    bk: Vec<u64>,
    stages: Vec<u64>,
    bn_cap: Vec<u64>,
    peak_idx: Vec<u8>,
    wave_floor: Vec<f64>,
    stage_eff: Vec<f64>,
    q_eff: Vec<f64>,
    mem_eff: Vec<f64>,
    one_minus_cov_epi: Vec<f64>,
    one_minus_cov: Vec<f64>,
}

impl ConfigBatch {
    pub fn new() -> ConfigBatch {
        ConfigBatch::default()
    }

    pub fn with_capacity(n: usize) -> ConfigBatch {
        let mut b = ConfigBatch::default();
        b.reserve(n);
        b
    }

    pub fn reserve(&mut self, n: usize) {
        self.bm.reserve(n);
        self.bn.reserve(n);
        self.bk.reserve(n);
        self.stages.reserve(n);
        self.bn_cap.reserve(n);
        self.peak_idx.reserve(n);
        self.wave_floor.reserve(n);
        self.stage_eff.reserve(n);
        self.q_eff.reserve(n);
        self.mem_eff.reserve(n);
        self.one_minus_cov_epi.reserve(n);
        self.one_minus_cov.reserve(n);
    }

    /// Drop all configs, keeping the column allocations.
    pub fn clear(&mut self) {
        self.bm.clear();
        self.bn.clear();
        self.bk.clear();
        self.stages.clear();
        self.bn_cap.clear();
        self.peak_idx.clear();
        self.wave_floor.clear();
        self.stage_eff.clear();
        self.q_eff.clear();
        self.mem_eff.clear();
        self.one_minus_cov_epi.clear();
        self.one_minus_cov.clear();
    }

    /// Lower one config into the columns.
    pub fn push(&mut self, cfg: &CandidateConfig) {
        let lc = LoweredCfg::of(cfg);
        self.bm.push(lc.bm);
        self.bn.push(lc.bn);
        self.bk.push(cfg.tile.2);
        self.stages.push(cfg.stages);
        self.bn_cap.push(lc.bn_cap);
        self.peak_idx.push(lc.peak_idx);
        self.wave_floor.push(lc.wave_floor);
        self.stage_eff.push(lc.stage_eff);
        self.q_eff.push(lc.q_eff);
        self.mem_eff.push(lc.mem_eff);
        self.one_minus_cov_epi.push(lc.one_minus_cov_epi);
        self.one_minus_cov.push(lc.one_minus_cov);
    }

    pub fn extend(&mut self, cfgs: &[CandidateConfig]) {
        self.reserve(cfgs.len());
        for c in cfgs {
            self.push(c);
        }
    }

    pub fn from_configs(cfgs: &[CandidateConfig]) -> ConfigBatch {
        let mut b = ConfigBatch::with_capacity(cfgs.len());
        b.extend(cfgs);
        b
    }

    pub fn len(&self) -> usize {
        self.bm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bm.is_empty()
    }

    /// Reassemble row `i` from the columns (register-resident; the batch
    /// evaluators call this in their inner loop).
    #[inline(always)]
    fn row(&self, i: usize) -> LoweredCfg {
        LoweredCfg {
            bm: self.bm[i],
            bn: self.bn[i],
            bn_cap: self.bn_cap[i],
            peak_idx: self.peak_idx[i],
            wave_floor: self.wave_floor[i],
            stage_eff: self.stage_eff[i],
            q_eff: self.q_eff[i],
            mem_eff: self.mem_eff[i],
            one_minus_cov_epi: self.one_minus_cov_epi[i],
            one_minus_cov: self.one_minus_cov[i],
        }
    }
}

/// The monomorphic batch evaluator selected at lowering time — one per
/// [`DominantDims`] shape.
type EvalFn = fn(&CompiledCosts, &ConfigBatch, &mut [f64]);
/// Scalar twin of [`EvalFn`] (the `Oracle::value` fast path); shares the
/// per-variant kernel, so one-config and batched evaluation are the same
/// FP operations by construction.
type EvalOneFn = fn(&CompiledCosts, &LoweredCfg) -> f64;

/// One (problem, arch) pair lowered into pre-resolved coefficients. All
/// model inputs — problem op graph, GPU peaks, clock ratios — are resolved
/// here; evaluation touches only these fields.
#[derive(Debug, Clone)]
pub struct CompiledCosts {
    /// `problem.flops()` as f64.
    flops: f64,
    /// Best-case (fully fused) DRAM bytes.
    fused_bytes: f64,
    /// `unfused_bytes - fused_bytes` — the fusion interpolation span.
    bytes_diff: f64,
    /// `n_ops - 1.0` — the extra-launch span.
    n_ops_m1: f64,
    /// Effective compute peaks indexed by [`PeakClass`]. For a non-matmul
    /// problem every entry is the scalar FP32 peak (the problem-side half
    /// of the `matmul_like && tensor_cores` branch, folded into data).
    peaks: [f64; 5],
    /// `gpu.effective_bandwidth()`.
    bw: f64,
    /// SM count (wave-quantization granularity).
    sms: u64,
    /// Flattened dominant dims: MatmulMn → (m, n, batch); Attention →
    /// (s, d.max(64), b·h); Other → unused zeros.
    dim_i: u64,
    dim_j: u64,
    grids: u64,
    eval: EvalFn,
    eval_one: EvalOneFn,
}

impl CompiledCosts {
    /// Lower one problem against the model's GPU. The only lowering an
    /// eval-stack component should run more than once per (problem, arch)
    /// pair is none at all — hold a [`CompiledCostModel`] instead.
    pub fn lower(model: &PerfModel, problem: &Problem) -> CompiledCosts {
        Self::from_costs(model, &model.problem_costs(problem))
    }

    /// Lowering body over already-hoisted [`ProblemCosts`] (the property
    /// test drives this directly with synthetic edge-dim costs).
    pub(crate) fn from_costs(model: &PerfModel, pc: &ProblemCosts) -> CompiledCosts {
        let gpu = &model.gpu;
        // `effective_*_flops()`/`effective_bandwidth()` are pure functions
        // of the GpuSpec's f64 fields: evaluating them at lowering time
        // yields the exact bits the scalar path recomputes per call.
        let fp32 = gpu.effective_fp32_flops();
        let peaks = if pc.matmul_like {
            [
                gpu.effective_tf32_flops(),
                gpu.effective_fp16_flops(),
                gpu.effective_fp8_flops(),
                gpu.effective_fp64_flops(),
                fp32,
            ]
        } else {
            [fp32; 5]
        };
        let (dim_i, dim_j, grids, eval, eval_one): (u64, u64, u64, EvalFn, EvalOneFn) =
            match pc.dom {
                DominantDims::MatmulMn { m, n, batch } => {
                    (m, n, batch, eval_matmul_mn, one_matmul_mn)
                }
                DominantDims::Attention { s, d, bh } => {
                    // `d.max(64)` is a per-problem constant in the scalar
                    // path's tile_efficiency; hoist it here.
                    (s, d.max(64), bh, eval_attention, one_attention)
                }
                DominantDims::Other => (0, 0, 0, eval_other, one_other),
            };
        CompiledCosts {
            flops: pc.flops,
            fused_bytes: pc.fused_bytes,
            bytes_diff: pc.unfused_bytes - pc.fused_bytes,
            n_ops_m1: pc.n_ops - 1.0,
            peaks,
            bw: gpu.effective_bandwidth(),
            sms: gpu.sm_count,
            dim_i,
            dim_j,
            grids,
            eval,
            eval_one,
        }
    }

    /// Evaluate the batch into `out` (`out.len()` must equal
    /// `batch.len()`): the branch-free hot loop.
    pub fn eval_into(&self, batch: &ConfigBatch, out: &mut [f64]) {
        assert_eq!(batch.len(), out.len(), "output slice must match the batch");
        (self.eval)(self, batch, out);
    }

    /// Allocating convenience over [`Self::eval_into`].
    pub fn eval_batch(&self, batch: &ConfigBatch) -> Vec<f64> {
        let mut out = vec![0.0; batch.len()];
        self.eval_into(batch, &mut out);
        out
    }

    /// One config through the compiled path — bit-identical to
    /// [`PerfModel::candidate_ms`] on the problem this was lowered from
    /// (the scalar `Oracle::value` fast path).
    pub fn candidate_ms(&self, cfg: &CandidateConfig) -> f64 {
        (self.eval_one)(self, &LoweredCfg::of(cfg))
    }
}

/// The shared tail of every variant kernel: `candidate_ms_with` over
/// pre-resolved coefficients, with the variant-specific tile/wave
/// efficiencies passed in. Multiplication order matches the scalar path's
/// left-associative product exactly.
#[inline(always)]
fn finish(c: &CompiledCosts, lc: &LoweredCfg, tile_eff: f64, wave_eff: f64) -> f64 {
    let bytes = c.fused_bytes + c.bytes_diff * lc.one_minus_cov_epi;
    let peak = c.peaks[lc.peak_idx as usize];
    let eff = tile_eff * wave_eff * lc.stage_eff * lc.q_eff * 0.96;
    let t_c = c.flops / (peak * eff);
    let t_m = bytes / (c.bw * lc.mem_eff);
    let launches = 1.0 + c.n_ops_m1 * lc.one_minus_cov;
    (t_c.max(t_m) + launches * LAUNCH_OVERHEAD_US * 1e-6) * 1e3
}

/// Wave-quantization efficiency over a block count. `floor` is `0.0` for
/// the Default scheduler: `natural` is `blocks as f64 / (waves*sms) as
/// f64` with `waves*sms >= 1`, so it is `+0.0` or positive — `max(0.0)`
/// returns it unchanged, bit for bit.
#[inline(always)]
fn wave_eff_of(blocks: u64, sms: u64, floor: f64) -> f64 {
    let waves = blocks.div_ceil(sms).max(1);
    let natural = blocks as f64 / (waves * sms) as f64;
    natural.max(floor)
}

#[inline(always)]
fn one_matmul_mn(c: &CompiledCosts, lc: &LoweredCfg) -> f64 {
    let tile_eff = quantization_eff(c.dim_i, lc.bm) * quantization_eff(c.dim_j, lc.bn);
    let blocks = c.grids * c.dim_i.div_ceil(lc.bm) * c.dim_j.div_ceil(lc.bn);
    finish(c, lc, tile_eff, wave_eff_of(blocks, c.sms, lc.wave_floor))
}

#[inline(always)]
fn one_attention(c: &CompiledCosts, lc: &LoweredCfg) -> f64 {
    // dim_i = s, dim_j = d.max(64), grids = b·h
    let tile_eff = quantization_eff(c.dim_i, lc.bm) * quantization_eff(c.dim_j, lc.bn_cap);
    let blocks = c.grids * c.dim_i.div_ceil(lc.bm);
    finish(c, lc, tile_eff, wave_eff_of(blocks, c.sms, lc.wave_floor))
}

#[inline(always)]
fn one_other(c: &CompiledCosts, lc: &LoweredCfg) -> f64 {
    // Non-tiled op: tile and wave efficiencies are exactly 1.0 in the
    // scalar path; `1.0 * x` is the identity bitwise.
    finish(c, lc, 1.0, 1.0)
}

fn eval_matmul_mn(c: &CompiledCosts, b: &ConfigBatch, out: &mut [f64]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = one_matmul_mn(c, &b.row(i));
    }
}

fn eval_attention(c: &CompiledCosts, b: &ConfigBatch, out: &mut [f64]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = one_attention(c, &b.row(i));
    }
}

fn eval_other(c: &CompiledCosts, b: &ConfigBatch, out: &mut [f64]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = one_other(c, &b.row(i));
    }
}

/// Per-problem compiled-costs cache: every problem of a suite lowered
/// eagerly against one arch, indexed by problem position. This is the
/// process-wide "lower once" guarantee (ADR-006): `Bench`,
/// `OwnedAnalytic`, and every `Env`/`AnalyticEvaluator` they hand out
/// share one of these, so no (problem, arch) pair is lowered twice on the
/// eval stack.
#[derive(Debug, Clone)]
pub struct CompiledCostModel {
    costs: Vec<CompiledCosts>,
}

impl CompiledCostModel {
    /// Lower every problem once. Eager (not lazy) on purpose: 59 lowerings
    /// cost microseconds, and an immutable `Vec` needs no interior
    /// mutability or locks on the hot path.
    pub fn compile(model: &PerfModel, problems: &[Problem]) -> CompiledCostModel {
        CompiledCostModel {
            costs: problems.iter().map(|p| CompiledCosts::lower(model, p)).collect(),
        }
    }

    /// The compiled costs of problem `idx` (panics out of range, like the
    /// slice indexing of the scalar path).
    pub fn problem(&self, idx: usize) -> &CompiledCosts {
        &self.costs[idx]
    }

    pub fn get(&self, idx: usize) -> Option<&CompiledCosts> {
        self.costs.get(idx)
    }

    pub fn len(&self) -> usize {
        self.costs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.costs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{constraint_table, Arch};
    use crate::kernelbench::suite;
    use crate::sol::hw::{GpuSpec, A100_SXM, H100_SXM};
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    /// Every ConstraintTable arch row (SM70–SM100), mapped to a concrete
    /// clock-scaled GPU spec so the compiled peaks table is exercised
    /// across distinct arithmetic (locked clocks, missing FP8 pipes,
    /// different SM counts).
    const ARCH_ROWS: [Arch; 7] = [
        Arch::Sm70,
        Arch::Sm80,
        Arch::Sm86,
        Arch::Sm89,
        Arch::Sm90,
        Arch::Sm90a,
        Arch::Sm100,
    ];

    fn gpu_for(arch: Arch) -> GpuSpec {
        match arch {
            // Volta-era: no BF16/FP8 pipes, small SM count, down-clocked.
            Arch::Sm70 => GpuSpec {
                name: "synthetic V100-class",
                sm_count: 80,
                max_sm_clock_mhz: 1530.0,
                locked_sm_clock_mhz: 1290.0,
                peak_tf32_tflops: 15.7, // FP16 TC era: reuse as the "TC" peak
                peak_fp16_tflops: 125.0,
                peak_fp8_tflops: 0.0,
                peak_fp32_tflops: 15.7,
                peak_fp64_tflops: 7.8,
                peak_bw_gbps: 900.0,
                mem_clock_ratio: 1.0,
                smem_per_sm: 96 * 1024,
                l2_bytes: 6 * 1024 * 1024,
            },
            Arch::Sm80 => A100_SXM.clone(),
            Arch::Sm86 => GpuSpec {
                name: "synthetic GA102-class",
                sm_count: 84,
                locked_sm_clock_mhz: 1695.0,
                max_sm_clock_mhz: 1860.0,
                peak_bw_gbps: 936.0,
                ..A100_SXM.clone()
            },
            Arch::Sm89 => GpuSpec {
                name: "synthetic AD102-class",
                sm_count: 128,
                max_sm_clock_mhz: 2520.0,
                locked_sm_clock_mhz: 2235.0,
                peak_fp8_tflops: 660.0,
                ..A100_SXM.clone()
            },
            Arch::Sm90 => GpuSpec { locked_sm_clock_mhz: 1980.0, ..H100_SXM.clone() },
            Arch::Sm90a => H100_SXM.clone(),
            Arch::Sm100 => GpuSpec {
                name: "synthetic B200-class",
                sm_count: 148,
                peak_tf32_tflops: 1100.0,
                peak_fp16_tflops: 2250.0,
                peak_fp8_tflops: 4500.0,
                peak_bw_gbps: 8000.0,
                ..H100_SXM.clone()
            },
        }
    }

    /// Tile menu for random configs: the agent TILES plus degenerate and
    /// asymmetric shapes (never zero — a zero block divides by zero in the
    /// generic path too; `quantization_eff`'s `block == 0` guard is pinned
    /// separately below).
    const TILE_MENU: [(u64, u64, u64); 6] = [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 64),
        (256, 128, 32),
        (129, 255, 1),
        (64, 200, 16),
    ];

    const DTYPES: [DType; 7] = [
        DType::Fp32,
        DType::Tf32,
        DType::Fp16,
        DType::Bf16,
        DType::Fp8E4m3,
        DType::Fp8E5m2,
        DType::Fp64,
    ];

    /// Edge-heavy dim menu: 0 (NaN-producing quantization), 1, block
    /// boundaries, and a 2^45 "huge" value (guard-adjacent without
    /// overflowing the block-count products both paths share).
    const DIMS: [u64; 12] = [0, 1, 2, 63, 64, 65, 127, 128, 129, 1000, 4095, 1 << 20];
    const HUGE_DIM: u64 = 1 << 45;

    fn rand_cfg(r: &mut Pcg32) -> CandidateConfig {
        CandidateConfig {
            tile: *r.choice(&TILE_MENU),
            compute_dtype: *r.choice(&DTYPES),
            tensor_cores: r.chance(0.7),
            fused_epilogue: r.chance(0.5),
            // below 0 and above 1 exercise the clamp
            fusion_coverage: r.f64() * 1.6 - 0.3,
            scheduler: *r.choice(&[
                SchedulerKind::Default,
                SchedulerKind::Persistent,
                SchedulerKind::StreamK,
            ]),
            stages: (r.f64() * 6.0) as u64,
            // 0.0 exercises the 0.01 floor
            quality: if r.chance(0.1) { 0.0 } else { r.f64() },
        }
    }

    fn rand_costs(r: &mut Pcg32) -> ProblemCosts {
        let dim = |r: &mut Pcg32| *r.choice(&DIMS);
        let dom = match (r.f64() * 3.0) as u64 {
            0 => {
                // at most one huge dim keeps both paths' u64 block products
                // inside u64 (they overflow identically, but a debug-build
                // panic would abort the property run)
                let huge = r.chance(0.15);
                DominantDims::MatmulMn {
                    m: if huge { HUGE_DIM } else { dim(r) },
                    n: if huge { 4095.min(dim(r)) } else { dim(r) },
                    batch: 1 + (r.f64() * 1024.0) as u64,
                }
            }
            1 => DominantDims::Attention {
                s: if r.chance(0.15) { HUGE_DIM } else { dim(r) },
                d: dim(r),
                bh: 1 + (r.f64() * 1024.0) as u64,
            },
            _ => DominantDims::Other,
        };
        ProblemCosts {
            flops: (r.f64() * 1e15).max(1.0),
            fused_bytes: (r.f64() * 1e10).max(1.0),
            unfused_bytes: (r.f64() * 4e10).max(1.0),
            n_ops: 1.0 + (r.f64() * 8.0).floor(),
            matmul_like: r.chance(0.6),
            dom,
        }
    }

    /// Satellite property test: random configs across every DominantDims
    /// variant and every ConstraintTable arch row agree **bitwise** between
    /// the compiled and uncompiled paths — including NaN-valued results
    /// from dim = 0 quantization (compared by bit pattern, since NaN ≠
    /// NaN).
    #[test]
    fn prop_compiled_matches_uncompiled_bitwise_across_arch_rows() {
        for arch in ARCH_ROWS {
            // tie the loop to the real constraint rows: each arch must
            // have one, and it must be the row for this arch
            assert_eq!(constraint_table(arch).arch, arch);
            let model = PerfModel::new(gpu_for(arch));
            prop::check(&format!("compiled-bitwise-{arch:?}"), 300, |r| {
                let pc = rand_costs(r);
                let compiled = CompiledCosts::from_costs(&model, &pc);
                let cfgs: Vec<CandidateConfig> = (0..4).map(|_| rand_cfg(r)).collect();
                let batch = ConfigBatch::from_configs(&cfgs);
                let got = compiled.eval_batch(&batch);
                for (cfg, &b) in cfgs.iter().zip(&got) {
                    let want = model.candidate_ms_with(&pc, cfg);
                    assert_eq!(
                        want.to_bits(),
                        b.to_bits(),
                        "batch: {want} vs {b} for {cfg:?} / {pc:?} on {arch:?}"
                    );
                    let one = compiled.candidate_ms(cfg);
                    assert_eq!(
                        want.to_bits(),
                        one.to_bits(),
                        "eval_one: {want} vs {one} for {cfg:?} / {pc:?} on {arch:?}"
                    );
                }
            });
        }
    }

    /// dim = 0 with a real block produces NaN (0/0) in *both* paths, with
    /// the same bit pattern; block-boundary dims stay finite and exact.
    #[test]
    fn zero_dim_quantization_is_nan_in_both_paths() {
        let model = PerfModel::new(H100_SXM.clone());
        let pc = ProblemCosts {
            flops: 1e12,
            fused_bytes: 1e9,
            unfused_bytes: 2e9,
            n_ops: 2.0,
            matmul_like: true,
            dom: DominantDims::MatmulMn { m: 0, n: 128, batch: 1 },
        };
        let cfg = CandidateConfig::library((128, 128, 64), DType::Fp16);
        let want = model.candidate_ms_with(&pc, &cfg);
        let got = CompiledCosts::from_costs(&model, &pc).candidate_ms(&cfg);
        assert!(want.is_nan() && got.is_nan(), "{want} vs {got}");
        assert_eq!(want.to_bits(), got.to_bits());
    }

    /// u64::MAX dims survive the guards without overflow when the block
    /// products stay in range (unit tile, single SM), identically on both
    /// paths.
    #[test]
    fn u64_max_dim_guards_agree() {
        let mut gpu = H100_SXM.clone();
        gpu.sm_count = 1;
        let model = PerfModel::new(gpu);
        let pc = ProblemCosts {
            flops: 1e12,
            fused_bytes: 1e9,
            unfused_bytes: 2e9,
            n_ops: 1.0,
            matmul_like: true,
            dom: DominantDims::MatmulMn { m: u64::MAX, n: 1, batch: 1 },
        };
        let cfg = CandidateConfig::library((1, 1, 1), DType::Fp32);
        let want = model.candidate_ms_with(&pc, &cfg);
        let got = CompiledCosts::from_costs(&model, &pc).candidate_ms(&cfg);
        assert!(want.is_finite());
        assert_eq!(want.to_bits(), got.to_bits());
    }

    #[test]
    fn compiled_cache_covers_suite_and_matches_scalar() {
        let problems = suite();
        let model = PerfModel::new(H100_SXM.clone());
        let compiled = CompiledCostModel::compile(&model, &problems);
        assert_eq!(compiled.len(), problems.len());
        let cfg = CandidateConfig::library((128, 64, 32), DType::Bf16);
        for (i, p) in problems.iter().enumerate() {
            let want = model.candidate_ms(p, &cfg);
            let got = compiled.problem(i).candidate_ms(&cfg);
            assert_eq!(want.to_bits(), got.to_bits(), "{}", p.id);
        }
        assert!(compiled.get(problems.len()).is_none());
    }

    #[test]
    fn config_batch_reuse_keeps_columns_aligned() {
        let mut b = ConfigBatch::new();
        let a = CandidateConfig::library((128, 128, 64), DType::Fp16);
        let mut c = CandidateConfig::library((256, 128, 32), DType::Fp32);
        c.scheduler = SchedulerKind::StreamK;
        c.fused_epilogue = false;
        c.fusion_coverage = 0.4;
        b.extend(&[a.clone(), c.clone()]);
        assert_eq!(b.len(), 2);
        b.clear();
        assert!(b.is_empty());
        b.push(&c);
        assert_eq!(b.len(), 1);
        let problems = suite();
        let model = PerfModel::new(H100_SXM.clone());
        let cc = CompiledCosts::lower(&model, &problems[0]);
        let got = cc.eval_batch(&b);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].to_bits(), model.candidate_ms(&problems[0], &c).to_bits());
    }

    #[test]
    #[should_panic(expected = "output slice must match")]
    fn eval_into_rejects_mismatched_output() {
        let problems = suite();
        let model = PerfModel::new(H100_SXM.clone());
        let cc = CompiledCosts::lower(&model, &problems[0]);
        let b = ConfigBatch::from_configs(&[CandidateConfig::library((64, 64, 32), DType::Fp32)]);
        let mut out = [0.0; 2];
        cc.eval_into(&b, &mut out);
    }
}
