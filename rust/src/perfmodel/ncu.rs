//! Simulated Nsight Compute profile — the *Measure* input to MANTIS
//! (paper §4.2 step 1). Derived from the analytical model so the profile
//! is consistent with the simulated runtime: a kernel near its compute
//! roofline shows high SM throughput, a memory-bound one shows high DRAM
//! throughput, and a badly-tiled one shows low occupancy.

use super::{CandidateConfig, PerfModel};
use crate::kernelbench::Problem;
use crate::util::json::Json;

/// The metric summary MANTIS consumes (a stand-in for `ncu --summary`).
#[derive(Debug, Clone)]
pub struct NcuProfile {
    /// Kernel duration (ms) as NCU would report it.
    pub duration_ms: f64,
    /// SM compute throughput, % of peak.
    pub sm_throughput_pct: f64,
    /// DRAM throughput, % of peak.
    pub dram_throughput_pct: f64,
    /// Achieved occupancy, %.
    pub occupancy_pct: f64,
    /// DRAM bytes moved.
    pub dram_bytes: u64,
    /// Kernel launch count observed in the profile.
    pub kernel_launches: u64,
    /// Launch signatures (library-pattern matching input for the
    /// PyTorch-only detector, paper §5.8).
    pub kernel_names: Vec<String>,
}

impl NcuProfile {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("duration_ms", self.duration_ms)
            .set("sm_throughput_pct", self.sm_throughput_pct)
            .set("dram_throughput_pct", self.dram_throughput_pct)
            .set("occupancy_pct", self.occupancy_pct)
            .set("dram_bytes", self.dram_bytes)
            .set("kernel_launches", self.kernel_launches)
            .set(
                "kernel_names",
                Json::Arr(self.kernel_names.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        o
    }
}

impl PerfModel {
    /// Profile a candidate: consistent with `candidate_ms`.
    pub fn profile_candidate(
        &self,
        problem: &Problem,
        cfg: &CandidateConfig,
        duration_ms: f64,
        kernel_names: Vec<String>,
    ) -> NcuProfile {
        let flops = problem.flops() as f64;
        let bytes = problem.fused_bytes() as f64;
        let peak = if problem.is_matmul_like() && cfg.tensor_cores {
            match cfg.compute_dtype {
                crate::dsl::DType::Fp16 | crate::dsl::DType::Bf16 => {
                    self.gpu.effective_fp16_flops()
                }
                _ => self.gpu.effective_tf32_flops(),
            }
        } else {
            self.gpu.effective_fp32_flops()
        };
        let dur_s = (duration_ms / 1e3).max(1e-9);
        let sm = (flops / dur_s / peak * 100.0).min(100.0);
        let dram = (bytes / dur_s / self.gpu.effective_bandwidth() * 100.0).min(100.0);
        // Occupancy proxy: deep pipelines with moderate tiles occupy well.
        let tile_cost = (cfg.tile.0 * cfg.tile.1) as f64 / (256.0 * 256.0);
        let occ = (100.0 * (1.0 - 0.45 * tile_cost) * (0.7 + 0.1 * cfg.stages.min(3) as f64))
            .clamp(8.0, 100.0);
        let launches = 1 + ((problem.ops.len() as f64 - 1.0)
            * (1.0 - cfg.fusion_coverage.clamp(0.0, 1.0))) as u64;
        NcuProfile {
            duration_ms: duration_ms,
            sm_throughput_pct: sm,
            dram_throughput_pct: dram,
            occupancy_pct: occ,
            dram_bytes: bytes as u64,
            kernel_launches: launches,
            kernel_names,
        }
    }
}

/// Known library kernel-name prefixes (paper §5.8: `at::native::`, cublas,
/// cudnn, …) — the static PyTorch-only detector matches against these.
pub const LIBRARY_KERNEL_PATTERNS: &[&str] = &[
    "at::native::",
    "cublas",
    "cutlass::Kernel", // cuBLAS-dispatched cutlass instantiations
    "cudnn",
    "void at_cuda_detail",
    "triton__", // torch.compile generated
    "vectorized_elementwise_kernel",
    "reduce_kernel",
];

/// Does a kernel-launch signature match a known library pattern?
pub fn is_library_kernel(name: &str) -> bool {
    LIBRARY_KERNEL_PATTERNS.iter().any(|p| name.contains(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::DType;
    use crate::kernelbench::{find, suite};
    use crate::perfmodel::CandidateConfig;
    use crate::sol::H100_SXM;

    #[test]
    fn compute_bound_profile_shows_high_sm() {
        let m = PerfModel::new(H100_SXM.clone());
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let cfg = CandidateConfig::library((128, 128, 64), DType::Fp32);
        let t = m.candidate_ms(p, &cfg);
        let prof = m.profile_candidate(p, &cfg, t, vec!["ucutlass_gemm".into()]);
        assert!(prof.sm_throughput_pct > 60.0, "{}", prof.sm_throughput_pct);
        assert!(prof.dram_throughput_pct < 30.0, "{}", prof.dram_throughput_pct);
    }

    #[test]
    fn memory_bound_profile_shows_high_dram() {
        let m = PerfModel::new(H100_SXM.clone());
        let s = suite();
        let p = &s[find(&s, "L1-23").unwrap()];
        let cfg = CandidateConfig::library((128, 128, 32), DType::Fp32);
        let t = m.candidate_ms(p, &cfg);
        let prof = m.profile_candidate(p, &cfg, t, vec!["softmax_custom".into()]);
        assert!(prof.dram_throughput_pct > 50.0, "{}", prof.dram_throughput_pct);
    }

    #[test]
    fn library_patterns_match() {
        assert!(is_library_kernel("void at::native::vectorized_elementwise_kernel<4, ...>"));
        assert!(is_library_kernel("ampere_sgemm_128x64_tn [cublas]"));
        assert!(!is_library_kernel("ucutlass_3fa9c2d1::kernel_impl_stage0"));
    }

    #[test]
    fn profile_json_roundtrips() {
        let prof = NcuProfile {
            duration_ms: 1.0,
            sm_throughput_pct: 50.0,
            dram_throughput_pct: 20.0,
            occupancy_pct: 75.0,
            dram_bytes: 1000,
            kernel_launches: 2,
            kernel_names: vec!["k1".into()],
        };
        let j = prof.to_json();
        assert_eq!(j.get("kernel_launches").unwrap().as_u64(), Some(2));
    }
}
