//! SOL report rendering: the Appendix A.2 markdown layout plus the
//! structured JSON block the agent runtime consumes.

use super::{Bottleneck, SolAnalysis};
use crate::kernelbench::Problem;
use crate::util::json::Json;

/// Render the full markdown SOL report (Appendix A.2 layout) with the FP16
/// augmentation section and structured JSON output.
pub fn render_report(problem: &Problem, a: &SolAnalysis) -> String {
    let mut s = String::with_capacity(4096);
    let tf32_peak = a.peak_flops / 1e12;
    let fp16_peak = tf32_peak * (a.t_sol_ms.max(1e-12) / a.t_sol_fp16_ms.max(1e-12)).max(1.0);
    let bw_tbps = a.peak_bw / 1e12;

    s.push_str("# Speed-of-Light (SOL) Analysis\n\n");
    s.push_str("## 1. Problem Characterization\n\n");
    s.push_str(&format!(
        "Problem {} ({}): {}\n\nReference op graph:\n",
        problem.id, problem.name, problem.rationale
    ));
    for op in &problem.ops {
        s.push_str(&format!(
            "- {}: {:.4e} FLOPs, {:.4e} best-case bytes\n",
            op.name(),
            op.flops() as f64,
            op.bytes(problem.dtype) as f64
        ));
    }
    s.push_str(&format!(
        "\nTotal FLOPs = {:.4e}\nTotal bytes (fused, best case) = {:.4e}\nArithmetic intensity = {:.1} FLOPs/byte\n\n",
        a.total_flops as f64, a.total_bytes as f64, a.arithmetic_intensity
    ));

    s.push_str("## 2. Hardware Limits (Clock-aware)\n\n");
    s.push_str(&format!(
        "Effective peak compute: {:.2} TFLOP/s ({:?})\nEffective peak bandwidth: {:.2} TB/s\n\n",
        tf32_peak, a.precision, bw_tbps
    ));

    s.push_str("## 3. Theoretical Minimum Time\n\n");
    s.push_str(&format!(
        "T_compute = {:.4} ms\nT_mem     = {:.4} ms\nSOL = max(T_compute, T_mem) = {:.4} ms\n\n",
        a.t_compute_ms, a.t_mem_ms, a.t_sol_ms
    ));

    s.push_str("## 4. Roofline Analysis\n\n");
    s.push_str(&format!(
        "Ridge point = {:.1} FLOPs/byte; kernel AI = {:.1} => {}\n\n",
        a.ridge_point,
        a.arithmetic_intensity,
        match a.bottleneck {
            Bottleneck::Compute => "Compute-bound region on the roofline plot.",
            Bottleneck::Memory => "Memory-bound region on the roofline plot.",
        }
    ));

    s.push_str("## 5. Summary\n\n");
    s.push_str(&format!(
        "=> Theoretical minimum execution time (SOL): {:.4} ms\n=> Primary bottleneck: {}\n\n",
        a.t_sol_ms,
        match a.bottleneck {
            Bottleneck::Compute => "Compute throughput",
            Bottleneck::Memory => "Memory bandwidth",
        }
    ));

    s.push_str("# FP16 Augmentation\n\n");
    s.push_str(&format!(
        "Kernel may cast to FP16 on-chip (2x TC throughput); inputs/outputs remain FP32 in DRAM.\n\
         FP16 SOL = {:.4} ms (peak {:.2} TFLOP/s; memory unchanged)\nFP16/{:?} ratio: {:.3}x\n\n",
        a.t_sol_fp16_ms,
        fp16_peak,
        a.precision,
        a.t_sol_fp16_ms / a.t_sol_ms
    ));

    s.push_str("# Structured JSON Output\n\n```json\n");
    s.push_str(&to_json(a).to_pretty());
    s.push_str("\n```\n");
    s
}

/// The structured JSON block (Appendix A.2 tail).
pub fn to_json(a: &SolAnalysis) -> Json {
    let mut o = Json::obj();
    o.set("problem_id", a.problem_id.clone())
        .set("total_flops", a.total_flops)
        .set("total_bytes", a.total_bytes)
        .set("arithmetic_intensity", a.arithmetic_intensity)
        .set("theoretical_runtime_ms", a.t_sol_ms)
        .set("theoretical_runtime_ms_fp16", a.t_sol_fp16_ms)
        .set("peak_tflops_effective", a.peak_flops / 1e12)
        .set("peak_bw_tbps", a.peak_bw / 1e12)
        .set("t_compute_ms", a.t_compute_ms)
        .set("t_mem_ms", a.t_mem_ms)
        .set("ridge_point", a.ridge_point)
        .set(
            "bottleneck",
            match a.bottleneck {
                Bottleneck::Compute => "compute",
                Bottleneck::Memory => "memory",
            },
        );
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelbench::{find, suite};
    use crate::sol::{analyze, H100_SXM};

    #[test]
    fn report_has_all_sections() {
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let a = analyze(p, &H100_SXM);
        let r = render_report(p, &a);
        for section in [
            "# Speed-of-Light (SOL) Analysis",
            "## 1. Problem Characterization",
            "## 2. Hardware Limits",
            "## 3. Theoretical Minimum Time",
            "## 4. Roofline Analysis",
            "## 5. Summary",
            "# FP16 Augmentation",
            "# Structured JSON Output",
        ] {
            assert!(r.contains(section), "missing {section}");
        }
        assert!(r.contains("Compute-bound"));
    }

    #[test]
    fn json_parses_back() {
        let s = suite();
        let a = analyze(&s[0], &H100_SXM);
        let j = to_json(&a);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("problem_id").unwrap().as_str(), Some("L1-1"));
        assert!(parsed.get("theoretical_runtime_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
