//! Speed-of-Light (SOL) analysis (paper §4.1): a roofline-style
//! first-principles bound over the full reference computation of a problem.
//!
//! The four steps of the paper's analysis:
//! 1. *Problem characterization* — FLOPs + best-case DRAM bytes
//!    ([`crate::kernelbench::Problem`] supplies both).
//! 2. *Hardware limits* — peak compute/bandwidth scaled by locked clocks
//!    ([`hw::GpuSpec`]).
//! 3. *Roofline bound* — `t_SOL = max(T_compute, T_mem)`.
//! 4. *Bottleneck classification* — arithmetic intensity vs. the ridge
//!    point.
//!
//! The FP32/TF32 estimate steers optimization; the FP16 *augmentation*
//! (tighter, since optimized kernels may drop to FP16 math while I/O stays
//! FP32) drives budget scheduling and integrity checking (paper §4.1, §5.8).

pub mod hw;
pub mod report;

pub use hw::{GpuSpec, H100_SXM};
pub use report::render_report;

use crate::kernelbench::Problem;

/// Which peak the compute bound uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrecisionAssumption {
    /// FP32 problem formulation with TF32 tensor-core throughput (the
    /// paper's steering default: PyTorch allows TF32 on H100).
    Tf32,
    /// FP16 tensor-core throughput with FP32 DRAM traffic (the paper's
    /// scheduling/integrity bound).
    Fp16Augmented,
    /// Scalar FP32 (no tensor cores) — for non-matmul workloads.
    Fp32Cuda,
}

/// Bottleneck classification from roofline analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Compute,
    Memory,
}

/// A complete SOL analysis for one problem (the "compact structured report"
/// of §4.1; `report::render_report` renders the Appendix A.2 markdown).
#[derive(Debug, Clone)]
pub struct SolAnalysis {
    pub problem_id: String,
    pub total_flops: u64,
    pub total_bytes: u64,
    pub arithmetic_intensity: f64,
    /// Effective (clock-scaled) peak in FLOP/s for the steering precision.
    pub peak_flops: f64,
    /// Effective peak DRAM bandwidth in B/s.
    pub peak_bw: f64,
    pub t_compute_ms: f64,
    pub t_mem_ms: f64,
    /// Lower-bound runtime, TF32 formulation (ms).
    pub t_sol_ms: f64,
    /// FP16-augmented lower bound (ms) — tighter compute peak, same bytes.
    pub t_sol_fp16_ms: f64,
    pub ridge_point: f64,
    pub bottleneck: Bottleneck,
    pub precision: PrecisionAssumption,
}

impl SolAnalysis {
    /// SOL gap g = t_best / t_SOL (paper §4.2). Values ≈ 1 mean near-SOL.
    pub fn gap(&self, t_best_ms: f64) -> f64 {
        t_best_ms / self.t_sol_ms
    }

    /// FP16-based gap, used by scheduling and integrity checking.
    pub fn gap_fp16(&self, t_best_ms: f64) -> f64 {
        t_best_ms / self.t_sol_fp16_ms
    }
}

/// Run the SOL analysis for a problem on the given GPU.
pub fn analyze(problem: &Problem, gpu: &GpuSpec) -> SolAnalysis {
    let flops = problem.flops();
    let bytes = problem.fused_bytes();
    let ai = flops as f64 / bytes as f64;

    // Matmul-like work rides the tensor cores (TF32 for FP32 inputs);
    // everything else is bounded by the CUDA-core FP32 pipe.
    let precision = if problem.is_matmul_like() {
        PrecisionAssumption::Tf32
    } else {
        PrecisionAssumption::Fp32Cuda
    };
    let peak_flops = match precision {
        PrecisionAssumption::Tf32 => gpu.effective_tf32_flops(),
        PrecisionAssumption::Fp16Augmented => gpu.effective_fp16_flops(),
        PrecisionAssumption::Fp32Cuda => gpu.effective_fp32_flops(),
    };
    let peak_bw = gpu.effective_bandwidth();

    let t_compute = flops as f64 / peak_flops;
    let t_mem = bytes as f64 / peak_bw;
    let t_sol = t_compute.max(t_mem);

    // FP16 augmentation: 2× TC throughput for matmul-like work; memory
    // traffic unchanged (I/O stays FP32 at the DRAM boundary). Non-matmul
    // work gains nothing from FP16 tensor cores.
    let fp16_peak = if problem.is_matmul_like() {
        gpu.effective_fp16_flops()
    } else {
        peak_flops
    };
    let t_sol_fp16 = (flops as f64 / fp16_peak).max(t_mem);

    let ridge = peak_flops / peak_bw;
    SolAnalysis {
        problem_id: problem.id.to_string(),
        total_flops: flops,
        total_bytes: bytes,
        arithmetic_intensity: ai,
        peak_flops,
        peak_bw,
        t_compute_ms: t_compute * 1e3,
        t_mem_ms: t_mem * 1e3,
        t_sol_ms: t_sol * 1e3,
        t_sol_fp16_ms: t_sol_fp16 * 1e3,
        ridge_point: ridge,
        bottleneck: if ai >= ridge { Bottleneck::Compute } else { Bottleneck::Memory },
        precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelbench::{find, suite};

    /// Appendix A.2 reference numbers for Problem 001 (4096³ FP32 GEMM on
    /// H100 at 1500 MHz locked clocks): SOL ≈ 0.367 ms (TF32), 0.1834 ms
    /// (FP16), T_mem ≈ 0.060 ms, AI ≈ 682.6, ridge ≈ 111.9.
    #[test]
    fn matches_appendix_a2_report() {
        let s = suite();
        let p = &s[find(&s, "L1-1").unwrap()];
        let a = analyze(p, &H100_SXM);
        assert!((a.t_compute_ms - 0.367).abs() < 0.002, "t_compute={}", a.t_compute_ms);
        assert!((a.t_mem_ms - 0.0601).abs() < 0.001, "t_mem={}", a.t_mem_ms);
        assert!((a.t_sol_ms - 0.367).abs() < 0.002);
        assert!((a.t_sol_fp16_ms - 0.1834).abs() < 0.001, "fp16={}", a.t_sol_fp16_ms);
        assert!((a.arithmetic_intensity - 682.6).abs() < 1.0);
        assert!((a.ridge_point - 111.9).abs() < 1.0, "ridge={}", a.ridge_point);
        assert_eq!(a.bottleneck, Bottleneck::Compute);
    }

    #[test]
    fn softmax_is_memory_bound() {
        let s = suite();
        let p = &s[find(&s, "L1-23").unwrap()];
        let a = analyze(p, &H100_SXM);
        assert_eq!(a.bottleneck, Bottleneck::Memory);
        assert!((a.t_sol_ms - a.t_mem_ms).abs() < 1e-9);
    }

    #[test]
    fn fp16_bound_never_looser() {
        let s = suite();
        for p in &s {
            let a = analyze(p, &H100_SXM);
            assert!(a.t_sol_fp16_ms <= a.t_sol_ms + 1e-12, "{}", p.id);
            assert!(a.t_sol_fp16_ms >= a.t_mem_ms - 1e-12, "{}", p.id);
        }
    }

    #[test]
    fn gap_identity() {
        let s = suite();
        let p = &s[0];
        let a = analyze(p, &H100_SXM);
        assert!((a.gap(a.t_sol_ms) - 1.0).abs() < 1e-12);
        assert!(a.gap(2.0 * a.t_sol_ms) > 1.9);
    }
}
