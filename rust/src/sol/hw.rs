//! Hardware limits, clock-aware (paper §4.1 step 2, Appendix A.2 §2).
//!
//! Peaks come from published specifications at max clocks; effective peaks
//! scale linearly with the locked application clock, exactly as the
//! Appendix A.2 report does: `494.7 TFLOP/s × 1500/1980 = 374.77 TFLOP/s`.

/// GPU specification with published peaks (dense, no sparsity) at max clock.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Number of SMs (wave-quantization granularity for the perf model).
    pub sm_count: u64,
    /// Max SM clock in MHz.
    pub max_sm_clock_mhz: f64,
    /// Locked application clock in MHz (the paper locks clocks; default 1500).
    pub locked_sm_clock_mhz: f64,
    /// Peak TF32 tensor-core throughput at max clock (TFLOP/s, dense).
    pub peak_tf32_tflops: f64,
    /// Peak FP16/BF16 tensor-core throughput at max clock (TFLOP/s, dense).
    pub peak_fp16_tflops: f64,
    /// Peak FP8 tensor-core throughput at max clock (TFLOP/s, dense).
    pub peak_fp8_tflops: f64,
    /// Peak scalar FP32 (CUDA-core) throughput at max clock (TFLOP/s).
    pub peak_fp32_tflops: f64,
    /// Peak FP64 throughput at max clock (TFLOP/s).
    pub peak_fp64_tflops: f64,
    /// Peak DRAM bandwidth (GB/s) at max memory clock.
    pub peak_bw_gbps: f64,
    /// Memory clock ratio (locked/max); HBM is usually not down-clocked.
    pub mem_clock_ratio: f64,
    /// Shared memory per SM (bytes) — feeds occupancy estimates.
    pub smem_per_sm: u64,
    /// L2 cache size (bytes).
    pub l2_bytes: u64,
}

impl GpuSpec {
    /// SM clock scaling factor.
    pub fn clock_ratio(&self) -> f64 {
        self.locked_sm_clock_mhz / self.max_sm_clock_mhz
    }

    pub fn effective_tf32_flops(&self) -> f64 {
        self.peak_tf32_tflops * 1e12 * self.clock_ratio()
    }

    pub fn effective_fp16_flops(&self) -> f64 {
        self.peak_fp16_tflops * 1e12 * self.clock_ratio()
    }

    pub fn effective_fp8_flops(&self) -> f64 {
        self.peak_fp8_tflops * 1e12 * self.clock_ratio()
    }

    pub fn effective_fp32_flops(&self) -> f64 {
        self.peak_fp32_tflops * 1e12 * self.clock_ratio()
    }

    pub fn effective_fp64_flops(&self) -> f64 {
        self.peak_fp64_tflops * 1e12 * self.clock_ratio()
    }

    /// Effective DRAM bandwidth in B/s.
    pub fn effective_bandwidth(&self) -> f64 {
        self.peak_bw_gbps * 1e9 * self.mem_clock_ratio
    }
}

/// NVIDIA H100 80GB SXM (Hopper, SM90a) — the paper's testbed, locked to
/// 1500 MHz as in §5.2 / Appendix A.2.
pub const H100_SXM: GpuSpec = GpuSpec {
    name: "NVIDIA H100 80GB HBM3 (SXM)",
    sm_count: 132,
    max_sm_clock_mhz: 1980.0,
    locked_sm_clock_mhz: 1500.0,
    peak_tf32_tflops: 494.7,
    peak_fp16_tflops: 989.4,
    peak_fp8_tflops: 1978.9,
    peak_fp32_tflops: 66.9,
    peak_fp64_tflops: 33.5,
    peak_bw_gbps: 3350.0,
    mem_clock_ratio: 1.0,
    smem_per_sm: 228 * 1024,
    l2_bytes: 50 * 1024 * 1024,
};

/// NVIDIA A100 80GB SXM (Ampere, SM80) — used by ablations / arch-gating
/// tests; peaks from the published datasheet.
pub const A100_SXM: GpuSpec = GpuSpec {
    name: "NVIDIA A100 80GB HBM2e (SXM)",
    sm_count: 108,
    max_sm_clock_mhz: 1410.0,
    locked_sm_clock_mhz: 1410.0,
    peak_tf32_tflops: 156.0,
    peak_fp16_tflops: 312.0,
    peak_fp8_tflops: 0.0,
    peak_fp32_tflops: 19.5,
    peak_fp64_tflops: 9.7,
    peak_bw_gbps: 2039.0,
    mem_clock_ratio: 1.0,
    smem_per_sm: 164 * 1024,
    l2_bytes: 40 * 1024 * 1024,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_clock_scaling_matches_appendix() {
        // Appendix A.2: 494.7 × (1500/1980) = 374.77 TFLOP/s TF32;
        // 989.4 × ratio = 749.55 TFLOP/s FP16.
        let tf32 = H100_SXM.effective_tf32_flops() / 1e12;
        let fp16 = H100_SXM.effective_fp16_flops() / 1e12;
        assert!((tf32 - 374.77).abs() < 0.05, "tf32={tf32}");
        assert!((fp16 - 749.55).abs() < 0.1, "fp16={fp16}");
        assert!((H100_SXM.effective_bandwidth() / 1e12 - 3.35).abs() < 1e-9);
    }

    #[test]
    fn fp16_is_twice_tf32() {
        let r = H100_SXM.effective_fp16_flops() / H100_SXM.effective_tf32_flops();
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn a100_unlocked() {
        assert!((A100_SXM.clock_ratio() - 1.0).abs() < 1e-12);
    }
}
