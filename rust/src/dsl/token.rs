//! Lexer for µCUTLASS. Clean, unquoted syntax — string quotes appear only
//! in `custom('expr', ...)` expressions (paper Appendix A.1).

use super::error::{DslError, DslErrorKind};

/// A token with its source span (byte offsets) for error reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokKind,
    pub start: usize,
    pub end: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword: `gemm`, `fp16`, `RowMajor`, `with_tile`, …
    Ident(String),
    /// Integer literal.
    Int(u64),
    /// Float literal (only in epilogue params / scaling).
    Float(f64),
    /// Single-quoted string, for `custom('expr')`.
    Str(String),
    LParen,
    RParen,
    LBrace,
    RBrace,
    Comma,
    Colon,
    Equals,
    Dot,
    /// The epilogue-composition operator `>>`.
    Chain,
    Eof,
}

impl TokKind {
    pub fn describe(&self) -> String {
        match self {
            TokKind::Ident(s) => format!("identifier `{s}`"),
            TokKind::Int(v) => format!("integer `{v}`"),
            TokKind::Float(v) => format!("float `{v}`"),
            TokKind::Str(s) => format!("string '{s}'"),
            TokKind::LParen => "`(`".into(),
            TokKind::RParen => "`)`".into(),
            TokKind::LBrace => "`{`".into(),
            TokKind::RBrace => "`}`".into(),
            TokKind::Comma => "`,`".into(),
            TokKind::Colon => "`:`".into(),
            TokKind::Equals => "`=`".into(),
            TokKind::Dot => "`.`".into(),
            TokKind::Chain => "`>>`".into(),
            TokKind::Eof => "end of input".into(),
        }
    }
}

/// Tokenize a µCUTLASS source string. `#`-comments run to end of line.
pub fn lex(src: &str) -> Result<Vec<Token>, DslError> {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'#' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'(' => {
                toks.push(Token { kind: TokKind::LParen, start: i, end: i + 1 });
                i += 1;
            }
            b')' => {
                toks.push(Token { kind: TokKind::RParen, start: i, end: i + 1 });
                i += 1;
            }
            b'{' => {
                toks.push(Token { kind: TokKind::LBrace, start: i, end: i + 1 });
                i += 1;
            }
            b'}' => {
                toks.push(Token { kind: TokKind::RBrace, start: i, end: i + 1 });
                i += 1;
            }
            b',' => {
                toks.push(Token { kind: TokKind::Comma, start: i, end: i + 1 });
                i += 1;
            }
            b':' => {
                toks.push(Token { kind: TokKind::Colon, start: i, end: i + 1 });
                i += 1;
            }
            b'=' => {
                toks.push(Token { kind: TokKind::Equals, start: i, end: i + 1 });
                i += 1;
            }
            b'.' => {
                toks.push(Token { kind: TokKind::Dot, start: i, end: i + 1 });
                i += 1;
            }
            b'>' => {
                if i + 1 < b.len() && b[i + 1] == b'>' {
                    toks.push(Token { kind: TokKind::Chain, start: i, end: i + 2 });
                    i += 2;
                } else {
                    return Err(DslError::at(
                        DslErrorKind::Lex,
                        i,
                        "stray `>`",
                        "the epilogue-composition operator is `>>` (two angle brackets)",
                    ));
                }
            }
            b'\'' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                while i < b.len() && b[i] != b'\'' {
                    s.push(b[i] as char);
                    i += 1;
                }
                if i >= b.len() {
                    return Err(DslError::at(
                        DslErrorKind::Lex,
                        start,
                        "unterminated string literal",
                        "custom() expressions use single quotes: custom('relu(x) * 2')",
                    ));
                }
                i += 1; // closing quote
                toks.push(Token { kind: TokKind::Str(s), start, end: i });
            }
            b'-' | b'0'..=b'9' => {
                let start = i;
                if c == b'-' {
                    i += 1;
                }
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let mut is_float = false;
                if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    is_float = true;
                    i += 1;
                    while i < b.len() && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                // exponent
                if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
                    let save = i;
                    i += 1;
                    if i < b.len() && (b[i] == b'+' || b[i] == b'-') {
                        i += 1;
                    }
                    if i < b.len() && b[i].is_ascii_digit() {
                        is_float = true;
                        while i < b.len() && b[i].is_ascii_digit() {
                            i += 1;
                        }
                    } else {
                        i = save;
                    }
                }
                let text = &src[start..i];
                let kind = if is_float || text.starts_with('-') && text.contains('.') {
                    TokKind::Float(text.parse().map_err(|_| {
                        DslError::at(DslErrorKind::Lex, start, "malformed number", "")
                    })?)
                } else if let Ok(v) = text.parse::<u64>() {
                    TokKind::Int(v)
                } else {
                    TokKind::Float(text.parse().map_err(|_| {
                        DslError::at(DslErrorKind::Lex, start, "malformed number", "")
                    })?)
                };
                toks.push(Token { kind, start, end: i });
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::Ident(src[start..i].to_string()),
                    start,
                    end: i,
                });
            }
            _ => {
                return Err(DslError::at(
                    DslErrorKind::Lex,
                    i,
                    &format!("unexpected character `{}`", c as char),
                    "µCUTLASS uses unquoted identifiers, `.` chaining, and `>>` epilogues",
                ));
            }
        }
    }
    toks.push(Token { kind: TokKind::Eof, start: b.len(), end: b.len() });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_kernel_line() {
        let toks = lex("gemm().with_arch(sm_90a) >> relu()").unwrap();
        let kinds: Vec<_> = toks.iter().map(|t| &t.kind).collect();
        assert!(matches!(kinds[0], TokKind::Ident(s) if s == "gemm"));
        assert!(kinds.iter().any(|k| matches!(k, TokKind::Chain)));
    }

    #[test]
    fn lexes_numbers() {
        let toks = lex("with_tile(m=128, n=64) scale(0.5) elu(-1.5)").unwrap();
        assert!(toks.iter().any(|t| t.kind == TokKind::Int(128)));
        assert!(toks.iter().any(|t| t.kind == TokKind::Float(0.5)));
        assert!(toks.iter().any(|t| t.kind == TokKind::Float(-1.5)));
    }

    #[test]
    fn lexes_custom_string() {
        let toks = lex("custom('x * 2 + y', inputs={'y': 'tensor'})").unwrap();
        assert!(toks.iter().any(|t| matches!(&t.kind, TokKind::Str(s) if s == "x * 2 + y")));
    }

    #[test]
    fn rejects_stray_angle() {
        assert!(lex("gemm() > relu()").is_err());
    }

    #[test]
    fn comments_skipped() {
        let toks = lex("gemm() # a comment\n.with_arch(sm_90a)").unwrap();
        assert!(!toks
            .iter()
            .any(|t| matches!(&t.kind, TokKind::Ident(s) if s == "comment")));
    }
}
