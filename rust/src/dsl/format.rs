//! Pretty-printer: render a lowered `ProgramIr` back to canonical µCUTLASS
//! source. Used for traceability (run logs store canonicalized programs)
//! and tested by the parse→lower→print→parse→lower roundtrip property.

use std::fmt::Write as _;

use super::ir::*;

/// Render a program back to canonical DSL source.
pub fn format_program(ir: &ProgramIr) -> String {
    match ir {
        ProgramIr::Kernel(k) => format_kernel(k),
        ProgramIr::Pipeline(p) => {
            let stages: Vec<String> = p
                .stages
                .iter()
                .map(|s| match s {
                    StageIr::Kernel(k) => format_kernel(k),
                    StageIr::Transpose { target, from_layout, to_layout, from_dtype, to_dtype } => {
                        match (from_dtype, to_dtype) {
                            (Some(f), Some(t)) => {
                                format!("transpose({target}, {from_layout}, {to_layout}, {f}, {t})")
                            }
                            _ => format!("transpose({target}, {from_layout}, {to_layout})"),
                        }
                    }
                })
                .collect();
            format!("pipeline({})", stages.join(", "))
        }
    }
}

fn gemm_layout(l: GemmLayout) -> &'static str {
    match l {
        GemmLayout::RowMajor => "RowMajor",
        GemmLayout::ColumnMajor => "ColumnMajor",
    }
}

fn format_kernel(k: &ConfigIr) -> String {
    let mut s = String::new();
    match &k.op {
        Operation::Gemm => s.push_str("gemm()"),
        Operation::BatchedGemm => s.push_str("batched_gemm()"),
        Operation::GroupedGemm { expert_count } => {
            let _ = write!(s, "grouped_gemm(expert_count={expert_count})");
        }
        Operation::Conv2dFprop { kh, kw } => {
            let _ = write!(s, "conv2d_fprop(kernel_h={kh}, kernel_w={kw})");
        }
        Operation::Conv2dDgrad { kh, kw } => {
            let _ = write!(s, "conv2d_dgrad(kernel_h={kh}, kernel_w={kw})");
        }
        Operation::Conv2dWgrad { kh, kw } => {
            let _ = write!(s, "conv2d_wgrad(kernel_h={kh}, kernel_w={kw})");
        }
        Operation::Conv1dFprop { kw } => {
            let _ = write!(s, "conv1d_fprop(kernel_w={kw})");
        }
        Operation::DepthwiseConv1d { kw } => {
            let _ = write!(s, "depthwise_conv1d(kernel_w={kw})");
        }
        Operation::GroupConv1d { kw, groups } => {
            let _ = write!(s, "group_conv1d(kernel_w={kw}, groups={groups})");
        }
        Operation::Conv3dFprop { kd, kh, kw } => {
            let _ = write!(s, "conv3d_fprop(kernel_d={kd}, kernel_h={kh}, kernel_w={kw})");
        }
        Operation::Conv3dDgrad { kd, kh, kw } => {
            let _ = write!(s, "conv3d_dgrad(kernel_d={kd}, kernel_h={kh}, kernel_w={kw})");
        }
        Operation::Conv3dWgrad { kd, kh, kw } => {
            let _ = write!(s, "conv3d_wgrad(kernel_d={kd}, kernel_h={kh}, kernel_w={kw})");
        }
        Operation::DepthwiseConv2d { kh, kw } => {
            let _ = write!(s, "depthwise_conv2d(kernel_h={kh}, kernel_w={kw})");
        }
        Operation::GroupConv2d { kh, kw, groups } => {
            let _ = write!(s, "group_conv2d(kernel_h={kh}, kernel_w={kw}, groups={groups})");
        }
        Operation::GroupConv3d { kd, kh, kw, groups } => {
            let _ = write!(
                s,
                "group_conv3d(kernel_d={kd}, kernel_h={kh}, kernel_w={kw}, groups={groups})"
            );
        }
    }

    if let (Some(din), Some(dacc), Some(dout)) = (k.dtype_input, k.dtype_acc, k.dtype_output) {
        let _ = write!(s, ".with_dtype(input={din}, acc={dacc}, output={dout})");
    }
    if let (Some(a), Some(b), Some(c)) = (k.layout_a, k.layout_b, k.layout_c) {
        let _ = write!(
            s,
            ".with_layout(A={}, B={}, C={})",
            gemm_layout(a),
            gemm_layout(b),
            gemm_layout(c)
        );
    }
    if let Some((i, f, o)) = &k.conv_layouts {
        let _ = write!(s, ".with_layout(input={i}, filter={f}, output={o})");
    }
    if let Some(arch) = k.arch {
        let _ = write!(s, ".with_arch({arch})");
    }
    if let Some(t) = k.tile {
        let call = match k.tile_spelling {
            Some(TileSpelling::WithThreadblockShape) => "with_threadblockshape",
            _ => "with_tile",
        };
        let _ = write!(s, ".{call}(m={}, n={}, k={})", t.m, t.n, t.k);
    }
    if let Some(al) = k.alignment {
        let _ = write!(s, ".with_alignment(A={}, B={}, C={})", al.a, al.b, al.c);
    }
    if let Some(st) = k.stages {
        let _ = write!(s, ".with_stages({st})");
    }
    if let Some(c) = k.cluster {
        let _ = write!(s, ".with_cluster(m={}, n={}, k={})", c.m, c.n, c.k);
    }
    if let Some(sw) = k.swizzle {
        let name = match sw {
            Swizzle::Identity1 => "Identity1",
            Swizzle::Identity2 => "Identity2",
            Swizzle::Identity4 => "Identity4",
            Swizzle::Identity8 => "Identity8",
            Swizzle::StreamK => "StreamK",
        };
        let _ = write!(s, ".with_swizzle(pattern={name})");
    }
    if let Some(sch) = k.scheduler {
        let tile = match sch.tile {
            TileScheduler::Default => "default",
            TileScheduler::Persistent => "persistent",
            TileScheduler::StreamK => "stream_k",
        };
        let kernel = match sch.kernel {
            KernelSchedule::Auto => "auto",
            KernelSchedule::CpAsync => "cp_async",
            KernelSchedule::CpAsyncCooperative => "cp_async_cooperative",
            KernelSchedule::Tma => "tma",
            KernelSchedule::TmaCooperative => "tma_cooperative",
            KernelSchedule::TmaPingpong => "tma_pingpong",
        };
        let epi = match sch.epilogue {
            EpilogueSchedule::Auto => "auto",
            EpilogueSchedule::Tma => "tma",
            EpilogueSchedule::TmaCooperative => "tma_cooperative",
            EpilogueSchedule::NoSmem => "no_smem",
        };
        let _ = write!(s, ".with_scheduler(tile={tile}, kernel={kernel}, epilogue={epi})");
    }
    if let Some((alpha, beta)) = k.scaling {
        let _ = write!(s, ".with_scaling(alpha={alpha}, beta={beta})");
    }
    if let Some(it) = k.iterator {
        let name = match it {
            Iterator_::Analytic => "analytic",
            Iterator_::Optimized => "optimized",
            Iterator_::FixedChannels => "fixed_channels",
            Iterator_::FewChannels => "few_channels",
            Iterator_::FixedStrideDilation => "fixed_stride_dilation",
        };
        let _ = write!(s, ".with_iterator({name})");
    }
    if let Some((mode, slices)) = k.split_k {
        let m = match mode {
            SplitK::None => "none",
            SplitK::Serial => "serial",
            SplitK::Parallel => "parallel",
        };
        let _ = write!(s, ".with_split_k(mode={m}, slices={slices})");
    }
    if k.operand_swap {
        s.push_str(".with_operand_swap(true)");
    }
    for e in &k.epilogue {
        s.push_str(" >> ");
        match e {
            EpilogueOp::Relu => s.push_str("relu()"),
            EpilogueOp::Gelu => s.push_str("gelu()"),
            EpilogueOp::Silu => s.push_str("silu()"),
            EpilogueOp::Sigmoid => s.push_str("sigmoid()"),
            EpilogueOp::Tanh => s.push_str("tanh()"),
            EpilogueOp::Mish => s.push_str("mish()"),
            EpilogueOp::Hardswish => s.push_str("hardswish()"),
            EpilogueOp::LeakyRelu { alpha } => {
                let _ = write!(s, "leaky_relu(alpha={alpha})");
            }
            EpilogueOp::Elu { alpha } => {
                let _ = write!(s, "elu(alpha={alpha})");
            }
            EpilogueOp::Clip { lo, hi } => {
                let _ = write!(s, "clip(lo={lo}, hi={hi})");
            }
            EpilogueOp::Bias => s.push_str("bias()"),
            EpilogueOp::PerChannelScale => s.push_str("per_channel_scale()"),
            EpilogueOp::PerRowScale => s.push_str("per_row_scale()"),
            EpilogueOp::PerColScale => s.push_str("per_col_scale()"),
            EpilogueOp::Scale { value } => {
                let _ = write!(s, "scale({value})");
            }
            EpilogueOp::AuxStore { name } => {
                let _ = write!(s, "aux_store({name})");
            }
            EpilogueOp::AuxLoad { name } => {
                let _ = write!(s, "aux_load({name})");
            }
            EpilogueOp::Custom { expr, inputs } => {
                if inputs.is_empty() {
                    let _ = write!(s, "custom('{expr}')");
                } else {
                    let dict: Vec<String> =
                        inputs.iter().map(|(k, v)| format!("'{k}': '{v}'")).collect();
                    let _ = write!(s, "custom('{expr}', inputs={{{}}})", dict.join(", "));
                }
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{compile, ir::lower, parser::parse};
    use crate::util::prop;

    fn roundtrip(src: &str) {
        let ir1 = lower(&parse(src).unwrap()).unwrap();
        let printed = format_program(&ir1);
        let ir2 = lower(&parse(&printed).unwrap_or_else(|e| panic!("{e}\n{printed}")))
            .unwrap_or_else(|e| panic!("{e}\n{printed}"));
        assert_eq!(ir1, ir2, "roundtrip changed the IR:\n{printed}");
    }

    #[test]
    fn roundtrips_sm90_gemm() {
        roundtrip(
            "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
             .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)\
             .with_stages(2).with_cluster(m=2, n=1, k=1)\
             .with_scheduler(tile=persistent, kernel=tma, epilogue=auto)\
             >> bias() >> leaky_relu(alpha=0.2) >> scale(0.5)",
        );
    }

    #[test]
    fn roundtrips_sm80_conv() {
        roundtrip(
            "conv2d_fprop(kernel_h=3, kernel_w=3)\
             .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_80)\
             .with_layout(input=TensorNHWC, filter=TensorNHWC, output=TensorNHWC)\
             .with_tile(m=128, n=64, k=32).with_iterator(optimized)\
             .with_split_k(mode=serial, slices=4) >> relu()",
        );
    }

    #[test]
    fn roundtrips_pipeline() {
        roundtrip(
            "pipeline(transpose(input, NCL, NLC, fp32, fp16), \
             gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a), \
             transpose(output, NLC, NCL, fp16, fp32))",
        );
    }

    #[test]
    fn roundtrips_custom_epilogue() {
        roundtrip(
            "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
             >> custom('x * 2 + y', inputs={'y': 'tensor'})",
        );
    }

    #[test]
    fn prop_agent_generated_sources_roundtrip() {
        // fuzz over agent-shaped configs: print → parse → lower is stable
        prop::check("dsl-print-roundtrip", 150, |rng| {
            let tiles = crate::agent::policy::TILES;
            let (m, n, k) = *rng.choice(tiles);
            let dt = *rng.choice(&["fp16", "bf16", "fp32"]);
            let align = if dt == "fp32" { 4 } else { 8 };
            let epi = *rng.choice(&["", " >> relu()", " >> bias() >> gelu()", " >> silu() >> scale(1.5)"]);
            let src = format!(
                "gemm().with_dtype(input={dt}, acc=fp32, output=fp32)\
                 .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
                 .with_threadblockshape(m={m}, n={n}, k={k})\
                 .with_alignment(A={align}, B={align}, C=4).with_stages(2){epi}"
            );
            if let Ok(c) = compile(&src) {
                let printed = format_program(&c.ir);
                let again = compile(&printed).unwrap_or_else(|e| panic!("{e}\n{printed}"));
                assert_eq!(c.hash(), again.hash(), "canonical print must preserve the config hash");
            }
        });
    }
}
