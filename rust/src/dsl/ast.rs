//! AST for µCUTLASS programs — the direct image of the Appendix A.1
//! grammar, before lowering to the typed configuration IR.

/// Top level: a single kernel or a multi-stage `pipeline(...)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Program {
    Kernel(KernelSpec),
    Pipeline(Vec<Stage>),
}

/// One pipeline stage: a kernel stage or a transform (transpose) stage.
#[derive(Debug, Clone, PartialEq)]
pub enum Stage {
    Kernel(KernelSpec),
    Transpose(TransposeSpec),
}

/// `operation , { configuration } , { epilogue }`.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub op_name: String,
    pub op_args: Vec<Arg>,
    pub configs: Vec<ConfigCall>,
    pub epilogue: Vec<EpilogueCall>,
    pub offset: usize,
}

/// A `.with_*(...)` configuration call.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigCall {
    pub name: String,
    pub args: Vec<Arg>,
    pub offset: usize,
}

/// A `>> op(...)` epilogue call.
#[derive(Debug, Clone, PartialEq)]
pub struct EpilogueCall {
    pub name: String,
    pub args: Vec<Arg>,
    pub offset: usize,
}

/// `transpose(target, FROM, TO[, from_dtype, to_dtype])` — layout transform
/// with optional fused dtype conversion (essentially free, per the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct TransposeSpec {
    pub target: String,
    pub from_layout: String,
    pub to_layout: String,
    pub from_dtype: Option<String>,
    pub to_dtype: Option<String>,
    pub offset: usize,
}

/// Argument value: unquoted identifier, number, quoted string, or a
/// `{ 'k': 'v', ... }` dict (only used by `custom(..., inputs={...})`).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    Ident(String),
    Int(u64),
    Float(f64),
    Str(String),
    Dict(Vec<(String, String)>),
}

impl ArgValue {
    pub fn describe(&self) -> String {
        match self {
            ArgValue::Ident(s) => format!("`{s}`"),
            ArgValue::Int(v) => format!("{v}"),
            ArgValue::Float(v) => format!("{v}"),
            ArgValue::Str(s) => format!("'{s}'"),
            ArgValue::Dict(_) => "{...}".into(),
        }
    }
}

/// A (possibly named) call argument.
#[derive(Debug, Clone, PartialEq)]
pub struct Arg {
    pub name: Option<String>,
    pub value: ArgValue,
    pub offset: usize,
}

impl KernelSpec {
    /// Find a configuration call by name (e.g. "with_dtype").
    pub fn config(&self, name: &str) -> Option<&ConfigCall> {
        self.configs.iter().find(|c| c.name == name)
    }
}

/// Helpers for pulling named/positional arguments out of a call.
pub fn find_arg<'a>(args: &'a [Arg], name: &str, position: usize) -> Option<&'a Arg> {
    args.iter()
        .find(|a| a.name.as_deref() == Some(name))
        .or_else(|| {
            let a = args.get(position)?;
            if a.name.is_none() {
                Some(a)
            } else {
                None
            }
        })
}
