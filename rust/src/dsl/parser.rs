//! Recursive-descent parser for µCUTLASS, following the Appendix A.1 EBNF:
//!
//! ```text
//! start   = kernel | pipeline ;
//! kernel  = operation , { configuration } , { epilogue } ;
//! pipeline = "pipeline(" , stage , { "," , stage } , ")" ;
//! stage   = transform_stage | kernel_stage ;
//! ```

use super::ast::*;
use super::error::{DslError, DslErrorKind};
use super::token::{lex, TokKind, Token};

pub fn parse(src: &str) -> Result<Program, DslError> {
    let toks = lex(src)?;
    let mut p = P { toks, i: 0 };
    let prog = p.program()?;
    p.expect_eof()?;
    Ok(prog)
}

struct P {
    toks: Vec<Token>,
    i: usize,
}

impl P {
    fn peek(&self) -> &Token {
        &self.toks[self.i.min(self.toks.len() - 1)]
    }

    fn next(&mut self) -> Token {
        let t = self.toks[self.i.min(self.toks.len() - 1)].clone();
        if self.i < self.toks.len() - 1 {
            self.i += 1;
        }
        t
    }

    fn err(&self, msg: &str, hint: &str) -> DslError {
        DslError::at(DslErrorKind::Parse, self.peek().start, msg, hint)
    }

    fn expect(&mut self, kind: &TokKind, what: &str) -> Result<Token, DslError> {
        if &self.peek().kind == kind {
            Ok(self.next())
        } else {
            Err(self.err(
                &format!("expected {what}, found {}", self.peek().kind.describe()),
                "",
            ))
        }
    }

    fn expect_eof(&mut self) -> Result<(), DslError> {
        if self.peek().kind == TokKind::Eof {
            Ok(())
        } else {
            Err(self.err(
                &format!("trailing {} after program", self.peek().kind.describe()),
                "a µCUTLASS program is a single kernel expression or one pipeline(...)",
            ))
        }
    }

    fn ident(&mut self, what: &str) -> Result<(String, usize), DslError> {
        match self.peek().kind.clone() {
            TokKind::Ident(s) => {
                let off = self.peek().start;
                self.next();
                Ok((s, off))
            }
            other => Err(self.err(
                &format!("expected {what}, found {}", other.describe()),
                "",
            )),
        }
    }

    fn program(&mut self) -> Result<Program, DslError> {
        if let TokKind::Ident(name) = &self.peek().kind {
            if name == "pipeline" {
                return self.pipeline();
            }
        }
        Ok(Program::Kernel(self.kernel()?))
    }

    fn pipeline(&mut self) -> Result<Program, DslError> {
        self.next(); // pipeline
        self.expect(&TokKind::LParen, "`(` after pipeline")?;
        let mut stages = Vec::new();
        loop {
            stages.push(self.stage()?);
            match self.peek().kind {
                TokKind::Comma => {
                    self.next();
                }
                TokKind::RParen => {
                    self.next();
                    break;
                }
                _ => {
                    return Err(self.err(
                        &format!(
                            "expected `,` or `)` in pipeline, found {}",
                            self.peek().kind.describe()
                        ),
                        "pipeline stages are comma-separated: pipeline(transpose(...), gemm()...)",
                    ))
                }
            }
        }
        if stages.is_empty() {
            return Err(self.err("empty pipeline", "a pipeline needs at least one stage"));
        }
        Ok(Program::Pipeline(stages))
    }

    fn stage(&mut self) -> Result<Stage, DslError> {
        if let TokKind::Ident(name) = &self.peek().kind {
            if name == "transpose" {
                return Ok(Stage::Transpose(self.transpose()?));
            }
        }
        Ok(Stage::Kernel(self.kernel()?))
    }

    fn transpose(&mut self) -> Result<TransposeSpec, DslError> {
        let (_, offset) = self.ident("transpose")?;
        self.expect(&TokKind::LParen, "`(`")?;
        let (target, _) = self.ident("transpose target (input/output)")?;
        self.expect(&TokKind::Comma, "`,`")?;
        let (from_layout, _) = self.ident("source layout (e.g. NCL)")?;
        self.expect(&TokKind::Comma, "`,`")?;
        let (to_layout, _) = self.ident("destination layout (e.g. NLC)")?;
        let mut from_dtype = None;
        let mut to_dtype = None;
        if self.peek().kind == TokKind::Comma {
            self.next();
            from_dtype = Some(self.ident("source dtype")?.0);
            self.expect(&TokKind::Comma, "`,` before destination dtype")?;
            to_dtype = Some(self.ident("destination dtype")?.0);
        }
        self.expect(&TokKind::RParen, "`)`")?;
        Ok(TransposeSpec { target, from_layout, to_layout, from_dtype, to_dtype, offset })
    }

    fn kernel(&mut self) -> Result<KernelSpec, DslError> {
        let (op_name, offset) = self.ident("an operation (e.g. gemm, conv2d_fprop)")?;
        self.expect(&TokKind::LParen, "`(` after operation name")?;
        let op_args = self.args()?;
        let mut spec = KernelSpec { op_name, op_args, configs: vec![], epilogue: vec![], offset };
        loop {
            match self.peek().kind.clone() {
                TokKind::Dot => {
                    self.next();
                    let (name, coff) = self.ident("a .with_* configuration")?;
                    self.expect(&TokKind::LParen, "`(`")?;
                    let args = self.args()?;
                    spec.configs.push(ConfigCall { name, args, offset: coff });
                }
                TokKind::Chain => {
                    self.next();
                    let (name, eoff) = self.ident("an epilogue op (e.g. relu, bias)")?;
                    self.expect(&TokKind::LParen, "`(`")?;
                    let args = self.args()?;
                    spec.epilogue.push(EpilogueCall { name, args, offset: eoff });
                }
                _ => break,
            }
        }
        Ok(spec)
    }

    /// Parse a call argument list up to and including the closing `)`.
    fn args(&mut self) -> Result<Vec<Arg>, DslError> {
        let mut out = Vec::new();
        if self.peek().kind == TokKind::RParen {
            self.next();
            return Ok(out);
        }
        loop {
            out.push(self.arg()?);
            match self.peek().kind {
                TokKind::Comma => {
                    self.next();
                }
                TokKind::RParen => {
                    self.next();
                    return Ok(out);
                }
                _ => {
                    return Err(self.err(
                        &format!(
                            "expected `,` or `)` in argument list, found {}",
                            self.peek().kind.describe()
                        ),
                        "arguments are comma-separated: .with_tile(m=128, n=128, k=32)",
                    ))
                }
            }
        }
    }

    fn arg(&mut self) -> Result<Arg, DslError> {
        let offset = self.peek().start;
        // named argument: ident '=' value
        if let TokKind::Ident(name) = self.peek().kind.clone() {
            if self.toks.get(self.i + 1).map(|t| &t.kind) == Some(&TokKind::Equals) {
                self.next(); // ident
                self.next(); // '='
                let value = self.value()?;
                return Ok(Arg { name: Some(name), value, offset });
            }
        }
        let value = self.value()?;
        Ok(Arg { name: None, value, offset })
    }

    fn value(&mut self) -> Result<ArgValue, DslError> {
        match self.peek().kind.clone() {
            TokKind::Ident(s) => {
                self.next();
                Ok(ArgValue::Ident(s))
            }
            TokKind::Int(v) => {
                self.next();
                Ok(ArgValue::Int(v))
            }
            TokKind::Float(v) => {
                self.next();
                Ok(ArgValue::Float(v))
            }
            TokKind::Str(s) => {
                self.next();
                Ok(ArgValue::Str(s))
            }
            TokKind::LBrace => {
                self.next();
                let mut pairs = Vec::new();
                if self.peek().kind == TokKind::RBrace {
                    self.next();
                    return Ok(ArgValue::Dict(pairs));
                }
                loop {
                    let key = match self.peek().kind.clone() {
                        TokKind::Str(s) => {
                            self.next();
                            s
                        }
                        TokKind::Ident(s) => {
                            self.next();
                            s
                        }
                        other => {
                            return Err(self.err(
                                &format!("expected dict key, found {}", other.describe()),
                                "custom() inputs use quoted keys: inputs={'y': 'tensor'}",
                            ))
                        }
                    };
                    self.expect(&TokKind::Colon, "`:` in dict")?;
                    let val = match self.peek().kind.clone() {
                        TokKind::Str(s) => {
                            self.next();
                            s
                        }
                        TokKind::Ident(s) => {
                            self.next();
                            s
                        }
                        other => {
                            return Err(self.err(
                                &format!("expected dict value, found {}", other.describe()),
                                "",
                            ))
                        }
                    };
                    pairs.push((key, val));
                    match self.peek().kind {
                        TokKind::Comma => {
                            self.next();
                        }
                        TokKind::RBrace => {
                            self.next();
                            return Ok(ArgValue::Dict(pairs));
                        }
                        _ => {
                            return Err(self.err(
                                &format!(
                                    "expected `,` or `}}` in dict, found {}",
                                    self.peek().kind.describe()
                                ),
                                "",
                            ))
                        }
                    }
                }
            }
            other => Err(self.err(
                &format!("expected an argument value, found {}", other.describe()),
                "",
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bare_gemm() {
        let p = parse("gemm()").unwrap();
        match p {
            Program::Kernel(k) => {
                assert_eq!(k.op_name, "gemm");
                assert!(k.configs.is_empty());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_full_kernel() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
                   .with_arch(sm_90a).with_threadblockshape(m=128, n=128, k=64)\
                   >> bias() >> relu()";
        match parse(src).unwrap() {
            Program::Kernel(k) => {
                assert_eq!(k.configs.len(), 3);
                assert_eq!(k.epilogue.len(), 2);
                assert_eq!(k.epilogue[0].name, "bias");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_conv_with_args() {
        match parse("conv2d_fprop(kernel_h=3, kernel_w=3).with_arch(sm_80)").unwrap() {
            Program::Kernel(k) => {
                assert_eq!(k.op_args.len(), 2);
                assert_eq!(k.op_args[0].name.as_deref(), Some("kernel_h"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_pipeline_with_transpose() {
        let src = "pipeline(transpose(input, NCL, NLC, fp32, fp16), \
                   gemm().with_arch(sm_90a), transpose(output, NLC, NCL, fp16, fp32))";
        match parse(src).unwrap() {
            Program::Pipeline(stages) => {
                assert_eq!(stages.len(), 3);
                assert!(matches!(&stages[0], Stage::Transpose(t) if t.target == "input"));
                assert!(matches!(&stages[1], Stage::Kernel(_)));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_custom_epilogue() {
        let src = "gemm() >> custom('x * 2 + y', inputs={'y': 'tensor'})";
        match parse(src).unwrap() {
            Program::Kernel(k) => {
                assert_eq!(k.epilogue[0].name, "custom");
                assert!(matches!(&k.epilogue[0].args[0].value, ArgValue::Str(s) if s.contains("x * 2")));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn error_on_trailing_tokens() {
        let e = parse("gemm() gemm()").unwrap_err();
        assert_eq!(e.kind, DslErrorKind::Parse);
        assert!(e.to_string().contains("trailing"));
    }

    #[test]
    fn error_on_missing_paren() {
        assert!(parse("gemm(").is_err());
        assert!(parse("gemm").is_err());
        assert!(parse("gemm().with_tile m=1").is_err());
    }

    #[test]
    fn parses_scale_positional_float() {
        match parse("gemm() >> scale(0.5)").unwrap() {
            Program::Kernel(k) => {
                assert!(matches!(k.epilogue[0].args[0].value, ArgValue::Float(v) if v == 0.5));
            }
            _ => panic!(),
        }
    }
}
