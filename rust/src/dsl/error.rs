//! Explanatory compile errors. The paper (§3, "Compilation") stresses that
//! when validation fails the compiler should explain *what went wrong and
//! why*, so the model can fix the specification before triggering an
//! expensive compile/run/profile attempt. Every error carries a hint.

use std::fmt;

/// Which compiler stage rejected the program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DslErrorKind {
    /// Lexical error (bad character, unterminated string).
    Lex,
    /// Syntactic error (grammar violation).
    Parse,
    /// Lowering error (unknown op/feature/enum value).
    Lower,
    /// Static constraint violation (arch gating, alignment, SMEM budget…).
    Constraint,
    /// Dimension-dependent violation found when binding to a problem.
    Bind,
}

impl DslErrorKind {
    pub fn stage(&self) -> &'static str {
        match self {
            DslErrorKind::Lex => "lex",
            DslErrorKind::Parse => "parse",
            DslErrorKind::Lower => "lower",
            DslErrorKind::Constraint => "validate",
            DslErrorKind::Bind => "bind",
        }
    }
}

/// A µCUTLASS compilation error: stage, location, message, and a hint that
/// explains the rule (mirroring the paper's "we try to explain what went
/// wrong and why").
#[derive(Debug, Clone, PartialEq)]
pub struct DslError {
    pub kind: DslErrorKind,
    pub offset: Option<usize>,
    pub message: String,
    pub hint: String,
}

impl DslError {
    pub fn new(kind: DslErrorKind, message: &str, hint: &str) -> Self {
        DslError { kind, offset: None, message: message.to_string(), hint: hint.to_string() }
    }

    pub fn at(kind: DslErrorKind, offset: usize, message: &str, hint: &str) -> Self {
        DslError {
            kind,
            offset: Some(offset),
            message: message.to_string(),
            hint: hint.to_string(),
        }
    }

    /// True if the program was rejected *before* any backend work — the
    /// property that saves compile/run/profile cycles (paper §3).
    pub fn is_static(&self) -> bool {
        !matches!(self.kind, DslErrorKind::Bind)
    }
}

impl fmt::Display for DslError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "µcutlass {} error", self.kind.stage())?;
        if let Some(off) = self.offset {
            write!(f, " at offset {off}")?;
        }
        write!(f, ": {}", self.message)?;
        if !self.hint.is_empty() {
            write!(f, "\n  hint: {}", self.hint)?;
        }
        Ok(())
    }
}

impl std::error::Error for DslError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_hint() {
        let e = DslError::at(DslErrorKind::Constraint, 10, "bad tile", "use with_threadblockshape");
        let s = e.to_string();
        assert!(s.contains("validate"));
        assert!(s.contains("offset 10"));
        assert!(s.contains("hint: use with_threadblockshape"));
    }

    #[test]
    fn static_vs_bind() {
        assert!(DslError::new(DslErrorKind::Constraint, "", "").is_static());
        assert!(!DslError::new(DslErrorKind::Bind, "", "").is_static());
    }
}
