//! Explanatory compile errors. The paper (§3, "Compilation") stresses that
//! when validation fails the compiler should explain *what went wrong and
//! why*, so the model can fix the specification before triggering an
//! expensive compile/run/profile attempt. Every error carries a hint.

use crate::util::json::Json;
use std::fmt;

/// Which compiler stage rejected the program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DslErrorKind {
    /// Lexical error (bad character, unterminated string).
    Lex,
    /// Syntactic error (grammar violation).
    Parse,
    /// Lowering error (unknown op/feature/enum value).
    Lower,
    /// Static constraint violation (arch gating, alignment, SMEM budget…).
    Constraint,
    /// Dimension-dependent violation found when binding to a problem.
    Bind,
}

impl DslErrorKind {
    /// Every kind, for exhaustive registry tests.
    pub const ALL: [DslErrorKind; 5] = [
        DslErrorKind::Lex,
        DslErrorKind::Parse,
        DslErrorKind::Lower,
        DslErrorKind::Constraint,
        DslErrorKind::Bind,
    ];

    pub fn stage(&self) -> &'static str {
        match self {
            DslErrorKind::Lex => "lex",
            DslErrorKind::Parse => "parse",
            DslErrorKind::Lower => "lower",
            DslErrorKind::Constraint => "validate",
            DslErrorKind::Bind => "bind",
        }
    }

    /// Stable machine-readable code. Shares one namespace with the
    /// analyzer's rule IDs (`analyze::RuleId`): `E0xx` = compiler
    /// rejections, `A1xx/A2xx/A3xx/C4xx` = analyzer diagnostics. Codes are
    /// append-only — a published code never changes meaning (pinned by the
    /// code-uniqueness test in `tests/lint.rs`).
    pub fn code(&self) -> &'static str {
        match self {
            DslErrorKind::Lex => "E001",
            DslErrorKind::Parse => "E002",
            DslErrorKind::Lower => "E003",
            DslErrorKind::Constraint => "E004",
            DslErrorKind::Bind => "E005",
        }
    }
}

/// A µCUTLASS compilation error: stage, location, message, and a hint that
/// explains the rule (mirroring the paper's "we try to explain what went
/// wrong and why").
#[derive(Debug, Clone, PartialEq)]
pub struct DslError {
    pub kind: DslErrorKind,
    pub offset: Option<usize>,
    pub message: String,
    pub hint: String,
}

impl DslError {
    pub fn new(kind: DslErrorKind, message: &str, hint: &str) -> Self {
        DslError { kind, offset: None, message: message.to_string(), hint: hint.to_string() }
    }

    pub fn at(kind: DslErrorKind, offset: usize, message: &str, hint: &str) -> Self {
        DslError {
            kind,
            offset: Some(offset),
            message: message.to_string(),
            hint: hint.to_string(),
        }
    }

    /// True if the program was rejected *before* any backend work — the
    /// property that saves compile/run/profile cycles (paper §3).
    pub fn is_static(&self) -> bool {
        !matches!(self.kind, DslErrorKind::Bind)
    }

    /// Machine-readable form, shaped like an analyzer diagnostic so
    /// `repro lint --json` consumers see one schema for compiler errors
    /// and lint findings alike.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("code", self.kind.code())
            .set("stage", self.kind.stage())
            .set("severity", "deny")
            .set("message", self.message.as_str())
            .set("hint", self.hint.as_str());
        match self.offset {
            Some(off) => j.set("offset", off as f64),
            None => j.set("offset", Json::Null),
        };
        j
    }
}

impl fmt::Display for DslError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "µcutlass {} error [{}]", self.kind.stage(), self.kind.code())?;
        if let Some(off) = self.offset {
            write!(f, " at offset {off}")?;
        }
        write!(f, ": {}", self.message)?;
        if !self.hint.is_empty() {
            write!(f, "\n  hint: {}", self.hint)?;
        }
        Ok(())
    }
}

impl std::error::Error for DslError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_hint() {
        let e = DslError::at(DslErrorKind::Constraint, 10, "bad tile", "use with_threadblockshape");
        let s = e.to_string();
        assert!(s.contains("validate"));
        assert!(s.contains("[E004]"));
        assert!(s.contains("offset 10"));
        assert!(s.contains("hint: use with_threadblockshape"));
    }

    #[test]
    fn codes_unique_and_stable() {
        let codes: Vec<&str> = DslErrorKind::ALL.iter().map(|k| k.code()).collect();
        for (i, c) in codes.iter().enumerate() {
            assert!(c.starts_with('E') && c.len() == 4, "bad code shape {c}");
            assert!(!codes[i + 1..].contains(c), "duplicate code {c}");
        }
        // Published codes are frozen: renumbering breaks downstream parsers.
        assert_eq!(DslErrorKind::Constraint.code(), "E004");
    }

    #[test]
    fn json_shape() {
        let e = DslError::at(DslErrorKind::Parse, 3, "unexpected token", "check syntax");
        let j = e.to_json();
        assert_eq!(j.get("code").and_then(|v| v.as_str()), Some("E002"));
        assert_eq!(j.get("severity").and_then(|v| v.as_str()), Some("deny"));
        assert_eq!(j.get("offset").and_then(|v| v.as_u64()), Some(3));
    }

    #[test]
    fn static_vs_bind() {
        assert!(DslError::new(DslErrorKind::Constraint, "", "").is_static());
        assert!(!DslError::new(DslErrorKind::Bind, "", "").is_static());
    }
}
