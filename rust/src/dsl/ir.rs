//! Typed configuration IR — the lowering target of the AST, and the input
//! to validation, code generation, the performance model, and the runtime
//! variant mapper. Every enum mirrors a terminal class of the grammar.

use std::fmt;

use super::ast::{self, Arg, ArgValue, EpilogueCall, KernelSpec, Program, Stage};
use super::error::{DslError, DslErrorKind};

// ---------------------------------------------------------------------------
// Terminals
// ---------------------------------------------------------------------------

/// Data types (grammar terminal `DTYPE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    Fp64,
    Fp32,
    Tf32,
    Fp16,
    Bf16,
    Fp8E4m3,
    Fp8E5m2,
    Int8,
    Int16,
    Int32,
    Uint8,
    Uint16,
    Uint32,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "fp64" | "float64" => DType::Fp64,
            "fp32" | "float32" => DType::Fp32,
            "tf32" => DType::Tf32,
            "fp16" | "float16" => DType::Fp16,
            "bf16" | "bfloat16" => DType::Bf16,
            "fp8_e4m3" | "e4m3" => DType::Fp8E4m3,
            "fp8_e5m2" | "e5m2" => DType::Fp8E5m2,
            "int8" | "s8" => DType::Int8,
            "int16" | "s16" => DType::Int16,
            "int32" | "s32" => DType::Int32,
            "uint8" | "u8" => DType::Uint8,
            "uint16" | "u16" => DType::Uint16,
            "uint32" | "u32" => DType::Uint32,
            _ => return None,
        })
    }

    /// Element size in bytes.
    pub fn size(&self) -> u64 {
        match self {
            DType::Fp64 => 8,
            DType::Fp32 | DType::Tf32 | DType::Int32 | DType::Uint32 => 4,
            DType::Fp16 | DType::Bf16 | DType::Int16 | DType::Uint16 => 2,
            DType::Fp8E4m3 | DType::Fp8E5m2 | DType::Int8 | DType::Uint8 => 1,
        }
    }

    pub fn is_fp8(&self) -> bool {
        matches!(self, DType::Fp8E4m3 | DType::Fp8E5m2)
    }

    pub fn cutlass_name(&self) -> &'static str {
        match self {
            DType::Fp64 => "double",
            DType::Fp32 => "float",
            DType::Tf32 => "cutlass::tfloat32_t",
            DType::Fp16 => "cutlass::half_t",
            DType::Bf16 => "cutlass::bfloat16_t",
            DType::Fp8E4m3 => "cutlass::float_e4m3_t",
            DType::Fp8E5m2 => "cutlass::float_e5m2_t",
            DType::Int8 => "int8_t",
            DType::Int16 => "int16_t",
            DType::Int32 => "int32_t",
            DType::Uint8 => "uint8_t",
            DType::Uint16 => "uint16_t",
            DType::Uint32 => "uint32_t",
        }
    }
}

impl DType {
    /// Canonical allocation-free name — the exact token `Display` prints
    /// (the interned `EvalKey` hashes these bytes, so key equality matches
    /// string-key equality field for field).
    pub fn name(&self) -> &'static str {
        match self {
            DType::Fp64 => "fp64",
            DType::Fp32 => "fp32",
            DType::Tf32 => "tf32",
            DType::Fp16 => "fp16",
            DType::Bf16 => "bf16",
            DType::Fp8E4m3 => "fp8_e4m3",
            DType::Fp8E5m2 => "fp8_e5m2",
            DType::Int8 => "int8",
            DType::Int16 => "int16",
            DType::Int32 => "int32",
            DType::Uint8 => "uint8",
            DType::Uint16 => "uint16",
            DType::Uint32 => "uint32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Target architectures (grammar terminal `ARCH`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Arch {
    Sm70,
    Sm80,
    Sm86,
    Sm89,
    Sm90,
    Sm90a,
    Sm100,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        Some(match s {
            "sm_70" | "sm70" => Arch::Sm70,
            "sm_80" | "sm80" => Arch::Sm80,
            "sm_86" | "sm86" => Arch::Sm86,
            "sm_89" | "sm89" => Arch::Sm89,
            "sm_90" | "sm90" => Arch::Sm90,
            "sm_90a" | "sm90a" => Arch::Sm90a,
            "sm_100" | "sm100" => Arch::Sm100,
            _ => return None,
        })
    }

    /// Numeric capability (90 for both sm_90 and sm_90a).
    pub fn level(&self) -> u32 {
        match self {
            Arch::Sm70 => 70,
            Arch::Sm80 => 80,
            Arch::Sm86 => 86,
            Arch::Sm89 => 89,
            Arch::Sm90 | Arch::Sm90a => 90,
            Arch::Sm100 => 100,
        }
    }

    pub fn is_sm90_plus(&self) -> bool {
        self.level() >= 90
    }
}

impl fmt::Display for Arch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Arch::Sm70 => "sm_70",
            Arch::Sm80 => "sm_80",
            Arch::Sm86 => "sm_86",
            Arch::Sm89 => "sm_89",
            Arch::Sm90 => "sm_90",
            Arch::Sm90a => "sm_90a",
            Arch::Sm100 => "sm_100",
        };
        f.write_str(s)
    }
}

/// GEMM operand layouts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmLayout {
    RowMajor,
    ColumnMajor,
}

impl GemmLayout {
    pub fn parse(s: &str) -> Option<GemmLayout> {
        match s {
            "RowMajor" => Some(GemmLayout::RowMajor),
            "ColumnMajor" => Some(GemmLayout::ColumnMajor),
            _ => None,
        }
    }
}

/// Swizzle patterns (SM70–89).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Swizzle {
    Identity1,
    Identity2,
    Identity4,
    Identity8,
    StreamK,
}

impl Swizzle {
    pub fn parse(s: &str) -> Option<Swizzle> {
        Some(match s {
            "Identity1" => Swizzle::Identity1,
            "Identity2" => Swizzle::Identity2,
            "Identity4" => Swizzle::Identity4,
            "Identity8" => Swizzle::Identity8,
            "StreamK" => Swizzle::StreamK,
            _ => return None,
        })
    }
}

/// Tile schedulers (SM90+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TileScheduler {
    #[default]
    Default,
    Persistent,
    StreamK,
}

/// Kernel schedules (SM90+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelSchedule {
    #[default]
    Auto,
    CpAsync,
    CpAsyncCooperative,
    Tma,
    TmaCooperative,
    TmaPingpong,
}

/// Epilogue schedules (SM90+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum EpilogueSchedule {
    #[default]
    Auto,
    Tma,
    TmaCooperative,
    NoSmem,
}

/// Conv iterator algorithms (SM70–89).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Iterator_ {
    Analytic,
    Optimized,
    FixedChannels,
    FewChannels,
    FixedStrideDilation,
}

/// Split-K modes (conv, SM70–89).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SplitK {
    None,
    Serial,
    Parallel,
}

// ---------------------------------------------------------------------------
// Operations
// ---------------------------------------------------------------------------

/// Operation families (grammar `operation`; coverage per paper Table 1a).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Operation {
    Gemm,
    BatchedGemm,
    GroupedGemm { expert_count: u64 },
    Conv2dFprop { kh: u64, kw: u64 },
    Conv2dDgrad { kh: u64, kw: u64 },
    Conv2dWgrad { kh: u64, kw: u64 },
    Conv1dFprop { kw: u64 },
    DepthwiseConv1d { kw: u64 },
    GroupConv1d { kw: u64, groups: u64 },
    Conv3dFprop { kd: u64, kh: u64, kw: u64 },
    Conv3dDgrad { kd: u64, kh: u64, kw: u64 },
    Conv3dWgrad { kd: u64, kh: u64, kw: u64 },
    DepthwiseConv2d { kh: u64, kw: u64 },
    GroupConv2d { kh: u64, kw: u64, groups: u64 },
    GroupConv3d { kd: u64, kh: u64, kw: u64, groups: u64 },
}

impl Operation {
    pub fn family(&self) -> &'static str {
        match self {
            Operation::Gemm => "gemm",
            Operation::BatchedGemm => "batched_gemm",
            Operation::GroupedGemm { .. } => "grouped_gemm",
            Operation::Conv2dFprop { .. } => "conv2d_fprop",
            Operation::Conv2dDgrad { .. } => "conv2d_dgrad",
            Operation::Conv2dWgrad { .. } => "conv2d_wgrad",
            Operation::Conv1dFprop { .. } => "conv1d_fprop",
            Operation::DepthwiseConv1d { .. } => "depthwise_conv1d",
            Operation::GroupConv1d { .. } => "group_conv1d",
            Operation::Conv3dFprop { .. } => "conv3d_fprop",
            Operation::Conv3dDgrad { .. } => "conv3d_dgrad",
            Operation::Conv3dWgrad { .. } => "conv3d_wgrad",
            Operation::DepthwiseConv2d { .. } => "depthwise_conv2d",
            Operation::GroupConv2d { .. } => "group_conv2d",
            Operation::GroupConv3d { .. } => "group_conv3d",
        }
    }

    pub fn is_gemm_family(&self) -> bool {
        matches!(
            self,
            Operation::Gemm | Operation::BatchedGemm | Operation::GroupedGemm { .. }
        )
    }

    pub fn is_conv_family(&self) -> bool {
        !self.is_gemm_family()
    }
}

// ---------------------------------------------------------------------------
// Epilogues
// ---------------------------------------------------------------------------

/// Fused epilogue ops (paper Table 1c); composed left-to-right by `>>`.
#[derive(Debug, Clone, PartialEq)]
pub enum EpilogueOp {
    Relu,
    Gelu,
    Silu,
    Sigmoid,
    Tanh,
    Mish,
    Hardswish,
    LeakyRelu { alpha: f64 },
    Elu { alpha: f64 },
    Clip { lo: f64, hi: f64 },
    Bias,
    PerChannelScale,
    PerRowScale,
    PerColScale,
    Scale { value: f64 },
    AuxStore { name: String },
    AuxLoad { name: String },
    Custom { expr: String, inputs: Vec<(String, String)> },
}

impl EpilogueOp {
    pub fn name(&self) -> &'static str {
        match self {
            EpilogueOp::Relu => "relu",
            EpilogueOp::Gelu => "gelu",
            EpilogueOp::Silu => "silu",
            EpilogueOp::Sigmoid => "sigmoid",
            EpilogueOp::Tanh => "tanh",
            EpilogueOp::Mish => "mish",
            EpilogueOp::Hardswish => "hardswish",
            EpilogueOp::LeakyRelu { .. } => "leaky_relu",
            EpilogueOp::Elu { .. } => "elu",
            EpilogueOp::Clip { .. } => "clip",
            EpilogueOp::Bias => "bias",
            EpilogueOp::PerChannelScale => "per_channel_scale",
            EpilogueOp::PerRowScale => "per_row_scale",
            EpilogueOp::PerColScale => "per_col_scale",
            EpilogueOp::Scale { .. } => "scale",
            EpilogueOp::AuxStore { .. } => "aux_store",
            EpilogueOp::AuxLoad { .. } => "aux_load",
            EpilogueOp::Custom { .. } => "custom",
        }
    }
}

// ---------------------------------------------------------------------------
// ConfigIR
// ---------------------------------------------------------------------------

/// Tile shape: `.with_tile` (SM70–89) or `.with_threadblockshape` (SM90+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

/// Cluster dims (SM90+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cluster {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

/// Scheduler configuration (SM90+): tile/kernel/epilogue schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Scheduler {
    pub tile: TileScheduler,
    pub kernel: KernelSchedule,
    pub epilogue: EpilogueSchedule,
}

/// Per-operand alignment (elements).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Alignment {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

/// Which call site set a tile: the two spellings are arch-gated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileSpelling {
    WithTile,
    WithThreadblockShape,
}

/// The validated, typed configuration of a single kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigIr {
    pub op: Operation,
    pub arch: Option<Arch>,
    pub dtype_input: Option<DType>,
    pub dtype_acc: Option<DType>,
    pub dtype_output: Option<DType>,
    pub layout_a: Option<GemmLayout>,
    pub layout_b: Option<GemmLayout>,
    pub layout_c: Option<GemmLayout>,
    pub conv_layouts: Option<(String, String, String)>,
    pub tile: Option<Tile>,
    pub tile_spelling: Option<TileSpelling>,
    pub stages: Option<u64>,
    pub alignment: Option<Alignment>,
    pub cluster: Option<Cluster>,
    pub swizzle: Option<Swizzle>,
    pub scheduler: Option<Scheduler>,
    pub scaling: Option<(f64, f64)>,
    pub iterator: Option<Iterator_>,
    pub split_k: Option<(SplitK, u64)>,
    pub operand_swap: bool,
    pub epilogue: Vec<EpilogueOp>,
    /// Source offset of the kernel, for error messages.
    pub offset: usize,
}

impl ConfigIr {
    pub fn new(op: Operation, offset: usize) -> Self {
        ConfigIr {
            op,
            arch: None,
            dtype_input: None,
            dtype_acc: None,
            dtype_output: None,
            layout_a: None,
            layout_b: None,
            layout_c: None,
            conv_layouts: None,
            tile: None,
            tile_spelling: None,
            stages: None,
            alignment: None,
            cluster: None,
            swizzle: None,
            scheduler: None,
            scaling: None,
            iterator: None,
            split_k: None,
            operand_swap: false,
            epilogue: Vec::new(),
            offset,
        }
    }

    /// Effective tile (defaults applied when the program omits it).
    pub fn effective_tile(&self) -> Tile {
        self.tile.unwrap_or(Tile { m: 128, n: 128, k: 32 })
    }

    /// Effective stage count.
    pub fn effective_stages(&self) -> u64 {
        self.stages.unwrap_or(3)
    }
}

/// A pipeline: transforms + kernel stages with explicit boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineIr {
    pub stages: Vec<StageIr>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum StageIr {
    Kernel(ConfigIr),
    Transpose {
        target: String,
        from_layout: String,
        to_layout: String,
        from_dtype: Option<DType>,
        to_dtype: Option<DType>,
    },
}

/// Lowered program: single kernel or pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum ProgramIr {
    Kernel(ConfigIr),
    Pipeline(PipelineIr),
}

impl ProgramIr {
    /// All kernel configs in the program (one for a kernel, 1+ for pipelines).
    pub fn kernels(&self) -> Vec<&ConfigIr> {
        match self {
            ProgramIr::Kernel(k) => vec![k],
            ProgramIr::Pipeline(p) => p
                .stages
                .iter()
                .filter_map(|s| match s {
                    StageIr::Kernel(k) => Some(k),
                    _ => None,
                })
                .collect(),
        }
    }

    /// The primary (first) kernel.
    pub fn primary(&self) -> Option<&ConfigIr> {
        self.kernels().into_iter().next()
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

pub fn lower(program: &Program) -> Result<ProgramIr, DslError> {
    match program {
        Program::Kernel(k) => Ok(ProgramIr::Kernel(lower_kernel(k)?)),
        Program::Pipeline(stages) => {
            let mut out = Vec::new();
            for s in stages {
                match s {
                    Stage::Kernel(k) => out.push(StageIr::Kernel(lower_kernel(k)?)),
                    Stage::Transpose(t) => {
                        for layout in [&t.from_layout, &t.to_layout] {
                            if !matches!(layout.as_str(), "NCL" | "NLC" | "NCHW" | "NHWC") {
                                return Err(DslError::at(
                                    DslErrorKind::Lower,
                                    t.offset,
                                    &format!("unknown transpose layout `{layout}`"),
                                    "supported layouts: NCL, NLC, NCHW, NHWC",
                                ));
                            }
                        }
                        if !matches!(t.target.as_str(), "input" | "output") {
                            return Err(DslError::at(
                                DslErrorKind::Lower,
                                t.offset,
                                &format!("transpose target must be input or output, got `{}`", t.target),
                                "",
                            ));
                        }
                        let parse_dt = |s: &Option<String>| -> Result<Option<DType>, DslError> {
                            match s {
                                None => Ok(None),
                                Some(x) => DType::parse(x).map(Some).ok_or_else(|| {
                                    DslError::at(
                                        DslErrorKind::Lower,
                                        t.offset,
                                        &format!("unknown dtype `{x}` in transpose"),
                                        "dtype conversion is fused with transpose: transpose(input, NCL, NLC, fp32, fp16)",
                                    )
                                }),
                            }
                        };
                        out.push(StageIr::Transpose {
                            target: t.target.clone(),
                            from_layout: t.from_layout.clone(),
                            to_layout: t.to_layout.clone(),
                            from_dtype: parse_dt(&t.from_dtype)?,
                            to_dtype: parse_dt(&t.to_dtype)?,
                        });
                    }
                }
            }
            Ok(ProgramIr::Pipeline(PipelineIr { stages: out }))
        }
    }
}

fn get_int(args: &[Arg], name: &str, pos: usize, ctx: &str, off: usize) -> Result<u64, DslError> {
    match ast::find_arg(args, name, pos).map(|a| &a.value) {
        Some(ArgValue::Int(v)) => Ok(*v),
        Some(other) => Err(DslError::at(
            DslErrorKind::Lower,
            off,
            &format!("{ctx}: `{name}` must be an integer, got {}", other.describe()),
            "",
        )),
        None => Err(DslError::at(
            DslErrorKind::Lower,
            off,
            &format!("{ctx}: missing required argument `{name}`"),
            "",
        )),
    }
}

fn get_float(args: &[Arg], name: &str, pos: usize) -> Option<f64> {
    match ast::find_arg(args, name, pos).map(|a| &a.value) {
        Some(ArgValue::Float(v)) => Some(*v),
        Some(ArgValue::Int(v)) => Some(*v as f64),
        _ => None,
    }
}

fn get_ident<'a>(args: &'a [Arg], name: &str, pos: usize) -> Option<&'a str> {
    match ast::find_arg(args, name, pos).map(|a| &a.value) {
        Some(ArgValue::Ident(s)) => Some(s),
        Some(ArgValue::Str(s)) => Some(s),
        _ => None,
    }
}

fn lower_operation(spec: &KernelSpec) -> Result<Operation, DslError> {
    let a = &spec.op_args;
    let off = spec.offset;
    let nm = spec.op_name.as_str();
    let op = match nm {
        "gemm" => Operation::Gemm,
        "batched_gemm" => Operation::BatchedGemm,
        "grouped_gemm" => Operation::GroupedGemm {
            expert_count: get_int(a, "expert_count", 0, nm, off)?,
        },
        "conv2d_fprop" | "conv2d_dgrad" | "conv2d_wgrad" | "depthwise_conv2d" => {
            let kh = get_int(a, "kernel_h", 0, nm, off)?;
            let kw = get_int(a, "kernel_w", 1, nm, off)?;
            match nm {
                "conv2d_fprop" => Operation::Conv2dFprop { kh, kw },
                "conv2d_dgrad" => Operation::Conv2dDgrad { kh, kw },
                "conv2d_wgrad" => Operation::Conv2dWgrad { kh, kw },
                _ => Operation::DepthwiseConv2d { kh, kw },
            }
        }
        "group_conv2d" => Operation::GroupConv2d {
            kh: get_int(a, "kernel_h", 0, nm, off)?,
            kw: get_int(a, "kernel_w", 1, nm, off)?,
            groups: get_int(a, "groups", 2, nm, off)?,
        },
        "conv1d_fprop" => Operation::Conv1dFprop { kw: get_int(a, "kernel_w", 0, nm, off)? },
        "depthwise_conv1d" => {
            Operation::DepthwiseConv1d { kw: get_int(a, "kernel_w", 0, nm, off)? }
        }
        "group_conv1d" => Operation::GroupConv1d {
            kw: get_int(a, "kernel_w", 0, nm, off)?,
            groups: get_int(a, "groups", 1, nm, off)?,
        },
        "conv3d_fprop" | "conv3d_dgrad" | "conv3d_wgrad" => {
            let kd = get_int(a, "kernel_d", 0, nm, off)?;
            let kh = get_int(a, "kernel_h", 1, nm, off)?;
            let kw = get_int(a, "kernel_w", 2, nm, off)?;
            match nm {
                "conv3d_fprop" => Operation::Conv3dFprop { kd, kh, kw },
                "conv3d_dgrad" => Operation::Conv3dDgrad { kd, kh, kw },
                _ => Operation::Conv3dWgrad { kd, kh, kw },
            }
        }
        "group_conv3d" => Operation::GroupConv3d {
            kd: get_int(a, "kernel_d", 0, nm, off)?,
            kh: get_int(a, "kernel_h", 1, nm, off)?,
            kw: get_int(a, "kernel_w", 2, nm, off)?,
            groups: get_int(a, "groups", 3, nm, off)?,
        },
        other => {
            return Err(DslError::at(
                DslErrorKind::Lower,
                off,
                &format!("unknown operation `{other}`"),
                "supported: gemm, batched_gemm, grouped_gemm, conv{1,2,3}d_{fprop,dgrad,wgrad}, depthwise_conv{1,2}d, group_conv{1,2,3}d",
            ))
        }
    };
    Ok(op)
}

fn lower_epilogue(call: &EpilogueCall) -> Result<EpilogueOp, DslError> {
    let a = &call.args;
    let off = call.offset;
    let op = match call.name.as_str() {
        "relu" => EpilogueOp::Relu,
        "gelu" => EpilogueOp::Gelu,
        "silu" => EpilogueOp::Silu,
        "sigmoid" => EpilogueOp::Sigmoid,
        "tanh" => EpilogueOp::Tanh,
        "mish" => EpilogueOp::Mish,
        "hardswish" => EpilogueOp::Hardswish,
        "leaky_relu" => EpilogueOp::LeakyRelu { alpha: get_float(a, "alpha", 0).unwrap_or(0.01) },
        "elu" => EpilogueOp::Elu { alpha: get_float(a, "alpha", 0).unwrap_or(1.0) },
        "clip" | "clamp" => EpilogueOp::Clip {
            lo: get_float(a, "lo", 0).or_else(|| get_float(a, "min", 0)).unwrap_or(0.0),
            hi: get_float(a, "hi", 1).or_else(|| get_float(a, "max", 1)).unwrap_or(1.0),
        },
        "bias" => EpilogueOp::Bias,
        "per_channel_scale" => EpilogueOp::PerChannelScale,
        "per_row_scale" => EpilogueOp::PerRowScale,
        "per_col_scale" => EpilogueOp::PerColScale,
        "scale" => {
            let v = get_float(a, "value", 0).ok_or_else(|| {
                DslError::at(DslErrorKind::Lower, off, "scale() needs a value", "e.g. scale(0.5)")
            })?;
            EpilogueOp::Scale { value: v }
        }
        "aux_store" | "aux_load" => {
            let name = get_ident(a, "name", 0).unwrap_or("aux").to_string();
            if call.name == "aux_store" {
                EpilogueOp::AuxStore { name }
            } else {
                EpilogueOp::AuxLoad { name }
            }
        }
        "custom" => {
            let expr = match a.first().map(|x| &x.value) {
                Some(ArgValue::Str(s)) => s.clone(),
                _ => {
                    return Err(DslError::at(
                        DslErrorKind::Lower,
                        off,
                        "custom() requires a quoted expression as its first argument",
                        "e.g. custom('x * 2 + y', inputs={'y': 'tensor'})",
                    ))
                }
            };
            let inputs = match ast::find_arg(a, "inputs", 1).map(|x| &x.value) {
                Some(ArgValue::Dict(d)) => d.clone(),
                None => Vec::new(),
                Some(other) => {
                    return Err(DslError::at(
                        DslErrorKind::Lower,
                        off,
                        &format!("custom() inputs must be a dict, got {}", other.describe()),
                        "",
                    ))
                }
            };
            EpilogueOp::Custom { expr, inputs }
        }
        other => {
            return Err(DslError::at(
                DslErrorKind::Lower,
                off,
                &format!("unknown epilogue op `{other}`"),
                "built-ins: relu, gelu, silu, sigmoid, tanh, mish, hardswish, leaky_relu, elu, clip, clamp, bias, per_channel_scale, per_row_scale, per_col_scale, scale, aux_store, aux_load, custom",
            ))
        }
    };
    Ok(op)
}

fn lower_kernel(spec: &KernelSpec) -> Result<ConfigIr, DslError> {
    let op = lower_operation(spec)?;
    let mut ir = ConfigIr::new(op, spec.offset);

    for cfg in &spec.configs {
        let a = &cfg.args;
        let off = cfg.offset;
        let dup = |field: &str| {
            DslError::at(
                DslErrorKind::Lower,
                off,
                &format!("duplicate configuration `.{field}()`"),
                "each configuration may appear at most once",
            )
        };
        match cfg.name.as_str() {
            "with_dtype" => {
                if ir.dtype_input.is_some() {
                    return Err(dup("with_dtype"));
                }
                let parse = |nm: &str, pos: usize| -> Result<DType, DslError> {
                    let s = get_ident(a, nm, pos).ok_or_else(|| {
                        DslError::at(
                            DslErrorKind::Lower,
                            off,
                            &format!("with_dtype: missing `{nm}`"),
                            "with_dtype(input=fp16, acc=fp32, output=fp16)",
                        )
                    })?;
                    DType::parse(s).ok_or_else(|| {
                        DslError::at(
                            DslErrorKind::Lower,
                            off,
                            &format!("unknown dtype `{s}`"),
                            "dtypes: fp64 fp32 tf32 fp16 bf16 fp8_e4m3 fp8_e5m2 int8 …",
                        )
                    })
                };
                ir.dtype_input = Some(parse("input", 0)?);
                ir.dtype_acc = Some(parse("acc", 1)?);
                ir.dtype_output = Some(parse("output", 2)?);
            }
            "with_layout" => {
                if ir.op.is_gemm_family() {
                    let parse = |nm: &str, pos: usize| -> Result<GemmLayout, DslError> {
                        let s = get_ident(a, nm, pos).ok_or_else(|| {
                            DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("with_layout: missing `{nm}`"),
                                "with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)",
                            )
                        })?;
                        GemmLayout::parse(s).ok_or_else(|| {
                            DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("unknown GEMM layout `{s}`"),
                                "GEMM layouts: RowMajor, ColumnMajor",
                            )
                        })
                    };
                    ir.layout_a = Some(parse("A", 0)?);
                    ir.layout_b = Some(parse("B", 1)?);
                    ir.layout_c = Some(parse("C", 2)?);
                } else {
                    let g = |nm: &str, pos: usize| -> Result<String, DslError> {
                        let s = get_ident(a, nm, pos).ok_or_else(|| {
                            DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("with_layout: missing `{nm}`"),
                                "with_layout(input=TensorNHWC, filter=TensorNHWC, output=TensorNHWC)",
                            )
                        })?;
                        if !matches!(s, "TensorNHWC" | "TensorNDHWC") {
                            return Err(DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("unknown conv layout `{s}`"),
                                "conv layouts: TensorNHWC, TensorNDHWC",
                            ));
                        }
                        Ok(s.to_string())
                    };
                    ir.conv_layouts = Some((g("input", 0)?, g("filter", 1)?, g("output", 2)?));
                }
            }
            "with_arch" => {
                if ir.arch.is_some() {
                    return Err(dup("with_arch"));
                }
                let s = get_ident(a, "arch", 0).ok_or_else(|| {
                    DslError::at(DslErrorKind::Lower, off, "with_arch: missing architecture", "")
                })?;
                ir.arch = Some(Arch::parse(s).ok_or_else(|| {
                    DslError::at(
                        DslErrorKind::Lower,
                        off,
                        &format!("unknown architecture `{s}`"),
                        "architectures: sm_70 sm_80 sm_86 sm_89 sm_90 sm_90a sm_100",
                    )
                })?);
            }
            "with_tile" | "with_threadblockshape" => {
                if ir.tile.is_some() {
                    return Err(dup(&cfg.name));
                }
                ir.tile = Some(Tile {
                    m: get_int(a, "m", 0, &cfg.name, off)?,
                    n: get_int(a, "n", 1, &cfg.name, off)?,
                    k: get_int(a, "k", 2, &cfg.name, off)?,
                });
                ir.tile_spelling = Some(if cfg.name == "with_tile" {
                    TileSpelling::WithTile
                } else {
                    TileSpelling::WithThreadblockShape
                });
            }
            "with_stages" => {
                if ir.stages.is_some() {
                    return Err(dup("with_stages"));
                }
                ir.stages = Some(get_int(a, "stages", 0, "with_stages", off)?);
            }
            "with_alignment" => {
                if ir.alignment.is_some() {
                    return Err(dup("with_alignment"));
                }
                ir.alignment = Some(Alignment {
                    a: get_int(a, "A", 0, "with_alignment", off)?,
                    b: get_int(a, "B", 1, "with_alignment", off)?,
                    c: get_int(a, "C", 2, "with_alignment", off)?,
                });
            }
            "with_cluster" => {
                if ir.cluster.is_some() {
                    return Err(dup("with_cluster"));
                }
                ir.cluster = Some(Cluster {
                    m: get_int(a, "m", 0, "with_cluster", off)?,
                    n: get_int(a, "n", 1, "with_cluster", off)?,
                    k: get_int(a, "k", 2, "with_cluster", off)?,
                });
            }
            "with_swizzle" => {
                let s = get_ident(a, "pattern", 0).ok_or_else(|| {
                    DslError::at(DslErrorKind::Lower, off, "with_swizzle: missing pattern", "")
                })?;
                ir.swizzle = Some(Swizzle::parse(s).ok_or_else(|| {
                    DslError::at(
                        DslErrorKind::Lower,
                        off,
                        &format!("unknown swizzle `{s}`"),
                        "swizzles: Identity1 Identity2 Identity4 Identity8 StreamK",
                    )
                })?);
            }
            "with_scheduler" => {
                if ir.scheduler.is_some() {
                    return Err(dup("with_scheduler"));
                }
                let mut sch = Scheduler::default();
                if let Some(s) = get_ident(a, "tile", usize::MAX) {
                    sch.tile = match s {
                        "default" => TileScheduler::Default,
                        "persistent" => TileScheduler::Persistent,
                        "stream_k" | "streamk" => TileScheduler::StreamK,
                        _ => {
                            return Err(DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("unknown tile scheduler `{s}`"),
                                "tile schedulers: default persistent stream_k",
                            ))
                        }
                    };
                }
                if let Some(s) = get_ident(a, "kernel", usize::MAX) {
                    sch.kernel = match s {
                        "auto" => KernelSchedule::Auto,
                        "cp_async" => KernelSchedule::CpAsync,
                        "cp_async_cooperative" => KernelSchedule::CpAsyncCooperative,
                        "tma" => KernelSchedule::Tma,
                        "tma_cooperative" => KernelSchedule::TmaCooperative,
                        "tma_pingpong" => KernelSchedule::TmaPingpong,
                        _ => {
                            return Err(DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("unknown kernel schedule `{s}`"),
                                "kernel schedules: auto cp_async cp_async_cooperative tma tma_cooperative tma_pingpong",
                            ))
                        }
                    };
                }
                if let Some(s) = get_ident(a, "epilogue", usize::MAX) {
                    sch.epilogue = match s {
                        "auto" => EpilogueSchedule::Auto,
                        "tma" => EpilogueSchedule::Tma,
                        "tma_cooperative" => EpilogueSchedule::TmaCooperative,
                        "no_smem" => EpilogueSchedule::NoSmem,
                        _ => {
                            return Err(DslError::at(
                                DslErrorKind::Lower,
                                off,
                                &format!("unknown epilogue schedule `{s}`"),
                                "epilogue schedules: auto tma tma_cooperative no_smem",
                            ))
                        }
                    };
                }
                ir.scheduler = Some(sch);
            }
            "with_scaling" => {
                ir.scaling = Some((
                    get_float(a, "alpha", 0).unwrap_or(1.0),
                    get_float(a, "beta", 1).unwrap_or(0.0),
                ));
            }
            "with_iterator" => {
                let s = get_ident(a, "iterator", 0).ok_or_else(|| {
                    DslError::at(DslErrorKind::Lower, off, "with_iterator: missing value", "")
                })?;
                ir.iterator = Some(match s {
                    "analytic" => Iterator_::Analytic,
                    "optimized" => Iterator_::Optimized,
                    "fixed_channels" => Iterator_::FixedChannels,
                    "few_channels" => Iterator_::FewChannels,
                    "fixed_stride_dilation" => Iterator_::FixedStrideDilation,
                    _ => {
                        return Err(DslError::at(
                            DslErrorKind::Lower,
                            off,
                            &format!("unknown iterator `{s}`"),
                            "iterators: analytic optimized fixed_channels few_channels fixed_stride_dilation",
                        ))
                    }
                });
            }
            "with_split_k" => {
                let mode = get_ident(a, "mode", 0).unwrap_or("serial");
                let m = match mode {
                    "none" => SplitK::None,
                    "serial" => SplitK::Serial,
                    "parallel" => SplitK::Parallel,
                    _ => {
                        return Err(DslError::at(
                            DslErrorKind::Lower,
                            off,
                            &format!("unknown split-k mode `{mode}`"),
                            "modes: none serial parallel",
                        ))
                    }
                };
                let slices = get_int(a, "slices", 1, "with_split_k", off).unwrap_or(1);
                ir.split_k = Some((m, slices));
            }
            "with_operand_swap" => {
                let v = get_ident(a, "value", 0).unwrap_or("true");
                ir.operand_swap = match v {
                    "true" => true,
                    "false" => false,
                    _ => {
                        return Err(DslError::at(
                            DslErrorKind::Lower,
                            off,
                            &format!("with_operand_swap takes true or false, got `{v}`"),
                            "",
                        ))
                    }
                };
            }
            other => {
                return Err(DslError::at(
                    DslErrorKind::Lower,
                    off,
                    &format!("unknown configuration `.{other}()`"),
                    "configurations: with_dtype with_layout with_arch with_tile with_threadblockshape with_stages with_alignment with_cluster with_swizzle with_scheduler with_scaling with_iterator with_split_k with_operand_swap",
                ))
            }
        }
    }

    for e in &spec.epilogue {
        ir.epilogue.push(lower_epilogue(e)?);
    }
    Ok(ir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;

    fn lower_src(src: &str) -> Result<ProgramIr, DslError> {
        lower(&parse(src).unwrap())
    }

    #[test]
    fn lowers_gemm_config() {
        let ir = lower_src(
            "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)\
             .with_arch(sm_90a).with_threadblockshape(m=128, n=128, k=64)",
        )
        .unwrap();
        let k = ir.primary().unwrap();
        assert_eq!(k.dtype_input, Some(DType::Fp16));
        assert_eq!(k.arch, Some(Arch::Sm90a));
        assert_eq!(k.tile, Some(Tile { m: 128, n: 128, k: 64 }));
        assert_eq!(k.tile_spelling, Some(TileSpelling::WithThreadblockShape));
    }

    #[test]
    fn lowers_epilogue_chain() {
        let ir = lower_src("gemm() >> bias() >> leaky_relu(alpha=0.2) >> scale(0.5)").unwrap();
        let k = ir.primary().unwrap();
        assert_eq!(k.epilogue.len(), 3);
        assert!(matches!(k.epilogue[1], EpilogueOp::LeakyRelu { alpha } if alpha == 0.2));
        assert!(matches!(k.epilogue[2], EpilogueOp::Scale { value } if value == 0.5));
    }

    #[test]
    fn rejects_unknown_dtype() {
        let e = lower_src("gemm().with_dtype(input=fp12, acc=fp32, output=fp32)").unwrap_err();
        assert!(e.to_string().contains("unknown dtype"));
    }

    #[test]
    fn rejects_unknown_operation() {
        let e = lower_src("gemv()").unwrap_err();
        assert!(e.to_string().contains("unknown operation"));
    }

    #[test]
    fn rejects_duplicate_config() {
        let e = lower_src("gemm().with_arch(sm_80).with_arch(sm_90a)").unwrap_err();
        assert!(e.to_string().contains("duplicate"));
    }

    #[test]
    fn lowers_pipeline() {
        let ir = lower_src(
            "pipeline(transpose(input, NCL, NLC, fp32, fp16), gemm().with_arch(sm_90a))",
        )
        .unwrap();
        match ir {
            ProgramIr::Pipeline(p) => {
                assert_eq!(p.stages.len(), 2);
                assert!(matches!(&p.stages[0],
                    StageIr::Transpose { from_dtype: Some(DType::Fp32), to_dtype: Some(DType::Fp16), .. }));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lowers_grouped_gemm() {
        let ir = lower_src("grouped_gemm(expert_count=8).with_arch(sm_90a)").unwrap();
        assert!(matches!(ir.primary().unwrap().op, Operation::GroupedGemm { expert_count: 8 }));
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::Fp32.size(), 4);
        assert_eq!(DType::Bf16.size(), 2);
        assert_eq!(DType::Fp8E4m3.size(), 1);
        assert_eq!(DType::Tf32.size(), 4);
    }
}
