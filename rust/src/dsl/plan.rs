//! `KernelPlan` — the pre-resolved lowering artifact (LIR) every consumer
//! layer reads (ADR-001, `rust/docs/adr/001-kernel-plan-lir.md`).
//!
//! `dsl::compile` lowers a validated [`ProgramIr`] into a `KernelPlan`
//! exactly once per candidate. The plan carries *effective* values — tile,
//! cluster, dtypes, stage count, scheduler, alignment — with every default
//! already applied, plus derived facts (per-stage SMEM, epilogue SMEM,
//! per-tile FLOPs and DRAM traffic) and the canonical configuration hash.
//!
//! Downstream layers consume the plan instead of re-deriving from the IR:
//!
//! * [`crate::dsl::codegen`] — syntax-directed emission from plan fields;
//! * [`crate::perfmodel`] — `CandidateConfig::from_plan`;
//! * [`crate::runtime`] — AOT variant selection on plan tile/dtype;
//! * [`crate::agent`] / [`crate::mantis`] — plan cache keyed by the config
//!   hash, plan threaded through attempt records;
//! * [`crate::integrity`] — dtype-aware SOL-ceiling bound.
//!
//! The configuration hash is a canonical field-by-field serialization
//! (replacing the earlier `format!("{ir:?}")` FNV hash, which was hostage
//! to `Debug` formatting: a field omitted from — or added to — a `Debug`
//! impl would silently change or collide hashes). Source offsets are
//! deliberately excluded: the hash identifies the *configuration*, not the
//! source text.

use std::fmt::Write as _;

use super::ir::*;

// ---------------------------------------------------------------------------
// Derived-fact helpers (shared with validate.rs so the budget the validator
// enforces is byte-identical to the one the plan reports)
// ---------------------------------------------------------------------------

/// SMEM bytes one pipeline stage stages for the A and B tiles.
pub fn stage_smem_bytes(tile: Tile, input: DType) -> u64 {
    (tile.m * tile.k + tile.k * tile.n) * input.size()
}

/// Epilogue SMEM estimate used in the stage-budget formula: TMA epilogues
/// stage the output tile through shared memory.
pub fn epilogue_smem_bytes(epilogue: EpilogueSchedule, tile: Tile, output: DType) -> u64 {
    match epilogue {
        EpilogueSchedule::NoSmem => 0,
        // auto/tma/tma_cooperative: one output sub-tile (m × n/2) staged
        _ => tile.m * (tile.n / 2).max(8) * output.size() / 2,
    }
}

// ---------------------------------------------------------------------------
// Plan types
// ---------------------------------------------------------------------------

/// One kernel stage, fully resolved: every `Option` of [`ConfigIr`] that
/// has a defined default is collapsed to its effective value.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelStagePlan {
    pub op: Operation,
    /// Operation family name ("gemm", "conv2d_fprop", …).
    pub family: String,
    pub arch: Arch,
    /// Effective threadblock tile.
    pub tile: Tile,
    /// Effective cluster shape (1×1×1 when unset / pre-SM90).
    pub cluster: Cluster,
    pub dtype_input: DType,
    pub dtype_acc: DType,
    pub dtype_output: DType,
    /// GEMM operand layouts (A, B, C); `None` for conv-family ops.
    pub gemm_layouts: Option<(GemmLayout, GemmLayout, GemmLayout)>,
    /// Conv tensor layouts (input, filter, output) when specified.
    pub conv_layouts: Option<(String, String, String)>,
    /// Effective per-operand alignment in elements.
    pub alignment: Alignment,
    /// Effective pipeline stage count.
    pub stages: u64,
    /// True when the program stated `.with_stages(…)` explicitly (SM90
    /// codegen emits `StageCount<N>` vs `StageCountAuto`).
    pub explicit_stages: bool,
    /// Effective scheduler triple (defaults applied).
    pub scheduler: Scheduler,
    pub swizzle: Option<Swizzle>,
    pub iterator: Option<Iterator_>,
    pub split_k: Option<(SplitK, u64)>,
    pub operand_swap: bool,
    /// Effective (alpha, beta) scaling.
    pub scaling: (f64, f64),
    /// Epilogue chain in application order.
    pub epilogue: Vec<EpilogueOp>,
    // --- derived facts (what the cost model / validator / SOL read) -------
    /// SMEM bytes per pipeline stage (A + B tiles).
    pub smem_per_stage_bytes: u64,
    /// SMEM bytes the epilogue stages through shared memory.
    pub epilogue_smem_bytes: u64,
    /// Total SMEM demand: `stages × per_stage + epilogue`.
    pub smem_bytes: u64,
    /// MAC FLOPs one output tile performs (2·m·n·k).
    pub flops_per_tile: u64,
    /// Best-case DRAM traffic per tile: A + B tiles in, C tile out.
    pub dram_bytes_per_tile: u64,
}

impl KernelStagePlan {
    /// Epilogue op names in chain order (the runtime/report view).
    pub fn epilogue_names(&self) -> Vec<String> {
        self.epilogue.iter().map(|e| e.name().to_string()).collect()
    }

    /// True when the compute dtype rides reduced-precision tensor cores
    /// (FP16/BF16/FP8) — the integrity SOL-ceiling picks its bound on this.
    pub fn reduced_precision(&self) -> bool {
        matches!(self.dtype_input, DType::Fp16 | DType::Bf16)
            || self.dtype_input.is_fp8()
    }
}

/// One stage of the plan: a resolved kernel or a data transform.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanStage {
    Kernel(KernelStagePlan),
    Transform {
        target: String,
        from_layout: String,
        to_layout: String,
        from_dtype: Option<DType>,
        to_dtype: Option<DType>,
    },
}

/// The pre-resolved, canonically ordered lowering artifact for a program.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelPlan {
    /// Stages in program order (a single kernel for non-pipelines).
    pub stages: Vec<PlanStage>,
    /// Total stage count (1 for a single kernel; kernels + transforms for
    /// pipelines) — the runtime's pipeline-depth view.
    pub pipeline_stages: usize,
    /// True when the program was written as `pipeline(...)` — a
    /// single-stage pipeline still gets the multi-stage driver entry point.
    pub is_pipeline: bool,
    /// Canonical configuration hash (hex, 16 chars).
    pub config_hash: String,
}

impl KernelPlan {
    /// Lower a **validated** program into its plan. Panics on programs that
    /// did not pass [`crate::dsl::validate::validate`] (missing arch/dtype).
    pub fn from_ir(ir: &ProgramIr) -> KernelPlan {
        Self::from_ir_hashed(ir, config_hash(ir))
    }

    /// [`KernelPlan::from_ir`] with an already-computed canonical hash
    /// (the cached compile path hashes before validation; don't hash twice).
    pub fn from_ir_hashed(ir: &ProgramIr, config_hash: String) -> KernelPlan {
        let stages = match ir {
            ProgramIr::Kernel(k) => vec![PlanStage::Kernel(resolve_kernel(k))],
            ProgramIr::Pipeline(p) => p
                .stages
                .iter()
                .map(|s| match s {
                    StageIr::Kernel(k) => PlanStage::Kernel(resolve_kernel(k)),
                    StageIr::Transpose { target, from_layout, to_layout, from_dtype, to_dtype } => {
                        PlanStage::Transform {
                            target: target.clone(),
                            from_layout: from_layout.clone(),
                            to_layout: to_layout.clone(),
                            from_dtype: *from_dtype,
                            to_dtype: *to_dtype,
                        }
                    }
                })
                .collect(),
        };
        let pipeline_stages = stages.len();
        KernelPlan {
            stages,
            pipeline_stages,
            is_pipeline: matches!(ir, ProgramIr::Pipeline(_)),
            config_hash,
        }
    }

    /// All resolved kernel stages in program order.
    pub fn kernels(&self) -> Vec<&KernelStagePlan> {
        self.stages
            .iter()
            .filter_map(|s| match s {
                PlanStage::Kernel(k) => Some(k),
                _ => None,
            })
            .collect()
    }

    /// The primary (first) kernel stage. Validated programs always have
    /// one. Allocation-free: this sits on the per-attempt hot path.
    pub fn primary(&self) -> &KernelStagePlan {
        self.stages
            .iter()
            .find_map(|s| match s {
                PlanStage::Kernel(k) => Some(k),
                _ => None,
            })
            .expect("validated programs have at least one kernel stage")
    }
}

/// Collapse a validated kernel config to its effective values.
fn resolve_kernel(k: &ConfigIr) -> KernelStagePlan {
    let arch = k.arch.expect("plan lowering requires a validated program (arch)");
    let din = k.dtype_input.expect("plan lowering requires a validated program (dtype)");
    let dacc = k.dtype_acc.unwrap_or(DType::Fp32);
    let dout = k.dtype_output.unwrap_or(din);
    let tile = k.effective_tile();
    let cluster = k.cluster.unwrap_or(Cluster { m: 1, n: 1, k: 1 });
    let alignment = k.alignment.unwrap_or(Alignment { a: 8, b: 8, c: 8 });
    let stages = k.effective_stages();
    let scheduler = k.scheduler.unwrap_or_default();
    let smem_per_stage = stage_smem_bytes(tile, din);
    let epi_smem = epilogue_smem_bytes(scheduler.epilogue, tile, dout);
    KernelStagePlan {
        family: k.op.family().to_string(),
        op: k.op.clone(),
        arch,
        tile,
        cluster,
        dtype_input: din,
        dtype_acc: dacc,
        dtype_output: dout,
        gemm_layouts: match (k.layout_a, k.layout_b, k.layout_c) {
            (Some(a), Some(b), Some(c)) => Some((a, b, c)),
            _ => None,
        },
        conv_layouts: k.conv_layouts.clone(),
        alignment,
        stages,
        explicit_stages: k.stages.is_some(),
        scheduler,
        swizzle: k.swizzle,
        iterator: k.iterator,
        split_k: k.split_k,
        operand_swap: k.operand_swap,
        scaling: k.scaling.unwrap_or((1.0, 0.0)),
        epilogue: k.epilogue.clone(),
        smem_per_stage_bytes: smem_per_stage,
        epilogue_smem_bytes: epi_smem,
        smem_bytes: stages * smem_per_stage + epi_smem,
        flops_per_tile: 2 * tile.m * tile.n * tile.k,
        dram_bytes_per_tile: (tile.m * tile.k + tile.k * tile.n) * din.size()
            + tile.m * tile.n * dout.size(),
    }
}

// ---------------------------------------------------------------------------
// Canonical configuration hash
// ---------------------------------------------------------------------------

/// Canonical configuration hash of a (possibly not yet validated) program:
/// FNV-1a over an explicit field-by-field serialization of every
/// configuration axis. Two programs hash equal iff their configurations
/// are identical; source text, formatting, and offsets never contribute.
pub fn config_hash(ir: &ProgramIr) -> String {
    let mut canon = String::with_capacity(512);
    canon_program(&mut canon, ir);
    format!("{:016x}", crate::util::fnv64(canon.as_bytes()))
}

fn canon_program(out: &mut String, ir: &ProgramIr) {
    match ir {
        ProgramIr::Kernel(k) => {
            out.push_str("K|");
            canon_kernel(out, k);
        }
        ProgramIr::Pipeline(p) => {
            out.push_str("P|");
            for s in &p.stages {
                match s {
                    StageIr::Kernel(k) => {
                        out.push_str("k{");
                        canon_kernel(out, k);
                        out.push('}');
                    }
                    StageIr::Transpose { target, from_layout, to_layout, from_dtype, to_dtype } => {
                        out.push_str("t{");
                        canon_str(out, target);
                        canon_str(out, from_layout);
                        canon_str(out, to_layout);
                        canon_opt(out, from_dtype.map(|d| d.to_string()));
                        canon_opt(out, to_dtype.map(|d| d.to_string()));
                        out.push('}');
                    }
                }
            }
        }
    }
}

/// Length-prefixed string so arbitrary text (custom exprs, layout names)
/// cannot forge field boundaries.
fn canon_str(out: &mut String, s: &str) {
    let _ = write!(out, "{}:{s};", s.len());
}

fn canon_opt(out: &mut String, v: Option<impl std::fmt::Display>) {
    match v {
        Some(v) => {
            let _ = write!(out, "{v};");
        }
        None => out.push_str("~;"),
    }
}

fn canon_kernel(out: &mut String, k: &ConfigIr) {
    // NOTE: every field of ConfigIr except `offset` must be serialized
    // here; the hash-perturbation unit test below enforces it.
    out.push_str("op=");
    canon_op(out, &k.op);
    out.push_str("arch=");
    canon_opt(out, k.arch);
    out.push_str("din=");
    canon_opt(out, k.dtype_input);
    out.push_str("dacc=");
    canon_opt(out, k.dtype_acc);
    out.push_str("dout=");
    canon_opt(out, k.dtype_output);
    out.push_str("la=");
    canon_opt(out, k.layout_a.map(layout_tag));
    out.push_str("lb=");
    canon_opt(out, k.layout_b.map(layout_tag));
    out.push_str("lc=");
    canon_opt(out, k.layout_c.map(layout_tag));
    out.push_str("cl=");
    match &k.conv_layouts {
        Some((i, f, o)) => {
            canon_str(out, i);
            canon_str(out, f);
            canon_str(out, o);
        }
        None => out.push_str("~;"),
    }
    out.push_str("tile=");
    canon_opt(out, k.tile.map(|t| format!("{}x{}x{}", t.m, t.n, t.k)));
    out.push_str("spell=");
    canon_opt(out, k.tile_spelling.map(|s| match s {
        TileSpelling::WithTile => "tile",
        TileSpelling::WithThreadblockShape => "tbs",
    }));
    out.push_str("stages=");
    canon_opt(out, k.stages);
    out.push_str("align=");
    canon_opt(out, k.alignment.map(|a| format!("{}x{}x{}", a.a, a.b, a.c)));
    out.push_str("cluster=");
    canon_opt(out, k.cluster.map(|c| format!("{}x{}x{}", c.m, c.n, c.k)));
    out.push_str("swz=");
    canon_opt(out, k.swizzle.map(|s| format!("{s:?}")));
    out.push_str("sched=");
    canon_opt(
        out,
        k.scheduler.map(|s| format!("{:?}/{:?}/{:?}", s.tile, s.kernel, s.epilogue)),
    );
    out.push_str("scale=");
    canon_opt(out, k.scaling.map(|(a, b)| format!("{a:?},{b:?}")));
    out.push_str("iter=");
    canon_opt(out, k.iterator.map(|i| format!("{i:?}")));
    out.push_str("splitk=");
    canon_opt(out, k.split_k.map(|(m, s)| format!("{m:?}/{s}")));
    let _ = write!(out, "swap={};", k.operand_swap);
    out.push_str("epi=[");
    for e in &k.epilogue {
        canon_epilogue(out, e);
    }
    out.push(']');
}

fn layout_tag(l: GemmLayout) -> &'static str {
    match l {
        GemmLayout::RowMajor => "row",
        GemmLayout::ColumnMajor => "col",
    }
}

fn canon_op(out: &mut String, op: &Operation) {
    let _ = write!(out, "{};", op.family());
    match op {
        Operation::Gemm | Operation::BatchedGemm => {}
        Operation::GroupedGemm { expert_count } => {
            let _ = write!(out, "e={expert_count};");
        }
        Operation::Conv2dFprop { kh, kw }
        | Operation::Conv2dDgrad { kh, kw }
        | Operation::Conv2dWgrad { kh, kw }
        | Operation::DepthwiseConv2d { kh, kw } => {
            let _ = write!(out, "kh={kh};kw={kw};");
        }
        Operation::Conv1dFprop { kw } | Operation::DepthwiseConv1d { kw } => {
            let _ = write!(out, "kw={kw};");
        }
        Operation::GroupConv1d { kw, groups } => {
            let _ = write!(out, "kw={kw};g={groups};");
        }
        Operation::Conv3dFprop { kd, kh, kw }
        | Operation::Conv3dDgrad { kd, kh, kw }
        | Operation::Conv3dWgrad { kd, kh, kw } => {
            let _ = write!(out, "kd={kd};kh={kh};kw={kw};");
        }
        Operation::GroupConv2d { kh, kw, groups } => {
            let _ = write!(out, "kh={kh};kw={kw};g={groups};");
        }
        Operation::GroupConv3d { kd, kh, kw, groups } => {
            let _ = write!(out, "kd={kd};kh={kh};kw={kw};g={groups};");
        }
    }
}

fn canon_epilogue(out: &mut String, e: &EpilogueOp) {
    let _ = write!(out, "{};", e.name());
    match e {
        EpilogueOp::LeakyRelu { alpha } | EpilogueOp::Elu { alpha } => {
            let _ = write!(out, "a={alpha:?};");
        }
        EpilogueOp::Clip { lo, hi } => {
            let _ = write!(out, "lo={lo:?};hi={hi:?};");
        }
        EpilogueOp::Scale { value } => {
            let _ = write!(out, "v={value:?};");
        }
        EpilogueOp::AuxStore { name } | EpilogueOp::AuxLoad { name } => {
            canon_str(out, name);
        }
        EpilogueOp::Custom { expr, inputs } => {
            canon_str(out, expr);
            for (kk, vv) in inputs {
                canon_str(out, kk);
                canon_str(out, vv);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;

    const SM90: &str = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
        .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)\
        .with_stages(2).with_scheduler(kernel=tma_cooperative, epilogue=auto)\
        >> bias() >> relu()";

    #[test]
    fn plan_resolves_effective_values() {
        let c = dsl::compile(SM90).unwrap();
        let k = c.plan.primary();
        assert_eq!(k.family, "gemm");
        assert_eq!((k.tile.m, k.tile.n, k.tile.k), (128, 128, 64));
        assert_eq!((k.cluster.m, k.cluster.n, k.cluster.k), (1, 1, 1), "cluster default applied");
        assert_eq!(k.dtype_input, DType::Fp16);
        assert_eq!(k.dtype_acc, DType::Fp32);
        assert_eq!(k.dtype_output, DType::Fp16);
        assert_eq!(k.stages, 2);
        assert!(k.explicit_stages);
        assert_eq!(k.scheduler.kernel, KernelSchedule::TmaCooperative);
        assert_eq!(k.epilogue_names(), vec!["bias", "relu"]);
        assert_eq!(k.smem_per_stage_bytes, (128 * 64 + 64 * 128) * 2);
        assert_eq!(k.smem_bytes, 2 * k.smem_per_stage_bytes + k.epilogue_smem_bytes);
        assert_eq!(k.flops_per_tile, 2 * 128 * 128 * 64);
        assert!(k.reduced_precision());
        assert_eq!(c.plan.pipeline_stages, 1);
        assert!(!c.plan.is_pipeline);
        assert_eq!(c.plan.config_hash, c.hash());
    }

    #[test]
    fn plan_defaults_when_omitted() {
        let c = dsl::compile(
            "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
             .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_80)",
        )
        .unwrap();
        let k = c.plan.primary();
        assert_eq!((k.tile.m, k.tile.n, k.tile.k), (128, 128, 32), "tile default");
        assert_eq!(k.stages, 3, "stage default");
        assert!(!k.explicit_stages);
        assert_eq!(k.alignment.a, 8, "alignment default");
        assert_eq!(k.scaling, (1.0, 0.0));
        assert!(!k.reduced_precision());
    }

    #[test]
    fn plan_covers_pipelines() {
        let c = dsl::compile(
            "pipeline(transpose(input, NCL, NLC, fp32, fp16), \
             gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a), \
             transpose(output, NLC, NCL, fp16, fp32))",
        )
        .unwrap();
        assert_eq!(c.plan.pipeline_stages, 3);
        assert_eq!(c.plan.kernels().len(), 1);
        assert!(matches!(
            &c.plan.stages[0],
            PlanStage::Transform { from_dtype: Some(DType::Fp32), .. }
        ));
    }

    // -- canonical hash ----------------------------------------------------

    fn base_ir() -> ConfigIr {
        let mut k = ConfigIr::new(Operation::Gemm, 0);
        k.arch = Some(Arch::Sm90a);
        k.dtype_input = Some(DType::Fp16);
        k.dtype_acc = Some(DType::Fp32);
        k.dtype_output = Some(DType::Fp16);
        k.layout_a = Some(GemmLayout::RowMajor);
        k.layout_b = Some(GemmLayout::ColumnMajor);
        k.layout_c = Some(GemmLayout::RowMajor);
        k.conv_layouts = None;
        k.tile = Some(Tile { m: 128, n: 128, k: 64 });
        k.tile_spelling = Some(TileSpelling::WithThreadblockShape);
        k.stages = Some(2);
        k.alignment = Some(Alignment { a: 8, b: 8, c: 8 });
        k.cluster = Some(Cluster { m: 2, n: 1, k: 1 });
        k.swizzle = None;
        k.scheduler = Some(Scheduler::default());
        k.scaling = Some((1.0, 0.0));
        k.iterator = None;
        k.split_k = None;
        k.operand_swap = false;
        k.epilogue = vec![EpilogueOp::Bias, EpilogueOp::Relu];
        k
    }

    fn hash_of(k: ConfigIr) -> String {
        config_hash(&ProgramIr::Kernel(k))
    }

    /// The satellite regression test: perturbing EVERY configuration field
    /// of ConfigIr must change the canonical hash (the old Debug-format
    /// hash was hostage to derive/format details).
    #[test]
    fn hash_changes_on_every_field_perturbation() {
        let base = hash_of(base_ir());
        let perturbations: Vec<(&str, Box<dyn Fn(&mut ConfigIr)>)> = vec![
            ("op", Box::new(|k| k.op = Operation::BatchedGemm)),
            ("op-param", Box::new(|k| k.op = Operation::GroupedGemm { expert_count: 4 })),
            ("arch", Box::new(|k| k.arch = Some(Arch::Sm80))),
            ("dtype_input", Box::new(|k| k.dtype_input = Some(DType::Bf16))),
            ("dtype_acc", Box::new(|k| k.dtype_acc = Some(DType::Fp16))),
            ("dtype_output", Box::new(|k| k.dtype_output = Some(DType::Fp32))),
            ("layout_a", Box::new(|k| k.layout_a = Some(GemmLayout::ColumnMajor))),
            ("layout_b", Box::new(|k| k.layout_b = Some(GemmLayout::RowMajor))),
            ("layout_c", Box::new(|k| k.layout_c = Some(GemmLayout::ColumnMajor))),
            ("conv_layouts", Box::new(|k| {
                k.conv_layouts =
                    Some(("TensorNHWC".into(), "TensorNHWC".into(), "TensorNHWC".into()))
            })),
            ("tile", Box::new(|k| k.tile = Some(Tile { m: 128, n: 128, k: 32 }))),
            ("tile_spelling", Box::new(|k| k.tile_spelling = Some(TileSpelling::WithTile))),
            ("stages", Box::new(|k| k.stages = Some(3))),
            ("stages-none", Box::new(|k| k.stages = None)),
            ("alignment", Box::new(|k| k.alignment = Some(Alignment { a: 4, b: 8, c: 8 }))),
            ("cluster", Box::new(|k| k.cluster = Some(Cluster { m: 1, n: 1, k: 1 }))),
            ("swizzle", Box::new(|k| k.swizzle = Some(Swizzle::StreamK))),
            ("scheduler", Box::new(|k| {
                k.scheduler = Some(Scheduler {
                    tile: TileScheduler::StreamK,
                    kernel: KernelSchedule::Tma,
                    epilogue: EpilogueSchedule::Auto,
                })
            })),
            ("scaling", Box::new(|k| k.scaling = Some((0.5, 0.0)))),
            ("iterator", Box::new(|k| k.iterator = Some(Iterator_::Optimized))),
            ("split_k", Box::new(|k| k.split_k = Some((SplitK::Serial, 2)))),
            ("operand_swap", Box::new(|k| k.operand_swap = true)),
            ("epilogue-order", Box::new(|k| {
                k.epilogue = vec![EpilogueOp::Relu, EpilogueOp::Bias]
            })),
            ("epilogue-param", Box::new(|k| {
                k.epilogue = vec![EpilogueOp::Bias, EpilogueOp::LeakyRelu { alpha: 0.2 }]
            })),
            ("epilogue-custom", Box::new(|k| {
                k.epilogue = vec![EpilogueOp::Custom { expr: "x * 2".into(), inputs: vec![] }]
            })),
        ];
        for (name, f) in perturbations {
            let mut k = base_ir();
            f(&mut k);
            assert_ne!(hash_of(k), base, "perturbing `{name}` must change the hash");
        }
    }

    #[test]
    fn hash_ignores_source_offsets() {
        let mut k = base_ir();
        k.offset = 57;
        assert_eq!(hash_of(k), hash_of(base_ir()), "offsets are not configuration");
    }

    #[test]
    fn hash_distinguishes_kernel_from_pipeline() {
        let k = base_ir();
        let single = config_hash(&ProgramIr::Kernel(k.clone()));
        let pipe = config_hash(&ProgramIr::Pipeline(PipelineIr {
            stages: vec![StageIr::Kernel(k)],
        }));
        assert_ne!(single, pipe);
    }

    #[test]
    fn custom_expr_cannot_forge_field_boundaries() {
        let mut a = base_ir();
        a.epilogue = vec![EpilogueOp::Custom { expr: "x;bias".into(), inputs: vec![] }];
        let mut b = base_ir();
        b.epilogue = vec![
            EpilogueOp::Custom { expr: "x".into(), inputs: vec![] },
            EpilogueOp::Bias,
        ];
        assert_ne!(hash_of(a), hash_of(b));
    }
}
