//! µCUTLASS: a compact DSL for CUTLASS-style GPU kernels (paper §3).
//!
//! The pipeline mirrors Figure 1 of the paper, extended with the ADR-001
//! lowering artifact:
//!
//! ```text
//!   kernel.dsl ──lex──▶ tokens ──parse──▶ AST ──lower──▶ typed ConfigIR
//!       ──validate (per-arch ConstraintTable: gating, alignment, SMEM)──▶
//!       ──plan (KernelPlan: pre-resolved tiles/dtypes/stages/SMEM/hash)──▶
//!       ──codegen──▶ { CUTLASS-style C++ header, KernelPlan }
//! ```
//!
//! The grammar is the paper's Appendix A.1 EBNF; the validation rules are
//! the compiler-enforced CONSTRAINTS block of that grammar, implemented in
//! [`validate`] as an interpreter over per-architecture
//! [`validate::ConstraintTable`] rows. When validation fails the error
//! explains *what* and *why* (the paper stresses this lets the model fix
//! the spec before burning a compile/run/profile attempt).
//!
//! Every consumer layer reads the [`plan::KernelPlan`] instead of
//! re-deriving configuration facts; the agent loop compiles through
//! [`compile_cached`] so identical candidate configurations within a run
//! skip re-lowering and re-generation entirely.
//!
//! ```no_run
//! use ucutlass_repro::dsl;
//! let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\n\
//!            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)\n\
//!            .with_arch(sm_90a)\n\
//!            .with_threadblockshape(m=128, n=128, k=64)\n\
//!            .with_alignment(A=8, B=8, C=8)\n\
//!            .with_stages(2)\n\
//!            .with_scheduler(kernel=tma_cooperative, epilogue=auto)\n\
//!            >> bias() >> relu()";
//! let compiled = dsl::compile(src).unwrap();
//! assert!(compiled.header.contains("CollectiveBuilder"));
//! assert_eq!(compiled.plan.primary().stages, 2);
//! ```

pub mod ast;
pub mod codegen;
pub mod error;
pub mod format;
pub mod ir;
pub mod parser;
pub mod plan;
pub mod token;
pub mod validate;

use std::collections::HashMap;

pub use ast::{EpilogueCall, KernelSpec, Program, Stage, TransposeSpec};
pub use codegen::Compiled;
pub use error::{DslError, DslErrorKind};
pub use ir::{Arch, ConfigIr, DType, EpilogueOp, GemmLayout, Operation, PipelineIr,
             ProgramIr, Scheduler};
pub use plan::{KernelPlan, KernelStagePlan, PlanStage};
pub use validate::{constraint_table, ConstraintTable};

/// Compile a µCUTLASS program: parse → lower → validate → plan → codegen.
pub fn compile(source: &str) -> Result<Compiled, DslError> {
    let ir = validate_source(source)?;
    Ok(codegen::generate(source, &ir))
}

/// Parse → lower → validate, without planning or code generation. This is
/// the agent repair loop: generate→validate→repair only needs the accept/
/// reject verdict (planning + codegen run once, for the accepted program).
pub fn validate_source(source: &str) -> Result<ProgramIr, DslError> {
    let program = parser::parse(source)?;
    let ir = ir::lower(&program)?;
    validate::validate(&ir)?;
    Ok(ir)
}

/// Compile and additionally bind against concrete problem dimensions,
/// running the dimension-dependent checks (operand-swap M==N, alignment
/// divisibility). `dims` is (M, N, K) for GEMM-family ops.
pub fn compile_bound(source: &str, dims: (u64, u64, u64)) -> Result<Compiled, DslError> {
    let compiled = compile(source)?;
    validate::validate_bound(&compiled.ir, dims)?;
    Ok(compiled)
}

/// Plan cache for the agent hot loop: compiled artifacts keyed by the
/// canonical configuration hash, with a source-string memo in front so a
/// verbatim repeat costs one map lookup plus an `Arc` bump (no re-parse,
/// no re-lower, no re-validate, no re-generation, no deep clone).
#[derive(Debug, Default)]
pub struct PlanCache {
    /// source text → config hash (fast path for verbatim repeats).
    by_source: HashMap<String, String>,
    /// config hash → compiled artifact (the canonical store).
    by_hash: HashMap<String, std::sync::Arc<Compiled>>,
    pub hits: u64,
    pub misses: u64,
}

/// Cap on the source-text memo: beyond this many distinct spellings the
/// cache still hits at the hash level, it just re-runs parse+lower first
/// (bounds memory on very long runs with many formatting variants).
const SOURCE_MEMO_CAP: usize = 4096;

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct configurations cached.
    pub fn len(&self) -> usize {
        self.by_hash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }

    /// Fast path: this exact source text was compiled before.
    fn hit_by_source(&mut self, source: &str) -> Option<std::sync::Arc<Compiled>> {
        if let Some(hash) = self.by_source.get(source) {
            if let Some(c) = self.by_hash.get(hash) {
                let out = c.clone();
                self.hits += 1;
                return Some(out);
            }
        }
        None
    }

    /// Hash-level hit: a differently-spelled but identical configuration
    /// was compiled before; memoize the new spelling.
    fn hit_by_hash(&mut self, source: &str, hash: &str) -> Option<std::sync::Arc<Compiled>> {
        if let Some(c) = self.by_hash.get(hash) {
            let out = c.clone();
            self.hits += 1;
            self.memo_source(source, hash);
            return Some(out);
        }
        None
    }

    fn memo_source(&mut self, source: &str, hash: &str) {
        if self.by_source.len() < SOURCE_MEMO_CAP {
            self.by_source.insert(source.to_string(), hash.to_string());
        }
    }
}

/// [`compile`] with a [`PlanCache`]: repeated candidate configurations
/// within a run skip re-lowering/re-generation (the cache is keyed by the
/// canonical config hash, so differently-formatted sources with identical
/// configurations also hit). Cached entries embed the header of the first
/// compile — the hash guarantees the configuration is identical.
pub fn compile_cached(
    source: &str,
    cache: &mut PlanCache,
) -> Result<std::sync::Arc<Compiled>, DslError> {
    if let Some(c) = cache.hit_by_source(source) {
        return Ok(c);
    }
    let program = parser::parse(source)?;
    let ir = ir::lower(&program)?;
    let hash = plan::config_hash(&ir);
    if let Some(c) = cache.hit_by_hash(source, &hash) {
        return Ok(c);
    }
    validate::validate(&ir)?;
    Ok(cache_miss_insert(source, &ir, hash, cache))
}

/// [`compile_cached`] for a caller that already holds the lowered,
/// **validated** IR of `source` (the agent repair loop validates during
/// generation): skips re-parse, re-lower, and re-validate entirely.
pub fn compile_lowered(
    source: &str,
    ir: &ProgramIr,
    cache: &mut PlanCache,
) -> std::sync::Arc<Compiled> {
    if let Some(c) = cache.hit_by_source(source) {
        return c;
    }
    let hash = plan::config_hash(ir);
    if let Some(c) = cache.hit_by_hash(source, &hash) {
        return c;
    }
    cache_miss_insert(source, ir, hash, cache)
}

/// Shared miss path: plan from the precomputed hash, generate, insert.
fn cache_miss_insert(
    source: &str,
    ir: &ProgramIr,
    hash: String,
    cache: &mut PlanCache,
) -> std::sync::Arc<Compiled> {
    let planned = plan::KernelPlan::from_ir_hashed(ir, hash.clone());
    let compiled = std::sync::Arc::new(codegen::generate_planned(source, ir, planned));
    cache.misses += 1;
    cache.memo_source(source, &hash);
    cache.by_hash.insert(hash, compiled.clone());
    compiled
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
        .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)\
        .with_stages(2).with_scheduler(kernel=tma_cooperative, epilogue=auto)\
        >> bias() >> relu()";

    #[test]
    fn doc_example_compiles() {
        let c = compile(SRC).unwrap();
        assert_eq!(c.plan.primary().family, "gemm");
        assert!(c.header.contains("ucutlass_"));
    }

    #[test]
    fn cache_hits_on_identical_source() {
        let mut cache = PlanCache::new();
        let a = compile_cached(SRC, &mut cache).unwrap();
        let b = compile_cached(SRC, &mut cache).unwrap();
        assert_eq!(a.hash(), b.hash());
        assert_eq!(a.header, b.header);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_hits_on_reformatted_source() {
        let mut cache = PlanCache::new();
        compile_cached(SRC, &mut cache).unwrap();
        // same configuration, different formatting → same config hash
        let reformatted = SRC.replace(").with_arch", ")  .with_arch");
        let c = compile_cached(&reformatted, &mut cache).unwrap();
        assert_eq!(cache.hits, 1, "config-hash level hit despite new source text");
        assert_eq!(cache.len(), 1);
        assert_eq!(c.hash(), compile(SRC).unwrap().hash());
    }

    #[test]
    fn cache_misses_on_different_config() {
        let mut cache = PlanCache::new();
        compile_cached(SRC, &mut cache).unwrap();
        compile_cached(&SRC.replace("n=128", "n=64"), &mut cache).unwrap();
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn compile_lowered_shares_the_cache() {
        let mut cache = PlanCache::new();
        let ir = validate_source(SRC).unwrap();
        let a = compile_lowered(SRC, &ir, &mut cache);
        assert_eq!(cache.misses, 1);
        let b = compile_cached(SRC, &mut cache).unwrap();
        assert_eq!(cache.hits, 1, "both entry points share one store");
        assert_eq!(a.hash(), b.hash());
    }

    #[test]
    fn cache_propagates_rejections() {
        let mut cache = PlanCache::new();
        let bad = SRC.replace("sm_90a", "sm_90");
        assert!(compile_cached(&bad, &mut cache).is_err());
        assert!(cache.is_empty(), "rejected programs are not cached");
    }

    #[test]
    fn cached_equals_uncached() {
        let mut cache = PlanCache::new();
        let warm = compile_cached(SRC, &mut cache).unwrap();
        let warm2 = compile_cached(SRC, &mut cache).unwrap();
        let cold = compile(SRC).unwrap();
        assert_eq!(warm.hash(), cold.hash());
        assert_eq!(warm2.header, cold.header);
        assert_eq!(warm.plan, cold.plan);
    }
}
