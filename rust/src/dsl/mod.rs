//! µCUTLASS: a compact DSL for CUTLASS-style GPU kernels (paper §3).
//!
//! The pipeline mirrors Figure 1 of the paper:
//!
//! ```text
//!   kernel.dsl ──lex──▶ tokens ──parse──▶ AST ──lower──▶ typed ConfigIR
//!       ──validate (arch gating, alignment, SMEM budget, …)──▶
//!       ──codegen──▶ { CUTLASS-style C++ header, variant key, hash }
//! ```
//!
//! The grammar is the paper's Appendix A.1 EBNF; the validation rules are
//! the compiler-enforced CONSTRAINTS block of that grammar, implemented in
//! [`validate`]. When validation fails the error explains *what* and *why*
//! (the paper stresses this lets the model fix the spec before burning a
//! compile/run/profile attempt).
//!
//! ```no_run
//! use ucutlass_repro::dsl;
//! let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\n\
//!            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)\n\
//!            .with_arch(sm_90a)\n\
//!            .with_threadblockshape(m=128, n=128, k=64)\n\
//!            .with_alignment(A=8, B=8, C=8)\n\
//!            .with_stages(2)\n\
//!            .with_scheduler(kernel=tma_cooperative, epilogue=auto)\n\
//!            >> bias() >> relu()";
//! let compiled = dsl::compile(src).unwrap();
//! assert!(compiled.header.contains("CollectiveBuilder"));
//! ```

pub mod ast;
pub mod codegen;
pub mod error;
pub mod format;
pub mod ir;
pub mod parser;
pub mod token;
pub mod validate;

pub use ast::{EpilogueCall, KernelSpec, Program, Stage, TransposeSpec};
pub use codegen::{Compiled, VariantKey};
pub use error::{DslError, DslErrorKind};
pub use ir::{Arch, ConfigIr, DType, EpilogueOp, GemmLayout, Operation, PipelineIr,
             ProgramIr, Scheduler};

/// Compile a µCUTLASS program: parse → lower → validate → codegen.
pub fn compile(source: &str) -> Result<Compiled, DslError> {
    let ir = validate_source(source)?;
    Ok(codegen::generate(source, &ir))
}

/// Parse → lower → validate, without code generation. This is the agent
/// hot path: the generate→validate→repair loop only needs the accept/
/// reject verdict (codegen runs once, for the accepted program).
pub fn validate_source(source: &str) -> Result<ProgramIr, DslError> {
    let program = parser::parse(source)?;
    let ir = ir::lower(&program)?;
    validate::validate(&ir)?;
    Ok(ir)
}

/// Compile and additionally bind against concrete problem dimensions,
/// running the dimension-dependent checks (operand-swap M==N, alignment
/// divisibility). `dims` is (M, N, K) for GEMM-family ops.
pub fn compile_bound(source: &str, dims: (u64, u64, u64)) -> Result<Compiled, DslError> {
    let compiled = compile(source)?;
    validate::validate_bound(&compiled.ir, dims)?;
    Ok(compiled)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_example_compiles() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
            .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=8)\
            .with_stages(2).with_scheduler(kernel=tma_cooperative, epilogue=auto)\
            >> bias() >> relu()";
        let c = compile(src).unwrap();
        assert_eq!(c.variant_key.family, "gemm");
        assert!(c.header.contains("ucutlass_"));
    }
}
