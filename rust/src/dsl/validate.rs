//! Constraint validation — the compiler-enforced CONSTRAINTS block of the
//! paper's Appendix A.1 grammar, plus the operator/feature gating of
//! Table 1. This is where µCUTLASS earns its keep: invalid configurations
//! are rejected *statically*, before any compile/run/profile attempt.
//!
//! Since ADR-001 the rules are **data-driven**: every per-architecture
//! fact (SMEM capacity, stage ceiling, tile bounds, dtype/feature gating)
//! lives in a [`ConstraintTable`] keyed by [`Arch`], and `validate()` is a
//! generic interpreter over the selected table — adding an architecture is
//! a table row, not a code edit (the zpl-toolchain ADR-0002 approach).

use super::error::{DslError, DslErrorKind};
use super::ir::*;
use super::plan;

/// SMEM capacity per SM on SM90 (228 KB usable) and the reserved slack the
/// grammar's stage formula subtracts (8 KB). Kept as named constants
/// because the Hopper table rows and several hint strings cite them.
pub const SM90_SMEM_BYTES: u64 = 228 * 1024;
pub const SM90_SMEM_RESERVED: u64 = 8 * 1024;

// ---------------------------------------------------------------------------
// Per-architecture constraint tables (paper Table 1 + Appendix A.1)
// ---------------------------------------------------------------------------

/// Everything `validate()` needs to know about one target architecture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConstraintTable {
    pub arch: Arch,
    /// Usable shared memory per SM (bytes).
    pub smem_bytes: u64,
    /// Reserved slack subtracted from the stage budget (bytes).
    pub smem_reserved: u64,
    /// Whether the `stages × per_stage + epilogue ≤ budget` formula is
    /// statically enforced (the grammar states it for SM90+ only; on
    /// SM70–89 the 2.x builders fall back to smaller stage counts).
    pub enforce_smem_budget: bool,
    /// Maximum pipeline stage count accepted by `.with_stages()`.
    pub max_stages: u64,
    /// Largest plausible threadblock tile (m, n, k).
    pub max_tile: (u64, u64, u64),
    /// MMA-atom alignment each tile dimension must honour (m, n, k).
    pub tile_align: (u64, u64, u64),
    /// Largest per-operand alignment in elements (power of two).
    pub max_alignment_elems: u64,
    /// TMA vector width in bytes; 0 = no TMA alignment requirement.
    pub tma_vector_bytes: u64,
    /// BF16 tensor cores available (Ampere+).
    pub supports_bf16: bool,
    /// FP8 (e4m3/e5m2) tensor cores available (paper rule: Hopper+).
    pub supports_fp8: bool,
    /// Warp-specialized 3.x feature block: clusters, kernel/epilogue
    /// schedules, `.with_threadblockshape()` spelling, operand swap.
    /// `false` selects the 2.x block: `.with_tile()`, swizzle, iterator,
    /// split-k.
    pub warp_specialized: bool,
    /// Maximum CTAs per thread-block cluster (0 = clusters unsupported).
    pub max_cluster_ctas: u64,
    /// Grouped GEMM coverage (Table 1a: SM80+).
    pub supports_grouped_gemm: bool,
    /// Grouped convolution coverage (Table 1a: SM80–89 only).
    pub supports_grouped_conv: bool,
    /// Conv3d wgrad coverage (Table 1a: SM70–89 only).
    pub supports_conv3d_wgrad: bool,
    /// `custom()` EVT epilogues (CollectiveBuilder route, SM90a only).
    pub supports_custom_epilogue: bool,
    /// The bare arch name is rejected in favour of its `a` suffix
    /// (sm_90 → sm_90a).
    pub requires_a_suffix: bool,
    /// Maximum fused epilogue chain length (EVT limit).
    pub max_epilogue_ops: usize,
}

/// Shared SM70–89 (CUTLASS 2.x route) defaults; rows below override.
const BASE_2X: ConstraintTable = ConstraintTable {
    arch: Arch::Sm70,
    smem_bytes: 96 * 1024,
    smem_reserved: 8 * 1024,
    enforce_smem_budget: false,
    max_stages: 12,
    max_tile: (512, 512, 256),
    tile_align: (16, 8, 8),
    max_alignment_elems: 16,
    tma_vector_bytes: 0,
    supports_bf16: false,
    supports_fp8: false,
    warp_specialized: false,
    max_cluster_ctas: 0,
    supports_grouped_gemm: false,
    supports_grouped_conv: false,
    supports_conv3d_wgrad: true,
    supports_custom_epilogue: false,
    requires_a_suffix: false,
    max_epilogue_ops: 8,
};

/// Shared SM90+ (CollectiveBuilder route) defaults; rows below override.
const BASE_3X: ConstraintTable = ConstraintTable {
    arch: Arch::Sm90a,
    smem_bytes: SM90_SMEM_BYTES,
    smem_reserved: SM90_SMEM_RESERVED,
    enforce_smem_budget: true,
    max_stages: 12,
    max_tile: (512, 512, 256),
    tile_align: (16, 8, 8),
    max_alignment_elems: 16,
    tma_vector_bytes: 16,
    supports_bf16: true,
    supports_fp8: true,
    warp_specialized: true,
    max_cluster_ctas: 16,
    supports_grouped_gemm: true,
    supports_grouped_conv: false,
    supports_conv3d_wgrad: false,
    supports_custom_epilogue: false,
    requires_a_suffix: false,
    max_epilogue_ops: 8,
};

const SM70: ConstraintTable = ConstraintTable { arch: Arch::Sm70, ..BASE_2X };
const SM80: ConstraintTable = ConstraintTable {
    arch: Arch::Sm80,
    smem_bytes: 164 * 1024,
    supports_bf16: true,
    supports_grouped_gemm: true,
    supports_grouped_conv: true,
    ..BASE_2X
};
const SM86: ConstraintTable = ConstraintTable {
    arch: Arch::Sm86,
    smem_bytes: 100 * 1024,
    supports_bf16: true,
    supports_grouped_gemm: true,
    supports_grouped_conv: true,
    ..BASE_2X
};
const SM89: ConstraintTable = ConstraintTable {
    arch: Arch::Sm89,
    smem_bytes: 100 * 1024,
    supports_bf16: true,
    supports_grouped_gemm: true,
    supports_grouped_conv: true,
    ..BASE_2X
};
const SM90: ConstraintTable =
    ConstraintTable { arch: Arch::Sm90, requires_a_suffix: true, ..BASE_3X };
const SM90A: ConstraintTable =
    ConstraintTable { arch: Arch::Sm90a, supports_custom_epilogue: true, ..BASE_3X };
const SM100: ConstraintTable = ConstraintTable { arch: Arch::Sm100, ..BASE_3X };

/// Look up the constraint table for an architecture.
pub fn constraint_table(arch: Arch) -> &'static ConstraintTable {
    match arch {
        Arch::Sm70 => &SM70,
        Arch::Sm80 => &SM80,
        Arch::Sm86 => &SM86,
        Arch::Sm89 => &SM89,
        Arch::Sm90 => &SM90,
        Arch::Sm90a => &SM90A,
        Arch::Sm100 => &SM100,
    }
}

// ---------------------------------------------------------------------------
// The generic validator
// ---------------------------------------------------------------------------

/// Validate a lowered program against all static constraints.
pub fn validate(prog: &ProgramIr) -> Result<(), DslError> {
    match prog {
        ProgramIr::Kernel(k) => validate_kernel(k),
        ProgramIr::Pipeline(p) => validate_pipeline(p),
    }
}

fn validate_pipeline(p: &PipelineIr) -> Result<(), DslError> {
    let n_kernels = p.stages.iter().filter(|s| matches!(s, StageIr::Kernel(_))).count();
    if n_kernels == 0 {
        return Err(DslError::new(
            DslErrorKind::Constraint,
            "pipeline has no kernel stage",
            "a pipeline orchestrates transforms around at least one kernel: pipeline(transpose(...), gemm()..., transpose(...))",
        ));
    }
    let first_kernel = p.stages.iter().position(|s| matches!(s, StageIr::Kernel(_))).unwrap();
    let last_kernel = p.stages.iter().rposition(|s| matches!(s, StageIr::Kernel(_))).unwrap();
    for (i, s) in p.stages.iter().enumerate() {
        match s {
            StageIr::Kernel(k) => validate_kernel(k)?,
            StageIr::Transpose { target, from_dtype, to_dtype, .. } => {
                if target == "output" && i < first_kernel {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose(output, ...) appears before any kernel stage",
                        "output transforms restore layout/dtype after the kernel; put them after the kernel stage",
                    ));
                }
                if target == "input" && i > last_kernel {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose(input, ...) appears after the last kernel stage",
                        "input transforms prepare operands; put them before the kernel stage",
                    ));
                }
                if from_dtype.is_some() != to_dtype.is_some() {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose dtype conversion needs both source and destination dtypes",
                        "e.g. transpose(input, NCL, NLC, fp32, fp16)",
                    ));
                }
            }
        }
    }
    Ok(())
}

fn err(off: usize, msg: &str, hint: &str) -> DslError {
    DslError::at(DslErrorKind::Constraint, off, msg, hint)
}

fn validate_kernel(k: &ConfigIr) -> Result<(), DslError> {
    let off = k.offset;

    // --- REQUIRED configurations ------------------------------------------
    let arch = k.arch.ok_or_else(|| {
        err(off, "missing required .with_arch()",
            "every kernel must name its target architecture, e.g. .with_arch(sm_90a)")
    })?;
    if k.dtype_input.is_none() {
        return Err(err(off, "missing required .with_dtype()",
            "e.g. .with_dtype(input=fp16, acc=fp32, output=fp16)"));
    }
    if k.op.is_gemm_family() && k.layout_a.is_none() {
        return Err(err(off, "missing required .with_layout() for GEMM",
            "e.g. .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)"));
    }

    let t = constraint_table(arch);
    let din = k.dtype_input.unwrap();
    let dout = k.dtype_output.unwrap_or(din);

    // --- operator × architecture coverage (Table 1a) -----------------------
    match &k.op {
        Operation::GroupedGemm { .. } if !t.supports_grouped_gemm => {
            return Err(err(off, "grouped_gemm requires SM80+",
                "Table 1a: Grouped GEMM is supported on SM80 and newer"));
        }
        Operation::Conv3dWgrad { .. } if !t.supports_conv3d_wgrad => {
            return Err(err(off, "conv3d_wgrad is not supported on SM90+",
                "Table 1a: Conv3d wgrad covers SM70–89 only; target sm_80/sm_89 or use a different formulation"));
        }
        Operation::GroupConv1d { .. } | Operation::GroupConv2d { .. }
        | Operation::GroupConv3d { .. } => {
            if !t.supports_grouped_conv {
                return Err(err(off, "grouped convolutions are supported on SM80–89 only",
                    "Table 1a: Grouped Conv requires SM80–89"));
            }
        }
        _ => {}
    }

    // --- dtype × architecture gating ---------------------------------------
    for d in [Some(din), k.dtype_acc, Some(dout)].into_iter().flatten() {
        if d == DType::Bf16 && !t.supports_bf16 {
            return Err(err(off, "bf16 requires SM80+",
                "bfloat16 tensor cores were introduced with Ampere (SM80)"));
        }
        if d.is_fp8() && !t.supports_fp8 {
            return Err(err(off, "fp8 requires SM90+",
                "FP8 (e4m3/e5m2) tensor cores were introduced with Hopper (SM90)"));
        }
    }

    // --- SM90 rule 1: always sm_90a ----------------------------------------
    if t.requires_a_suffix {
        return Err(err(off, &format!("use {arch}a, not {arch}"),
            "the 'a' suffix enables wgmma/warp-specialized features; this applies to ALL schedules (tma, tma_cooperative, cp_async, …)"));
    }

    // --- tile spelling gating (SM90 rule 2) --------------------------------
    if let Some(spelling) = k.tile_spelling {
        match (spelling, t.warp_specialized) {
            (TileSpelling::WithTile, true) => {
                return Err(err(off, ".with_tile() is rejected on SM90+",
                    "use .with_threadblockshape(m=…, n=…, k=…) on SM90+ (SM90 constraint 2)"));
            }
            (TileSpelling::WithThreadblockShape, false) => {
                return Err(err(off, ".with_threadblockshape() requires SM90+",
                    "use .with_tile(m=…, n=…, k=…) on SM70–89"));
            }
            _ => {}
        }
    }

    // --- feature gating (Table 1b) ------------------------------------------
    if k.cluster.is_some() && !t.warp_specialized {
        return Err(err(off, ".with_cluster() requires SM90+",
            "thread-block clusters were introduced with Hopper"));
    }
    if k.scheduler.is_some() && !t.warp_specialized {
        return Err(err(off, ".with_scheduler() requires SM90+",
            "kernel/epilogue schedules (TMA, pingpong, cooperative) are SM90+ features; SM70–89 uses .with_swizzle()"));
    }
    if k.swizzle.is_some() && t.warp_specialized {
        return Err(err(off, ".with_swizzle() is SM70–89 only",
            "on SM90+ use .with_scheduler(tile=…) instead"));
    }
    if k.iterator.is_some() && t.warp_specialized {
        return Err(err(off, ".with_iterator() is SM70–89 only", ""));
    }
    if k.iterator.is_some() && !k.op.is_conv_family() {
        return Err(err(off, ".with_iterator() applies to convolutions only", ""));
    }
    if k.split_k.is_some() && t.warp_specialized {
        return Err(err(off, ".with_split_k() is SM70–89 only",
            "on SM90+ use .with_scheduler(tile=stream_k) for K-dimension parallelism"));
    }
    if k.operand_swap && !t.warp_specialized {
        return Err(err(off, ".with_operand_swap() requires SM90+", ""));
    }

    // --- tile sanity ----------------------------------------------------------
    if let Some(tl) = k.tile {
        if tl.m == 0 || tl.n == 0 || tl.k == 0 {
            return Err(err(off, "tile dimensions must be positive", ""));
        }
        let (am, an, ak) = t.tile_align;
        if tl.m % am != 0 || tl.n % an != 0 || tl.k % ak != 0 {
            return Err(err(off,
                &format!("tile {}x{}x{} is not MMA-atom aligned", tl.m, tl.n, tl.k),
                &format!("tile m must be a multiple of {am}, n a multiple of {an}, k a multiple of {ak} (tensor-core atom shapes)")));
        }
        let (mm, mn, mk) = t.max_tile;
        if tl.m > mm || tl.n > mn || tl.k > mk {
            return Err(err(off,
                &format!("tile {}x{}x{} is implausibly large", tl.m, tl.n, tl.k),
                "the largest practical threadblock tiles are 256x256 with k ≤ 128"));
        }
    }

    // --- cluster sanity ---------------------------------------------------------
    if let Some(c) = k.cluster {
        let legal = [1u64, 2, 4, 8, 16];
        if !legal.contains(&c.m) || !legal.contains(&c.n) || c.k != 1 {
            return Err(err(off,
                &format!("cluster {}x{}x{} is invalid", c.m, c.n, c.k),
                "cluster m/n must be 1, 2, 4, 8 or 16 and cluster k must be 1"));
        }
        if c.m * c.n > t.max_cluster_ctas {
            return Err(err(off,
                &format!("cluster size exceeds {} CTAs", t.max_cluster_ctas),
                "Hopper clusters span at most 16 thread blocks"));
        }
    }

    // --- stages sanity -----------------------------------------------------------
    if let Some(s) = k.stages {
        if s == 0 || s > t.max_stages {
            return Err(err(off, &format!("with_stages({s}) is out of range"),
                &format!("pipeline stages are between 1 and {}", t.max_stages)));
        }
    }

    // --- alignment rules -----------------------------------------------------------
    if let Some(al) = k.alignment {
        for (name, v) in [("A", al.a), ("B", al.b), ("C", al.c)] {
            if v == 0 || !v.is_power_of_two() || v > t.max_alignment_elems {
                return Err(err(off,
                    &format!("alignment {name}={v} is invalid"),
                    &format!("alignments are powers of two between 1 and {} (elements)",
                        t.max_alignment_elems)));
            }
        }
        // SM90 rule 3: TMA alignment — (alignment * element_size) % 16 == 0.
        if t.tma_vector_bytes > 0 {
            let checks = [("A", al.a, din), ("B", al.b, din), ("C", al.c, dout)];
            for (name, v, d) in checks {
                if (v * d.size()) % t.tma_vector_bytes != 0 {
                    return Err(err(off,
                        &format!("TMA alignment violated for operand {name}: {v} elements × {} bytes = {} bytes, not a multiple of {}",
                            d.size(), v * d.size(), t.tma_vector_bytes),
                        "SM90 TMA requires 16-byte aligned vectors: fp16/bf16 need alignment ≥ 8, fp32 needs ≥ 4 (SM90 constraint 3)"));
                }
            }
        }
    }

    // --- scheduler coupling (SM90 rules 4–6) --------------------------------------
    if let Some(sch) = k.scheduler {
        if sch.kernel == KernelSchedule::TmaCooperative
            && !matches!(sch.epilogue, EpilogueSchedule::TmaCooperative | EpilogueSchedule::Auto)
        {
            return Err(err(off,
                "kernel=tma_cooperative requires epilogue=tma_cooperative (or auto)",
                "mismatched schedules cause the 'MMA_TILE_M must divide EPI_TILE_M' instantiation error (SM90 constraint 4)"));
        }
        let cooperative = matches!(
            sch.kernel,
            KernelSchedule::TmaCooperative | KernelSchedule::CpAsyncCooperative
        );
        if cooperative {
            let tl = k.effective_tile();
            let cm = k.cluster.map(|c| c.m).unwrap_or(1);
            if tl.m / cm.max(1) < 128 {
                return Err(err(off,
                    &format!("cooperative kernel needs tile_m/cluster_m ≥ 128, got {}/{} = {}",
                        tl.m, cm, tl.m / cm.max(1)),
                    "cooperative schedules split the M tile across two warp groups; per-CTA M below 128 cannot host both (SM90 constraint 5)"));
            }
            if sch.kernel == KernelSchedule::TmaCooperative && k.stages.is_none() {
                return Err(err(off,
                    "kernel=tma_cooperative requires explicit .with_stages(…)",
                    "stage count must be stated so the SMEM budget is checkable: stages = (228KB - epilogue_smem - 8KB) / per_stage_smem (SM90 constraint 6)"));
            }
        }
    }

    // --- SMEM stage budget (SM90 rule 6) -------------------------------------------
    if t.enforce_smem_budget {
        if let (Some(stages), Some(tl)) = (k.stages, k.tile) {
            let per_stage = plan::stage_smem_bytes(tl, din);
            let epi_smem =
                plan::epilogue_smem_bytes(k.scheduler.unwrap_or_default().epilogue, tl, dout);
            let budget = t.smem_bytes - t.smem_reserved;
            let need = stages * per_stage + epi_smem;
            if need > budget {
                let max_stages = if per_stage == 0 { 0 } else { (budget.saturating_sub(epi_smem)) / per_stage };
                return Err(err(off,
                    &format!(
                        "SMEM budget exceeded: {stages} stages × {per_stage} B/stage + {epi_smem} B epilogue = {need} B > {budget} B"),
                    &format!("large tiles exhaust shared memory; this tile supports at most {max_stages} stage(s) — use a smaller tile, fp16/bf16 inputs, .with_stages({}), or epilogue=no_smem (SM90 constraint 6)",
                        max_stages.max(1))));
            }
        }
    }

    // --- operand swap static half (SM90 rule 7; M==N checked at bind) ---------------
    if k.operand_swap {
        if !matches!(k.op, Operation::Gemm) {
            return Err(err(off, ".with_operand_swap(true) applies to GEMM only", ""));
        }
        if !matches!(din, DType::Fp32 | DType::Tf32) {
            return Err(err(off,
                ".with_operand_swap(true) is an FP32 GEMM optimization",
                "FP16/BF16 already use the RS GMMA variant with RowMajor B; operand swap only benefits FP32 (SM90 constraint 7)"));
        }
    }

    // --- epilogue rules ----------------------------------------------------------------
    if k.epilogue.len() > t.max_epilogue_ops {
        return Err(err(off,
            &format!("epilogue chain of {} ops is too long", k.epilogue.len()),
            &format!("EVT fusion supports at most {} chained epilogue ops", t.max_epilogue_ops)));
    }
    let n_bias = k.epilogue.iter().filter(|e| matches!(e, EpilogueOp::Bias)).count();
    if n_bias > 1 {
        return Err(err(off, "bias() may appear at most once in an epilogue chain", ""));
    }
    for e in &k.epilogue {
        if let EpilogueOp::Custom { expr, .. } = e {
            if !t.supports_custom_epilogue {
                return Err(err(off,
                    "custom() epilogue expressions require sm_90a",
                    "custom EVT nodes are emitted through the CUTLASS 3.x CollectiveBuilder, which is SM90a-only (Table 1c)"));
            }
            if expr.trim().is_empty() {
                return Err(err(off, "custom() expression is empty", ""));
            }
        }
        if let EpilogueOp::Clip { lo, hi } = e {
            if lo > hi {
                return Err(err(off,
                    &format!("clip range [{lo}, {hi}] is inverted"), "lo must be ≤ hi"));
            }
        }
    }
    // depthwise conv on SM90+ routes to the CuTe backend with restricted epilogues
    if matches!(k.op, Operation::DepthwiseConv2d { .. } | Operation::DepthwiseConv1d { .. })
        && t.warp_specialized
    {
        let ok = k.epilogue.iter().all(|e| {
            matches!(e, EpilogueOp::Relu | EpilogueOp::Bias | EpilogueOp::Scale { .. })
        });
        if !ok {
            return Err(err(off,
                "depthwise conv on SM90+ (CuTe backend) supports only relu/bias/scale epilogues",
                "Table 1a: the SM90+ depthwise route has limited epilogue support; lower the arch to sm_89 or simplify the chain"));
        }
    }

    Ok(())
}

/// Dimension-dependent checks run when a compiled program is bound to a
/// concrete problem: operand-swap squareness and alignment divisibility.
pub fn validate_bound(prog: &ProgramIr, dims: (u64, u64, u64)) -> Result<(), DslError> {
    let (m, n, kdim) = dims;
    for k in prog.kernels() {
        if k.operand_swap && m != n {
            return Err(DslError::new(
                DslErrorKind::Bind,
                &format!(".with_operand_swap(true) requires a square output, got M={m}, N={n}"),
                "the (A·B)^T = B^T·A^T reinterpretation is only layout-free when M == N (SM90 constraint 7)",
            ));
        }
        if let Some(al) = k.alignment {
            for (nm, align, dim) in [("A", al.a, kdim), ("B", al.b, n), ("C", al.c, n)] {
                if align > 0 && dim % align != 0 {
                    return Err(DslError::new(
                        DslErrorKind::Bind,
                        &format!(
                            "operand {nm} alignment {align} does not divide its contiguous dimension {dim}"),
                        "choose an alignment that divides the problem's leading dimension, or pad the tensor",
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{compile, compile_bound};

    fn compile_err(src: &str) -> String {
        compile(src).unwrap_err().to_string()
    }

    const SM90_BASE: &str = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)";

    #[test]
    fn accepts_valid_sm90_gemm() {
        let src = format!("{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64)\
            .with_alignment(A=8, B=8, C=8).with_stages(3)");
        assert!(compile(&src).is_ok());
    }

    #[test]
    fn requires_arch() {
        let e = compile_err("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor)");
        assert!(e.contains("with_arch"), "{e}");
    }

    #[test]
    fn requires_dtype() {
        let e = compile_err("gemm().with_arch(sm_80)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor)");
        assert!(e.contains("with_dtype"), "{e}");
    }

    #[test]
    fn requires_gemm_layout() {
        let e = compile_err("gemm().with_arch(sm_80).with_dtype(input=fp32, acc=fp32, output=fp32)");
        assert!(e.contains("with_layout"), "{e}");
    }

    #[test]
    fn rejects_sm90_without_a() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90)");
        assert!(e.contains("sm_90a"), "{e}");
    }

    #[test]
    fn rejects_with_tile_on_sm90() {
        let e = compile_err(&format!("{SM90_BASE}.with_tile(m=128, n=128, k=32)"));
        assert!(e.contains("with_threadblockshape"), "{e}");
    }

    #[test]
    fn rejects_threadblockshape_on_sm80() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_threadblockshape(m=128, n=128, k=32)");
        assert!(e.contains("SM90+"), "{e}");
    }

    #[test]
    fn rejects_bf16_on_sm70() {
        let e = compile_err("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_70)");
        assert!(e.contains("bf16 requires SM80+"), "{e}");
    }

    #[test]
    fn rejects_fp8_below_sm90() {
        let e = compile_err("gemm().with_dtype(input=fp8_e4m3, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_89)");
        assert!(e.contains("fp8 requires SM90+"), "{e}");
    }

    #[test]
    fn rejects_tma_alignment_violation() {
        // fp16: alignment 4 × 2 bytes = 8 bytes, not a multiple of 16
        let e = compile_err(&format!("{SM90_BASE}.with_alignment(A=4, B=8, C=8)"));
        assert!(e.contains("TMA alignment"), "{e}");
    }

    #[test]
    fn fp32_alignment4_is_tma_ok() {
        let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_alignment(A=4, B=4, C=4)";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn rejects_cooperative_epilogue_mismatch() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64).with_stages(2)\
             .with_scheduler(kernel=tma_cooperative, epilogue=tma)"));
        assert!(e.contains("MMA_TILE_M"), "{e}");
    }

    #[test]
    fn rejects_cooperative_small_per_cta_m() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64).with_stages(2)\
             .with_cluster(m=2, n=1, k=1)\
             .with_scheduler(kernel=tma_cooperative, epilogue=auto)"));
        assert!(e.contains("128"), "{e}");
    }

    #[test]
    fn cooperative_requires_explicit_stages() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64)\
             .with_scheduler(kernel=tma_cooperative, epilogue=auto)"));
        assert!(e.contains("with_stages"), "{e}");
    }

    #[test]
    fn rejects_smem_exhaustion() {
        // 256x128x64 fp32 tiles: per stage (256*64 + 64*128)*4 = 98 KB;
        // 3 stages ≈ 295 KB >> 220 KB budget.
        let e = compile_err("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_threadblockshape(m=256, n=128, k=64).with_stages(3)");
        assert!(e.contains("SMEM budget"), "{e}");
        assert!(e.contains("at most"), "{e}");
    }

    #[test]
    fn operand_swap_fp32_only() {
        let e = compile_err(&format!("{SM90_BASE}.with_operand_swap(true)"));
        assert!(e.contains("FP32"), "{e}");
    }

    #[test]
    fn operand_swap_bind_requires_square() {
        let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_operand_swap(true)";
        assert!(compile_bound(src, (1024, 1024, 512)).is_ok());
        let e = compile_bound(src, (1024, 512, 512)).unwrap_err();
        assert_eq!(e.kind, DslErrorKind::Bind);
        assert!(e.to_string().contains("square"), "{e}");
    }

    #[test]
    fn bind_alignment_divisibility() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
            .with_alignment(A=8, B=8, C=8)";
        assert!(compile_bound(src, (128, 128, 128)).is_ok());
        let e = compile_bound(src, (128, 128, 100)).unwrap_err();
        assert!(e.to_string().contains("alignment"), "{e}");
    }

    #[test]
    fn rejects_custom_epilogue_below_sm90a() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_tile(m=128, n=128, k=32) >> custom('x * 2')");
        assert!(e.contains("sm_90a"), "{e}");
    }

    #[test]
    fn rejects_conv3d_wgrad_on_sm90() {
        let e = compile_err("conv3d_wgrad(kernel_d=3, kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a)");
        assert!(e.contains("SM90"), "{e}");
    }

    #[test]
    fn rejects_grouped_conv_outside_sm80_89() {
        let e = compile_err("group_conv2d(kernel_h=3, kernel_w=3, groups=4)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a)");
        assert!(e.contains("SM80–89"), "{e}");
        let e = compile_err("group_conv2d(kernel_h=3, kernel_w=3, groups=4)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_70)");
        assert!(e.contains("SM80–89"), "{e}");
    }

    #[test]
    fn rejects_swizzle_on_sm90() {
        let e = compile_err(&format!("{SM90_BASE}.with_swizzle(pattern=Identity4)"));
        assert!(e.contains("SM70–89"), "{e}");
    }

    #[test]
    fn rejects_scheduler_on_sm80() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_scheduler(kernel=tma)");
        assert!(e.contains("SM90+"), "{e}");
    }

    #[test]
    fn rejects_bad_cluster() {
        let e = compile_err(&format!("{SM90_BASE}.with_cluster(m=3, n=1, k=1)"));
        assert!(e.contains("cluster"), "{e}");
    }

    #[test]
    fn rejects_misaligned_tile() {
        let e = compile_err(&format!("{SM90_BASE}.with_threadblockshape(m=100, n=128, k=32)"));
        assert!(e.contains("MMA-atom"), "{e}");
    }

    #[test]
    fn rejects_inverted_clip() {
        let e = compile_err(&format!("{SM90_BASE} >> clip(lo=2.0, hi=1.0)"));
        assert!(e.contains("inverted"), "{e}");
    }

    #[test]
    fn rejects_double_bias() {
        let e = compile_err(&format!("{SM90_BASE} >> bias() >> relu() >> bias()"));
        assert!(e.contains("bias"), "{e}");
    }

    #[test]
    fn pipeline_checks_transform_placement() {
        let e = compile(
            "pipeline(transpose(output, NLC, NCL), gemm()\
             .with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a))",
        )
        .unwrap_err();
        assert!(e.to_string().contains("before any kernel"), "{e}");
    }

    #[test]
    fn valid_pipeline_accepted() {
        let src = "pipeline(transpose(input, NCL, NLC, fp32, fp16), \
            gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a), \
            transpose(output, NLC, NCL, fp16, fp32))";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn depthwise_sm90_epilogue_restrictions() {
        let ok = "depthwise_conv2d(kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a) >> relu()";
        assert!(compile(ok).is_ok());
        let bad = "depthwise_conv2d(kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a) >> gelu()";
        assert!(compile(bad).unwrap_err().to_string().contains("CuTe"), );
    }

    // -- constraint-table coverage -------------------------------------------

    #[test]
    fn tables_cover_every_arch() {
        for arch in [Arch::Sm70, Arch::Sm80, Arch::Sm86, Arch::Sm89, Arch::Sm90,
                     Arch::Sm90a, Arch::Sm100] {
            let t = constraint_table(arch);
            assert_eq!(t.arch, arch);
            assert!(t.smem_bytes > t.smem_reserved);
            assert_eq!(t.warp_specialized, arch.is_sm90_plus());
        }
    }

    #[test]
    fn table_rows_encode_table1_facts() {
        assert!(!constraint_table(Arch::Sm70).supports_bf16);
        assert!(constraint_table(Arch::Sm80).supports_bf16);
        assert!(!constraint_table(Arch::Sm89).supports_fp8);
        assert!(constraint_table(Arch::Sm90a).supports_fp8);
        assert!(constraint_table(Arch::Sm80).supports_grouped_conv);
        assert!(!constraint_table(Arch::Sm90a).supports_grouped_conv);
        assert!(constraint_table(Arch::Sm89).supports_conv3d_wgrad);
        assert!(!constraint_table(Arch::Sm100).supports_conv3d_wgrad);
        assert!(constraint_table(Arch::Sm90).requires_a_suffix);
        assert!(!constraint_table(Arch::Sm90a).requires_a_suffix);
        assert!(constraint_table(Arch::Sm90a).supports_custom_epilogue);
        assert!(!constraint_table(Arch::Sm100).supports_custom_epilogue);
        assert_eq!(constraint_table(Arch::Sm90a).smem_bytes, SM90_SMEM_BYTES);
    }

    #[test]
    fn sm70_accepts_2x_features() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_70)\
            .with_tile(m=128, n=128, k=32).with_swizzle(pattern=Identity4)\
            .with_split_k(mode=serial, slices=2).with_stages(2)";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn sm80_accepts_grouped_conv() {
        let src = "group_conv2d(kernel_h=3, kernel_w=3, groups=4)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_80)\
            .with_layout(input=TensorNHWC, filter=TensorNHWC, output=TensorNHWC)\
            .with_tile(m=64, n=64, k=32)";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn sm80_accepts_grouped_gemm_sm70_rejects() {
        let sm80 = "grouped_gemm(expert_count=8)\
            .with_dtype(input=bf16, acc=fp32, output=bf16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)";
        assert!(compile(sm80).is_ok());
        let sm70 = "grouped_gemm(expert_count=8)\
            .with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_70)";
        assert!(compile_err(sm70).contains("SM80+"));
    }

    #[test]
    fn smem_budget_not_enforced_on_2x_route() {
        // This tile+stages combination would blow the SM89 100KB capacity,
        // but the grammar states the stage formula for SM90+ only; the 2.x
        // builders degrade gracefully instead of rejecting statically.
        let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_89)\
            .with_tile(m=256, n=128, k=64).with_stages(3)";
        assert!(compile(src).is_ok());
        assert!(!constraint_table(Arch::Sm89).enforce_smem_budget);
    }

    // -- differential property test: table-driven vs legacy SM90 rules ------

    /// The pre-ADR-001 hardcoded SM90 rule set, kept verbatim as the
    /// differential oracle: accept/reject must agree on random SM90
    /// configurations.
    mod legacy {
        use super::super::super::ir::*;
        use super::super::{SM90_SMEM_BYTES, SM90_SMEM_RESERVED};

        fn epilogue_smem_bytes(k: &ConfigIr, t: Tile, dout: DType) -> u64 {
            let sch = k.scheduler.unwrap_or_default();
            match sch.epilogue {
                EpilogueSchedule::NoSmem => 0,
                _ => t.m * (t.n / 2).max(8) * dout.size() / 2,
            }
        }

        pub fn validate_kernel(k: &ConfigIr) -> Result<(), String> {
            let e = |m: &str| Err(m.to_string());
            let arch = match k.arch {
                Some(a) => a,
                None => return e("missing arch"),
            };
            if k.dtype_input.is_none() {
                return e("missing dtype");
            }
            if k.op.is_gemm_family() && k.layout_a.is_none() {
                return e("missing layout");
            }
            let din = k.dtype_input.unwrap();
            let dout = k.dtype_output.unwrap_or(din);
            let sm90 = arch.is_sm90_plus();
            match &k.op {
                Operation::GroupedGemm { .. } if arch.level() < 80 => return e("grouped gemm"),
                Operation::Conv3dWgrad { .. } if sm90 => return e("conv3d wgrad"),
                Operation::GroupConv1d { .. } | Operation::GroupConv2d { .. }
                | Operation::GroupConv3d { .. } => {
                    if arch.level() < 80 || sm90 {
                        return e("grouped conv");
                    }
                }
                _ => {}
            }
            for d in [Some(din), k.dtype_acc, Some(dout)].into_iter().flatten() {
                if d == DType::Bf16 && arch.level() < 80 {
                    return e("bf16");
                }
                if d.is_fp8() && !sm90 {
                    return e("fp8");
                }
            }
            if arch == Arch::Sm90 {
                return e("sm_90a");
            }
            if let Some(spelling) = k.tile_spelling {
                match (spelling, sm90) {
                    (TileSpelling::WithTile, true) => return e("with_tile"),
                    (TileSpelling::WithThreadblockShape, false) => return e("tbs"),
                    _ => {}
                }
            }
            if k.cluster.is_some() && !sm90 {
                return e("cluster");
            }
            if k.scheduler.is_some() && !sm90 {
                return e("scheduler");
            }
            if k.swizzle.is_some() && sm90 {
                return e("swizzle");
            }
            if k.iterator.is_some() && sm90 {
                return e("iterator");
            }
            if k.iterator.is_some() && !k.op.is_conv_family() {
                return e("iterator-op");
            }
            if k.split_k.is_some() && sm90 {
                return e("split_k");
            }
            if k.operand_swap && !sm90 {
                return e("operand_swap arch");
            }
            if let Some(t) = k.tile {
                if t.m == 0 || t.n == 0 || t.k == 0 {
                    return e("tile zero");
                }
                if t.m % 16 != 0 || t.n % 8 != 0 || t.k % 8 != 0 {
                    return e("tile align");
                }
                if t.m > 512 || t.n > 512 || t.k > 256 {
                    return e("tile large");
                }
            }
            if let Some(c) = k.cluster {
                let legal = [1u64, 2, 4, 8, 16];
                if !legal.contains(&c.m) || !legal.contains(&c.n) || c.k != 1 {
                    return e("cluster bad");
                }
                if c.m * c.n > 16 {
                    return e("cluster big");
                }
            }
            if let Some(s) = k.stages {
                if s == 0 || s > 12 {
                    return e("stages");
                }
            }
            if let Some(al) = k.alignment {
                for v in [al.a, al.b, al.c] {
                    if v == 0 || !v.is_power_of_two() || v > 16 {
                        return e("alignment");
                    }
                }
                if sm90 {
                    for (v, d) in [(al.a, din), (al.b, din), (al.c, dout)] {
                        if (v * d.size()) % 16 != 0 {
                            return e("tma");
                        }
                    }
                }
            }
            if let Some(sch) = k.scheduler {
                if sch.kernel == KernelSchedule::TmaCooperative
                    && !matches!(
                        sch.epilogue,
                        EpilogueSchedule::TmaCooperative | EpilogueSchedule::Auto
                    )
                {
                    return e("coop epilogue");
                }
                let cooperative = matches!(
                    sch.kernel,
                    KernelSchedule::TmaCooperative | KernelSchedule::CpAsyncCooperative
                );
                if cooperative {
                    let t = k.effective_tile();
                    let cm = k.cluster.map(|c| c.m).unwrap_or(1);
                    if t.m / cm.max(1) < 128 {
                        return e("coop m");
                    }
                    if sch.kernel == KernelSchedule::TmaCooperative && k.stages.is_none() {
                        return e("coop stages");
                    }
                }
            }
            if sm90 {
                if let (Some(stages), Some(t)) = (k.stages, k.tile) {
                    let per_stage = (t.m * t.k + t.k * t.n) * din.size();
                    let epi_smem = epilogue_smem_bytes(k, t, dout);
                    let budget = SM90_SMEM_BYTES - SM90_SMEM_RESERVED;
                    if stages * per_stage + epi_smem > budget {
                        return e("smem");
                    }
                }
            }
            if k.operand_swap {
                if !matches!(k.op, Operation::Gemm) {
                    return e("swap op");
                }
                if !matches!(din, DType::Fp32 | DType::Tf32) {
                    return e("swap dtype");
                }
            }
            if k.epilogue.len() > 8 {
                return e("epi long");
            }
            if k.epilogue.iter().filter(|x| matches!(x, EpilogueOp::Bias)).count() > 1 {
                return e("double bias");
            }
            for x in &k.epilogue {
                if let EpilogueOp::Custom { expr, .. } = x {
                    if arch != Arch::Sm90a {
                        return e("custom arch");
                    }
                    if expr.trim().is_empty() {
                        return e("custom empty");
                    }
                }
                if let EpilogueOp::Clip { lo, hi } = x {
                    if lo > hi {
                        return e("clip");
                    }
                }
            }
            if matches!(
                k.op,
                Operation::DepthwiseConv2d { .. } | Operation::DepthwiseConv1d { .. }
            ) && sm90
            {
                let ok = k.epilogue.iter().all(|x| {
                    matches!(x, EpilogueOp::Relu | EpilogueOp::Bias | EpilogueOp::Scale { .. })
                });
                if !ok {
                    return e("depthwise epi");
                }
            }
            Ok(())
        }
    }

    use crate::util::prop;
    use crate::util::rng::Pcg32;

    /// Random (frequently-invalid) configuration generator over every
    /// architecture row, biased toward SM90a (the densest rule set).
    fn random_config(rng: &mut Pcg32) -> ConfigIr {
        let op = match rng.below(5) {
            0 => Operation::Gemm,
            1 => Operation::BatchedGemm,
            2 => Operation::GroupedGemm { expert_count: 4 },
            3 => Operation::DepthwiseConv2d { kh: 3, kw: 3 },
            _ => Operation::Conv2dFprop { kh: 3, kw: 3 },
        };
        let mut k = ConfigIr::new(op, 0);
        let arch = if rng.chance(0.5) {
            Arch::Sm90a
        } else {
            *rng.choice(&[Arch::Sm70, Arch::Sm80, Arch::Sm86, Arch::Sm89, Arch::Sm90,
                          Arch::Sm100])
        };
        k.arch = Some(arch);
        let dts = [DType::Fp16, DType::Bf16, DType::Fp32, DType::Tf32, DType::Fp8E4m3];
        k.dtype_input = Some(*rng.choice(&dts));
        k.dtype_acc = Some(DType::Fp32);
        k.dtype_output =
            Some(if rng.chance(0.5) { k.dtype_input.unwrap() } else { DType::Fp32 });
        if k.op.is_gemm_family() {
            k.layout_a = Some(GemmLayout::RowMajor);
            k.layout_b = Some(*rng.choice(&[GemmLayout::RowMajor, GemmLayout::ColumnMajor]));
            k.layout_c = Some(GemmLayout::RowMajor);
        }
        if rng.chance(0.85) {
            let ms = [64u64, 100, 128, 256, 512, 768];
            let ns = [8u64, 60, 64, 128, 256, 640];
            let ks = [8u64, 32, 64, 128, 256, 320];
            k.tile = Some(Tile { m: *rng.choice(&ms), n: *rng.choice(&ns), k: *rng.choice(&ks) });
            // usually the spelling matching the arch, sometimes the wrong one
            let arch_spelling = if arch.is_sm90_plus() {
                TileSpelling::WithThreadblockShape
            } else {
                TileSpelling::WithTile
            };
            let wrong_spelling = if arch.is_sm90_plus() {
                TileSpelling::WithTile
            } else {
                TileSpelling::WithThreadblockShape
            };
            k.tile_spelling = Some(if rng.chance(0.85) { arch_spelling } else { wrong_spelling });
        }
        if rng.chance(0.7) {
            k.stages = Some(rng.below(14) as u64);
        }
        if rng.chance(0.6) {
            let opts = [1u64, 2, 3, 4, 8, 16, 32];
            k.alignment = Some(Alignment {
                a: *rng.choice(&opts),
                b: *rng.choice(&opts),
                c: *rng.choice(&opts),
            });
        }
        if rng.chance(0.4) {
            let cs = [1u64, 2, 3, 4, 8, 16];
            k.cluster = Some(Cluster {
                m: *rng.choice(&cs),
                n: *rng.choice(&cs),
                k: if rng.chance(0.8) { 1 } else { 2 },
            });
        }
        if rng.chance(0.15) {
            k.swizzle = Some(Swizzle::Identity4);
        }
        if rng.chance(0.5) {
            k.scheduler = Some(Scheduler {
                tile: *rng.choice(&[
                    TileScheduler::Default,
                    TileScheduler::Persistent,
                    TileScheduler::StreamK,
                ]),
                kernel: *rng.choice(&[
                    KernelSchedule::Auto,
                    KernelSchedule::Tma,
                    KernelSchedule::TmaCooperative,
                    KernelSchedule::CpAsyncCooperative,
                    KernelSchedule::TmaPingpong,
                ]),
                epilogue: *rng.choice(&[
                    EpilogueSchedule::Auto,
                    EpilogueSchedule::Tma,
                    EpilogueSchedule::TmaCooperative,
                    EpilogueSchedule::NoSmem,
                ]),
            });
        }
        if rng.chance(0.1) {
            k.iterator = Some(Iterator_::Optimized);
        }
        if rng.chance(0.1) {
            k.split_k = Some((SplitK::Serial, 2));
        }
        k.operand_swap = rng.chance(0.15);
        let n_epi = rng.below(11);
        for _ in 0..n_epi {
            k.epilogue.push(match rng.below(6) {
                0 => EpilogueOp::Relu,
                1 => EpilogueOp::Bias,
                2 => EpilogueOp::Gelu,
                3 => EpilogueOp::Scale { value: 0.5 },
                4 => EpilogueOp::Clip {
                    lo: rng.range_f64(-1.0, 1.0),
                    hi: rng.range_f64(-1.0, 1.0),
                },
                _ => EpilogueOp::Custom { expr: "x * 2".into(), inputs: vec![] },
            });
        }
        k
    }

    #[test]
    fn prop_table_driven_matches_legacy() {
        prop::check("table-vs-legacy", 600, |rng| {
            let k = random_config(rng);
            let new = super::validate(&ProgramIr::Kernel(k.clone())).is_ok();
            let old = legacy::validate_kernel(&k).is_ok();
            assert_eq!(
                new, old,
                "table-driven verdict {new} != legacy verdict {old} for {k:#?}"
            );
        });
    }
}
