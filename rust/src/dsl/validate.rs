//! Constraint validation — the compiler-enforced CONSTRAINTS block of the
//! paper's Appendix A.1 grammar, plus the operator/feature gating of
//! Table 1. This is where µCUTLASS earns its keep: invalid configurations
//! are rejected *statically*, before any compile/run/profile attempt.

use super::error::{DslError, DslErrorKind};
use super::ir::*;

/// SMEM capacity per SM on SM90 (228 KB usable) and the reserved slack the
/// grammar's stage formula subtracts (8 KB).
pub const SM90_SMEM_BYTES: u64 = 228 * 1024;
pub const SM90_SMEM_RESERVED: u64 = 8 * 1024;

/// Validate a lowered program against all static constraints.
pub fn validate(prog: &ProgramIr) -> Result<(), DslError> {
    match prog {
        ProgramIr::Kernel(k) => validate_kernel(k),
        ProgramIr::Pipeline(p) => validate_pipeline(p),
    }
}

fn validate_pipeline(p: &PipelineIr) -> Result<(), DslError> {
    let n_kernels = p.stages.iter().filter(|s| matches!(s, StageIr::Kernel(_))).count();
    if n_kernels == 0 {
        return Err(DslError::new(
            DslErrorKind::Constraint,
            "pipeline has no kernel stage",
            "a pipeline orchestrates transforms around at least one kernel: pipeline(transpose(...), gemm()..., transpose(...))",
        ));
    }
    let first_kernel = p.stages.iter().position(|s| matches!(s, StageIr::Kernel(_))).unwrap();
    let last_kernel = p.stages.iter().rposition(|s| matches!(s, StageIr::Kernel(_))).unwrap();
    for (i, s) in p.stages.iter().enumerate() {
        match s {
            StageIr::Kernel(k) => validate_kernel(k)?,
            StageIr::Transpose { target, from_dtype, to_dtype, .. } => {
                if target == "output" && i < first_kernel {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose(output, ...) appears before any kernel stage",
                        "output transforms restore layout/dtype after the kernel; put them after the kernel stage",
                    ));
                }
                if target == "input" && i > last_kernel {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose(input, ...) appears after the last kernel stage",
                        "input transforms prepare operands; put them before the kernel stage",
                    ));
                }
                if from_dtype.is_some() != to_dtype.is_some() {
                    return Err(DslError::new(
                        DslErrorKind::Constraint,
                        "transpose dtype conversion needs both source and destination dtypes",
                        "e.g. transpose(input, NCL, NLC, fp32, fp16)",
                    ));
                }
            }
        }
    }
    Ok(())
}

fn err(off: usize, msg: &str, hint: &str) -> DslError {
    DslError::at(DslErrorKind::Constraint, off, msg, hint)
}

fn validate_kernel(k: &ConfigIr) -> Result<(), DslError> {
    let off = k.offset;

    // --- REQUIRED configurations ------------------------------------------
    let arch = k.arch.ok_or_else(|| {
        err(off, "missing required .with_arch()",
            "every kernel must name its target architecture, e.g. .with_arch(sm_90a)")
    })?;
    if k.dtype_input.is_none() {
        return Err(err(off, "missing required .with_dtype()",
            "e.g. .with_dtype(input=fp16, acc=fp32, output=fp16)"));
    }
    if k.op.is_gemm_family() && k.layout_a.is_none() {
        return Err(err(off, "missing required .with_layout() for GEMM",
            "e.g. .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor)"));
    }

    let din = k.dtype_input.unwrap();
    let dout = k.dtype_output.unwrap_or(din);
    let sm90 = arch.is_sm90_plus();

    // --- operator × architecture coverage (Table 1a) -----------------------
    match &k.op {
        Operation::GroupedGemm { .. } if arch.level() < 80 => {
            return Err(err(off, "grouped_gemm requires SM80+",
                "Table 1a: Grouped GEMM is supported on SM80 and newer"));
        }
        Operation::Conv3dWgrad { .. } if sm90 => {
            return Err(err(off, "conv3d_wgrad is not supported on SM90+",
                "Table 1a: Conv3d wgrad covers SM70–89 only; target sm_80/sm_89 or use a different formulation"));
        }
        Operation::GroupConv1d { .. } | Operation::GroupConv2d { .. }
        | Operation::GroupConv3d { .. } => {
            if arch.level() < 80 || sm90 {
                return Err(err(off, "grouped convolutions are supported on SM80–89 only",
                    "Table 1a: Grouped Conv requires SM80–89"));
            }
        }
        _ => {}
    }

    // --- dtype × architecture gating ---------------------------------------
    for d in [Some(din), k.dtype_acc, Some(dout)].into_iter().flatten() {
        if d == DType::Bf16 && arch.level() < 80 {
            return Err(err(off, "bf16 requires SM80+",
                "bfloat16 tensor cores were introduced with Ampere (SM80)"));
        }
        if d.is_fp8() && !sm90 {
            return Err(err(off, "fp8 requires SM90+",
                "FP8 (e4m3/e5m2) tensor cores were introduced with Hopper (SM90)"));
        }
    }

    // --- SM90 rule 1: always sm_90a ----------------------------------------
    if arch == Arch::Sm90 {
        return Err(err(off, "use sm_90a, not sm_90",
            "the 'a' suffix enables wgmma/warp-specialized features; this applies to ALL schedules (tma, tma_cooperative, cp_async, …)"));
    }

    // --- tile spelling gating (SM90 rule 2) --------------------------------
    if let Some(spelling) = k.tile_spelling {
        match (spelling, sm90) {
            (TileSpelling::WithTile, true) => {
                return Err(err(off, ".with_tile() is rejected on SM90+",
                    "use .with_threadblockshape(m=…, n=…, k=…) on SM90+ (SM90 constraint 2)"));
            }
            (TileSpelling::WithThreadblockShape, false) => {
                return Err(err(off, ".with_threadblockshape() requires SM90+",
                    "use .with_tile(m=…, n=…, k=…) on SM70–89"));
            }
            _ => {}
        }
    }

    // --- feature gating (Table 1b) ------------------------------------------
    if k.cluster.is_some() && !sm90 {
        return Err(err(off, ".with_cluster() requires SM90+",
            "thread-block clusters were introduced with Hopper"));
    }
    if k.scheduler.is_some() && !sm90 {
        return Err(err(off, ".with_scheduler() requires SM90+",
            "kernel/epilogue schedules (TMA, pingpong, cooperative) are SM90+ features; SM70–89 uses .with_swizzle()"));
    }
    if k.swizzle.is_some() && sm90 {
        return Err(err(off, ".with_swizzle() is SM70–89 only",
            "on SM90+ use .with_scheduler(tile=…) instead"));
    }
    if k.iterator.is_some() && sm90 {
        return Err(err(off, ".with_iterator() is SM70–89 only", ""));
    }
    if k.iterator.is_some() && !k.op.is_conv_family() {
        return Err(err(off, ".with_iterator() applies to convolutions only", ""));
    }
    if k.split_k.is_some() && sm90 {
        return Err(err(off, ".with_split_k() is SM70–89 only",
            "on SM90+ use .with_scheduler(tile=stream_k) for K-dimension parallelism"));
    }
    if k.operand_swap && !sm90 {
        return Err(err(off, ".with_operand_swap() requires SM90+", ""));
    }

    // --- tile sanity ----------------------------------------------------------
    if let Some(t) = k.tile {
        if t.m == 0 || t.n == 0 || t.k == 0 {
            return Err(err(off, "tile dimensions must be positive", ""));
        }
        if t.m % 16 != 0 || t.n % 8 != 0 || t.k % 8 != 0 {
            return Err(err(off,
                &format!("tile {}x{}x{} is not MMA-atom aligned", t.m, t.n, t.k),
                "tile m must be a multiple of 16, n and k multiples of 8 (tensor-core atom shapes)"));
        }
        if t.m > 512 || t.n > 512 || t.k > 256 {
            return Err(err(off,
                &format!("tile {}x{}x{} is implausibly large", t.m, t.n, t.k),
                "the largest practical threadblock tiles are 256x256 with k ≤ 128"));
        }
    }

    // --- cluster sanity ---------------------------------------------------------
    if let Some(c) = k.cluster {
        let legal = [1u64, 2, 4, 8, 16];
        if !legal.contains(&c.m) || !legal.contains(&c.n) || c.k != 1 {
            return Err(err(off,
                &format!("cluster {}x{}x{} is invalid", c.m, c.n, c.k),
                "cluster m/n must be 1, 2, 4, 8 or 16 and cluster k must be 1"));
        }
        if c.m * c.n > 16 {
            return Err(err(off, "cluster size exceeds 16 CTAs",
                "Hopper clusters span at most 16 thread blocks"));
        }
    }

    // --- stages sanity -----------------------------------------------------------
    if let Some(s) = k.stages {
        if s == 0 || s > 12 {
            return Err(err(off, &format!("with_stages({s}) is out of range"),
                "pipeline stages are between 1 and 12"));
        }
    }

    // --- alignment rules -----------------------------------------------------------
    if let Some(al) = k.alignment {
        for (name, v) in [("A", al.a), ("B", al.b), ("C", al.c)] {
            if v == 0 || !v.is_power_of_two() || v > 16 {
                return Err(err(off,
                    &format!("alignment {name}={v} is invalid"),
                    "alignments are powers of two between 1 and 16 (elements)"));
            }
        }
        // SM90 rule 3: TMA alignment — (alignment * element_size) % 16 == 0.
        if sm90 {
            let checks = [("A", al.a, din), ("B", al.b, din), ("C", al.c, dout)];
            for (name, v, d) in checks {
                if (v * d.size()) % 16 != 0 {
                    return Err(err(off,
                        &format!("TMA alignment violated for operand {name}: {v} elements × {} bytes = {} bytes, not a multiple of 16",
                            d.size(), v * d.size()),
                        "SM90 TMA requires 16-byte aligned vectors: fp16/bf16 need alignment ≥ 8, fp32 needs ≥ 4 (SM90 constraint 3)"));
                }
            }
        }
    }

    // --- scheduler coupling (SM90 rules 4–6) --------------------------------------
    if let Some(sch) = k.scheduler {
        if sch.kernel == KernelSchedule::TmaCooperative
            && !matches!(sch.epilogue, EpilogueSchedule::TmaCooperative | EpilogueSchedule::Auto)
        {
            return Err(err(off,
                "kernel=tma_cooperative requires epilogue=tma_cooperative (or auto)",
                "mismatched schedules cause the 'MMA_TILE_M must divide EPI_TILE_M' instantiation error (SM90 constraint 4)"));
        }
        let cooperative = matches!(
            sch.kernel,
            KernelSchedule::TmaCooperative | KernelSchedule::CpAsyncCooperative
        );
        if cooperative {
            let t = k.effective_tile();
            let cm = k.cluster.map(|c| c.m).unwrap_or(1);
            if t.m / cm.max(1) < 128 {
                return Err(err(off,
                    &format!("cooperative kernel needs tile_m/cluster_m ≥ 128, got {}/{} = {}",
                        t.m, cm, t.m / cm.max(1)),
                    "cooperative schedules split the M tile across two warp groups; per-CTA M below 128 cannot host both (SM90 constraint 5)"));
            }
            if sch.kernel == KernelSchedule::TmaCooperative && k.stages.is_none() {
                return Err(err(off,
                    "kernel=tma_cooperative requires explicit .with_stages(…)",
                    "stage count must be stated so the SMEM budget is checkable: stages = (228KB - epilogue_smem - 8KB) / per_stage_smem (SM90 constraint 6)"));
            }
        }
    }

    // --- SMEM stage budget (SM90 rule 6) -------------------------------------------
    if sm90 {
        if let (Some(stages), Some(t)) = (k.stages, k.tile) {
            let per_stage = (t.m * t.k + t.k * t.n) * din.size();
            let epi_smem = epilogue_smem_bytes(k, t, dout);
            let budget = SM90_SMEM_BYTES - SM90_SMEM_RESERVED;
            let need = stages * per_stage + epi_smem;
            if need > budget {
                let max_stages = if per_stage == 0 { 0 } else { (budget.saturating_sub(epi_smem)) / per_stage };
                return Err(err(off,
                    &format!(
                        "SMEM budget exceeded: {stages} stages × {per_stage} B/stage + {epi_smem} B epilogue = {need} B > {budget} B"),
                    &format!("large tiles exhaust shared memory; this tile supports at most {max_stages} stage(s) — use a smaller tile, fp16/bf16 inputs, .with_stages({}), or epilogue=no_smem (SM90 constraint 6)",
                        max_stages.max(1))));
            }
        }
    }

    // --- operand swap static half (SM90 rule 7; M==N checked at bind) ---------------
    if k.operand_swap {
        if !matches!(k.op, Operation::Gemm) {
            return Err(err(off, ".with_operand_swap(true) applies to GEMM only", ""));
        }
        if !matches!(din, DType::Fp32 | DType::Tf32) {
            return Err(err(off,
                ".with_operand_swap(true) is an FP32 GEMM optimization",
                "FP16/BF16 already use the RS GMMA variant with RowMajor B; operand swap only benefits FP32 (SM90 constraint 7)"));
        }
    }

    // --- epilogue rules ----------------------------------------------------------------
    if k.epilogue.len() > 8 {
        return Err(err(off,
            &format!("epilogue chain of {} ops is too long", k.epilogue.len()),
            "EVT fusion supports at most 8 chained epilogue ops"));
    }
    let n_bias = k.epilogue.iter().filter(|e| matches!(e, EpilogueOp::Bias)).count();
    if n_bias > 1 {
        return Err(err(off, "bias() may appear at most once in an epilogue chain", ""));
    }
    for e in &k.epilogue {
        if let EpilogueOp::Custom { expr, .. } = e {
            if arch != Arch::Sm90a {
                return Err(err(off,
                    "custom() epilogue expressions require sm_90a",
                    "custom EVT nodes are emitted through the CUTLASS 3.x CollectiveBuilder, which is SM90a-only (Table 1c)"));
            }
            if expr.trim().is_empty() {
                return Err(err(off, "custom() expression is empty", ""));
            }
        }
        if let EpilogueOp::Clip { lo, hi } = e {
            if lo > hi {
                return Err(err(off,
                    &format!("clip range [{lo}, {hi}] is inverted"), "lo must be ≤ hi"));
            }
        }
    }
    // depthwise conv on SM90+ routes to the CuTe backend with restricted epilogues
    if matches!(k.op, Operation::DepthwiseConv2d { .. } | Operation::DepthwiseConv1d { .. })
        && sm90
    {
        let ok = k.epilogue.iter().all(|e| {
            matches!(e, EpilogueOp::Relu | EpilogueOp::Bias | EpilogueOp::Scale { .. })
        });
        if !ok {
            return Err(err(off,
                "depthwise conv on SM90+ (CuTe backend) supports only relu/bias/scale epilogues",
                "Table 1a: the SM90+ depthwise route has limited epilogue support; lower the arch to sm_89 or simplify the chain"));
        }
    }

    Ok(())
}

/// Epilogue SMEM estimate used in the stage-budget formula: TMA epilogues
/// stage the output tile through shared memory.
fn epilogue_smem_bytes(k: &ConfigIr, t: Tile, dout: DType) -> u64 {
    let sch = k.scheduler.unwrap_or_default();
    match sch.epilogue {
        EpilogueSchedule::NoSmem => 0,
        // auto/tma/tma_cooperative: one output sub-tile (m × n/2) staged
        _ => t.m * (t.n / 2).max(8) * dout.size() / 2,
    }
}

/// Dimension-dependent checks run when a compiled program is bound to a
/// concrete problem: operand-swap squareness and alignment divisibility.
pub fn validate_bound(prog: &ProgramIr, dims: (u64, u64, u64)) -> Result<(), DslError> {
    let (m, n, kdim) = dims;
    for k in prog.kernels() {
        if k.operand_swap && m != n {
            return Err(DslError::new(
                DslErrorKind::Bind,
                &format!(".with_operand_swap(true) requires a square output, got M={m}, N={n}"),
                "the (A·B)^T = B^T·A^T reinterpretation is only layout-free when M == N (SM90 constraint 7)",
            ));
        }
        if let Some(al) = k.alignment {
            for (nm, align, dim) in [("A", al.a, kdim), ("B", al.b, n), ("C", al.c, n)] {
                if align > 0 && dim % align != 0 {
                    return Err(DslError::new(
                        DslErrorKind::Bind,
                        &format!(
                            "operand {nm} alignment {align} does not divide its contiguous dimension {dim}"),
                        "choose an alignment that divides the problem's leading dimension, or pad the tensor",
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{compile, compile_bound};

    fn compile_err(src: &str) -> String {
        compile(src).unwrap_err().to_string()
    }

    const SM90_BASE: &str = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)";

    #[test]
    fn accepts_valid_sm90_gemm() {
        let src = format!("{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64)\
            .with_alignment(A=8, B=8, C=8).with_stages(3)");
        assert!(compile(&src).is_ok());
    }

    #[test]
    fn requires_arch() {
        let e = compile_err("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor)");
        assert!(e.contains("with_arch"), "{e}");
    }

    #[test]
    fn requires_dtype() {
        let e = compile_err("gemm().with_arch(sm_80)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor)");
        assert!(e.contains("with_dtype"), "{e}");
    }

    #[test]
    fn requires_gemm_layout() {
        let e = compile_err("gemm().with_arch(sm_80).with_dtype(input=fp32, acc=fp32, output=fp32)");
        assert!(e.contains("with_layout"), "{e}");
    }

    #[test]
    fn rejects_sm90_without_a() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90)");
        assert!(e.contains("sm_90a"), "{e}");
    }

    #[test]
    fn rejects_with_tile_on_sm90() {
        let e = compile_err(&format!("{SM90_BASE}.with_tile(m=128, n=128, k=32)"));
        assert!(e.contains("with_threadblockshape"), "{e}");
    }

    #[test]
    fn rejects_threadblockshape_on_sm80() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_threadblockshape(m=128, n=128, k=32)");
        assert!(e.contains("SM90+"), "{e}");
    }

    #[test]
    fn rejects_bf16_on_sm70() {
        let e = compile_err("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_70)");
        assert!(e.contains("bf16 requires SM80+"), "{e}");
    }

    #[test]
    fn rejects_fp8_below_sm90() {
        let e = compile_err("gemm().with_dtype(input=fp8_e4m3, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_89)");
        assert!(e.contains("fp8 requires SM90+"), "{e}");
    }

    #[test]
    fn rejects_tma_alignment_violation() {
        // fp16: alignment 4 × 2 bytes = 8 bytes, not a multiple of 16
        let e = compile_err(&format!("{SM90_BASE}.with_alignment(A=4, B=8, C=8)"));
        assert!(e.contains("TMA alignment"), "{e}");
    }

    #[test]
    fn fp32_alignment4_is_tma_ok() {
        let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_alignment(A=4, B=4, C=4)";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn rejects_cooperative_epilogue_mismatch() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64).with_stages(2)\
             .with_scheduler(kernel=tma_cooperative, epilogue=tma)"));
        assert!(e.contains("MMA_TILE_M"), "{e}");
    }

    #[test]
    fn rejects_cooperative_small_per_cta_m() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64).with_stages(2)\
             .with_cluster(m=2, n=1, k=1)\
             .with_scheduler(kernel=tma_cooperative, epilogue=auto)"));
        assert!(e.contains("128"), "{e}");
    }

    #[test]
    fn cooperative_requires_explicit_stages() {
        let e = compile_err(&format!(
            "{SM90_BASE}.with_threadblockshape(m=128, n=128, k=64)\
             .with_scheduler(kernel=tma_cooperative, epilogue=auto)"));
        assert!(e.contains("with_stages"), "{e}");
    }

    #[test]
    fn rejects_smem_exhaustion() {
        // 256x128x64 fp32 tiles: per stage (256*64 + 64*128)*4 = 98 KB;
        // 3 stages ≈ 295 KB >> 220 KB budget.
        let e = compile_err("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_threadblockshape(m=256, n=128, k=64).with_stages(3)");
        assert!(e.contains("SMEM budget"), "{e}");
        assert!(e.contains("at most"), "{e}");
    }

    #[test]
    fn operand_swap_fp32_only() {
        let e = compile_err(&format!("{SM90_BASE}.with_operand_swap(true)"));
        assert!(e.contains("FP32"), "{e}");
    }

    #[test]
    fn operand_swap_bind_requires_square() {
        let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
            .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
            .with_operand_swap(true)";
        assert!(compile_bound(src, (1024, 1024, 512)).is_ok());
        let e = compile_bound(src, (1024, 512, 512)).unwrap_err();
        assert_eq!(e.kind, DslErrorKind::Bind);
        assert!(e.to_string().contains("square"), "{e}");
    }

    #[test]
    fn bind_alignment_divisibility() {
        let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
            .with_alignment(A=8, B=8, C=8)";
        assert!(compile_bound(src, (128, 128, 128)).is_ok());
        let e = compile_bound(src, (128, 128, 100)).unwrap_err();
        assert!(e.to_string().contains("alignment"), "{e}");
    }

    #[test]
    fn rejects_custom_epilogue_below_sm90a() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_tile(m=128, n=128, k=32) >> custom('x * 2')");
        assert!(e.contains("sm_90a"), "{e}");
    }

    #[test]
    fn rejects_conv3d_wgrad_on_sm90() {
        let e = compile_err("conv3d_wgrad(kernel_d=3, kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a)");
        assert!(e.contains("SM90"), "{e}");
    }

    #[test]
    fn rejects_grouped_conv_outside_sm80_89() {
        let e = compile_err("group_conv2d(kernel_h=3, kernel_w=3, groups=4)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a)");
        assert!(e.contains("SM80–89"), "{e}");
        let e = compile_err("group_conv2d(kernel_h=3, kernel_w=3, groups=4)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_70)");
        assert!(e.contains("SM80–89"), "{e}");
    }

    #[test]
    fn rejects_swizzle_on_sm90() {
        let e = compile_err(&format!("{SM90_BASE}.with_swizzle(pattern=Identity4)"));
        assert!(e.contains("SM70–89"), "{e}");
    }

    #[test]
    fn rejects_scheduler_on_sm80() {
        let e = compile_err("gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_80)\
            .with_scheduler(kernel=tma)");
        assert!(e.contains("SM90+"), "{e}");
    }

    #[test]
    fn rejects_bad_cluster() {
        let e = compile_err(&format!("{SM90_BASE}.with_cluster(m=3, n=1, k=1)"));
        assert!(e.contains("cluster"), "{e}");
    }

    #[test]
    fn rejects_misaligned_tile() {
        let e = compile_err(&format!("{SM90_BASE}.with_threadblockshape(m=100, n=128, k=32)"));
        assert!(e.contains("MMA-atom"), "{e}");
    }

    #[test]
    fn rejects_inverted_clip() {
        let e = compile_err(&format!("{SM90_BASE} >> clip(lo=2.0, hi=1.0)"));
        assert!(e.contains("inverted"), "{e}");
    }

    #[test]
    fn rejects_double_bias() {
        let e = compile_err(&format!("{SM90_BASE} >> bias() >> relu() >> bias()"));
        assert!(e.contains("bias"), "{e}");
    }

    #[test]
    fn pipeline_checks_transform_placement() {
        let e = compile(
            "pipeline(transpose(output, NLC, NCL), gemm()\
             .with_dtype(input=fp16, acc=fp32, output=fp16)\
             .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a))",
        )
        .unwrap_err();
        assert!(e.to_string().contains("before any kernel"), "{e}");
    }

    #[test]
    fn valid_pipeline_accepted() {
        let src = "pipeline(transpose(input, NCL, NLC, fp32, fp16), \
            gemm().with_dtype(input=fp16, acc=fp32, output=fp16)\
            .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a), \
            transpose(output, NLC, NCL, fp16, fp32))";
        assert!(compile(src).is_ok());
    }

    #[test]
    fn depthwise_sm90_epilogue_restrictions() {
        let ok = "depthwise_conv2d(kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a) >> relu()";
        assert!(compile(ok).is_ok());
        let bad = "depthwise_conv2d(kernel_h=3, kernel_w=3)\
            .with_dtype(input=fp16, acc=fp32, output=fp16).with_arch(sm_90a) >> gelu()";
        assert!(compile(bad).unwrap_err().to_string().contains("CuTe"), );
    }
}
