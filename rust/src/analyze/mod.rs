//! Static analysis over lowered µCUTLASS programs (ADR-009).
//!
//! `dsl::validate` is accept/reject-only: the first violated constraint
//! aborts compilation. This module is the other half of the paper's
//! "explanatory compiler feedback" claim (§3, §4.4): a *valid* program can
//! still be a wasted trial (duplicate config, SOL-infeasible candidate), a
//! benchmark-gaming vehicle (dead stages, accumulator drops, constant
//! outputs), or one step from a constraint cliff. The analyzer walks the
//! parsed AST and the lowered [`ProgramIr`] together and emits structured
//! [`Diagnostic`]s — stable rule ID, severity, source span, *why* text,
//! and an optional machine-applicable [`Fix`] — instead of a single error.
//!
//! Rule namespaces (shared with [`crate::dsl::DslErrorKind::code`]):
//!
//! | codes       | family                                        |
//! |-------------|-----------------------------------------------|
//! | `E001–E005` | compiler rejections (lex/parse/lower/validate/bind) |
//! | `A1xx`      | SOL-infeasibility / implausibility            |
//! | `A2xx`      | static gaming detection (dataflow walk)       |
//! | `A3xx`      | canonical-equivalence (duplicate-trial waste)  |
//! | `C4xx`      | constraint-cliff warnings (one step from reject) |
//!
//! The hot-loop half (A101/A102/A301 need a *session context*: current
//! best, seen hashes, stop policy) lives in [`prune::PruneGate`]; the
//! purely static rules run through [`analyze_source`] and back the
//! `repro lint` CLI.

use crate::dsl::ir::lower;
use crate::dsl::parser::parse;
use crate::dsl::validate::validate;
use crate::dsl::{Arch, DslError, Program, ProgramIr};
use crate::util::json::Json;

pub mod prune;
pub mod rules;

pub use prune::{PruneGate, PRUNE_MARGIN};

/// Diagnostic severity. `Deny` marks programs whose *measurement* cannot be
/// trusted (gaming vehicles); `Warn` marks wasted work; `Note` marks
/// fragile-but-valid configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Deny,
    Warn,
    Note,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
            Severity::Note => "note",
        }
    }
}

/// A half-open byte range `[offset, offset + len)` into the source text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub offset: usize,
    pub len: usize,
}

impl Span {
    pub fn new(offset: usize, len: usize) -> Span {
        Span { offset, len }
    }

    pub fn end(&self) -> usize {
        self.offset + self.len
    }

    /// The source text the span covers (empty if out of bounds).
    pub fn slice<'a>(&self, src: &'a str) -> &'a str {
        src.get(self.offset..self.end()).unwrap_or("")
    }
}

/// A machine-applicable rewrite: replace `span` with `replacement`.
#[derive(Debug, Clone, PartialEq)]
pub struct Fix {
    pub span: Span,
    pub replacement: String,
    /// Short imperative description, e.g. "remove the dead stage".
    pub title: String,
}

impl Fix {
    /// Apply the rewrite to `src` (pure; panics never — out-of-bounds
    /// spans return the source unchanged).
    pub fn apply(&self, src: &str) -> String {
        if self.span.end() > src.len() {
            return src.to_string();
        }
        let mut out = String::with_capacity(src.len() + self.replacement.len());
        out.push_str(&src[..self.span.offset]);
        out.push_str(&self.replacement);
        out.push_str(&src[self.span.end()..]);
        out
    }
}

/// Stable analyzer rule identifiers. Codes are append-only: a published
/// code never changes meaning or severity class (pinned by the golden and
/// uniqueness tests in `tests/lint.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuleId {
    /// A101 — the candidate's analytic lower bound cannot beat the current
    /// best measurement (hot-loop rule; see [`prune::PruneGate`]).
    SolInfeasible,
    /// A102 — the session's best already sits inside the scheduler's
    /// `StopRule::sol_band`: further trials cannot change the stop decision.
    SolBandStop,
    /// A103 — the epilogue forces a constant output: any measured speedup
    /// is benchmark gaming, and a sub-SOL runtime is physically meaningless
    /// for the declared computation.
    SolImplausible,
    /// A201 — a stage/op whose result is provably unobservable (dead
    /// transpose, cancelling transpose pair, aux_store never loaded).
    DeadStage,
    /// A202 — an epilogue that multiplies the accumulator by zero, dropping
    /// every FLOP the main loop computed.
    AccumulatorDrop,
    /// A203 — an identity epilogue op (scale(1), leaky_relu(alpha=1)):
    /// wasted EVT slot, wasted trial variance.
    IdentityChain,
    /// A301 — the program lowers to an already-seen canonical config hash:
    /// measuring it again is duplicate-trial waste (hot-loop rule).
    DuplicateConfig,
    /// C401 — SMEM use within one pipeline stage of the budget reject.
    SmemCliff,
    /// C402 — stage count exactly at the architecture maximum.
    StagesAtMax,
    /// C403 — operand alignment exactly at the TMA vector minimum.
    AlignmentAtTmaMin,
    /// C404 — a tile dimension exactly at the architecture maximum.
    TileAtMax,
}

impl RuleId {
    pub const ALL: [RuleId; 11] = [
        RuleId::SolInfeasible,
        RuleId::SolBandStop,
        RuleId::SolImplausible,
        RuleId::DeadStage,
        RuleId::AccumulatorDrop,
        RuleId::IdentityChain,
        RuleId::DuplicateConfig,
        RuleId::SmemCliff,
        RuleId::StagesAtMax,
        RuleId::AlignmentAtTmaMin,
        RuleId::TileAtMax,
    ];

    pub fn code(&self) -> &'static str {
        match self {
            RuleId::SolInfeasible => "A101",
            RuleId::SolBandStop => "A102",
            RuleId::SolImplausible => "A103",
            RuleId::DeadStage => "A201",
            RuleId::AccumulatorDrop => "A202",
            RuleId::IdentityChain => "A203",
            RuleId::DuplicateConfig => "A301",
            RuleId::SmemCliff => "C401",
            RuleId::StagesAtMax => "C402",
            RuleId::AlignmentAtTmaMin => "C403",
            RuleId::TileAtMax => "C404",
        }
    }

    pub fn parse_code(code: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.code() == code)
    }

    /// One-line rule summary (the registry entry in ADR-009).
    pub fn summary(&self) -> &'static str {
        match self {
            RuleId::SolInfeasible => "candidate cannot beat the current best measurement",
            RuleId::SolBandStop => "best already inside the scheduler's SOL band",
            RuleId::SolImplausible => "epilogue forces a constant output",
            RuleId::DeadStage => "stage result is provably unobservable",
            RuleId::AccumulatorDrop => "epilogue multiplies the accumulator by zero",
            RuleId::IdentityChain => "identity epilogue op has no effect",
            RuleId::DuplicateConfig => "lowers to an already-measured config hash",
            RuleId::SmemCliff => "within one pipeline stage of the SMEM budget",
            RuleId::StagesAtMax => "stage count at the architecture maximum",
            RuleId::AlignmentAtTmaMin => "alignment at the TMA vector minimum",
            RuleId::TileAtMax => "tile dimension at the architecture maximum",
        }
    }

    /// The rule's fixed severity class.
    pub fn severity(&self) -> Severity {
        match self {
            RuleId::SolImplausible | RuleId::AccumulatorDrop => Severity::Deny,
            RuleId::SolInfeasible
            | RuleId::SolBandStop
            | RuleId::DeadStage
            | RuleId::IdentityChain
            | RuleId::DuplicateConfig => Severity::Warn,
            RuleId::SmemCliff
            | RuleId::StagesAtMax
            | RuleId::AlignmentAtTmaMin
            | RuleId::TileAtMax => Severity::Note,
        }
    }
}

/// One structured finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub rule: RuleId,
    pub severity: Severity,
    /// Byte span of the offending construct (None when the finding has no
    /// single anchor, e.g. a whole-program property).
    pub span: Option<Span>,
    /// What is wrong.
    pub message: String,
    /// Why it matters — the explanatory half the paper calls out.
    pub why: String,
    pub fix: Option<Fix>,
}

impl Diagnostic {
    pub fn new(rule: RuleId, message: impl Into<String>, why: impl Into<String>) -> Diagnostic {
        Diagnostic {
            rule,
            severity: rule.severity(),
            span: None,
            message: message.into(),
            why: why.into(),
            fix: None,
        }
    }

    pub fn with_span(mut self, span: Span) -> Diagnostic {
        self.span = Some(span);
        self
    }

    pub fn with_fix(mut self, fix: Fix) -> Diagnostic {
        self.fix = Some(fix);
        self
    }

    /// The `repro lint --json` wire shape (one schema with
    /// [`DslError::to_json`]: code/severity/message + span/why/fix).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("code", self.rule.code())
            .set("severity", self.severity.name())
            .set("message", self.message.as_str())
            .set("why", self.why.as_str());
        match self.span {
            Some(s) => {
                let mut sp = Json::obj();
                sp.set("offset", s.offset as f64).set("len", s.len as f64);
                j.set("span", sp)
            }
            None => j.set("span", Json::Null),
        };
        match &self.fix {
            Some(f) => {
                let mut fj = Json::obj();
                let mut sp = Json::obj();
                sp.set("offset", f.span.offset as f64).set("len", f.span.len as f64);
                fj.set("span", sp)
                    .set("replacement", f.replacement.as_str())
                    .set("title", f.title.as_str());
                j.set("fix", fj)
            }
            None => j.set("fix", Json::Null),
        };
        j
    }

    /// Human-readable rendering, mirroring `DslError`'s style.
    pub fn render(&self, src: &str) -> String {
        let mut out = format!("{} [{}]", self.severity.name(), self.rule.code());
        if let Some(s) = self.span {
            out.push_str(&format!(" at offset {}", s.offset));
            let text = s.slice(src);
            if !text.is_empty() && text.len() <= 60 {
                out.push_str(&format!(" `{text}`"));
            }
        }
        out.push_str(&format!(": {}", self.message));
        if !self.why.is_empty() {
            out.push_str(&format!("\n  why: {}", self.why));
        }
        if let Some(f) = &self.fix {
            out.push_str(&format!("\n  fix: {} -> `{}`", f.title, f.replacement));
        }
        out
    }
}

/// Analyze a source program: parse → lower → validate → rule walk.
///
/// A compiler rejection (any stage) is returned as `Err` — it is already a
/// structured, coded error ([`DslError::to_json`]); the analyzer's job
/// starts where validate stops. On success the diagnostics are sorted by
/// (span offset, code) so output is stable across rule-evaluation order.
pub fn analyze_source(
    src: &str,
    arch_override: Option<Arch>,
) -> Result<Vec<Diagnostic>, DslError> {
    let ast = parse(src)?;
    let ir = lower(&ast)?;
    validate(&ir)?;
    Ok(analyze_program(src, &ast, &ir, arch_override))
}

/// The rule walk over an already-compiled program (no validation retry —
/// callers on the agent hot path hand in the IR they already have).
pub fn analyze_program(
    src: &str,
    ast: &Program,
    ir: &ProgramIr,
    arch_override: Option<Arch>,
) -> Vec<Diagnostic> {
    let mut diags = rules::run_static_rules(src, ast, ir, arch_override);
    diags.sort_by_key(|d| (d.span.map(|s| s.offset).unwrap_or(usize::MAX), d.rule.code()));
    diags
}

/// Count diagnostics at `Deny` after optional warning escalation — the
/// `repro lint` exit-code input.
pub fn deny_count(diags: &[Diagnostic], deny_warnings: bool) -> usize {
    diags
        .iter()
        .filter(|d| {
            d.severity == Severity::Deny || (deny_warnings && d.severity == Severity::Warn)
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_codes_unique_and_frozen() {
        let codes: Vec<&str> = RuleId::ALL.iter().map(|r| r.code()).collect();
        for (i, c) in codes.iter().enumerate() {
            assert!(!codes[i + 1..].contains(c), "duplicate rule code {c}");
            assert_eq!(RuleId::parse_code(c), Some(RuleId::ALL[i]));
        }
        assert_eq!(RuleId::SolImplausible.code(), "A103");
        assert_eq!(RuleId::DuplicateConfig.code(), "A301");
        assert_eq!(RuleId::SmemCliff.code(), "C401");
    }

    #[test]
    fn fix_apply_is_pure_and_bounded() {
        let fix = Fix {
            span: Span::new(4, 3),
            replacement: "XY".into(),
            title: "t".into(),
        };
        assert_eq!(fix.apply("abcdDEFgh"), "abcdXYgh");
        let oob = Fix { span: Span::new(100, 5), replacement: "x".into(), title: "t".into() };
        assert_eq!(oob.apply("short"), "short");
    }

    #[test]
    fn deny_count_escalation() {
        let d1 = Diagnostic::new(RuleId::AccumulatorDrop, "m", "w");
        let d2 = Diagnostic::new(RuleId::IdentityChain, "m", "w");
        let d3 = Diagnostic::new(RuleId::TileAtMax, "m", "w");
        let all = vec![d1, d2, d3];
        assert_eq!(deny_count(&all, false), 1);
        assert_eq!(deny_count(&all, true), 2, "notes never escalate");
    }
}
